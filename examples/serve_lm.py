"""Batched serving with the PDQ-int8 path (deliverable b).

Runs the same prompts through the fp and PDQ-int8(W8A8 + int8 KV) engines
and compares greedy outputs + tok/s.

    PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.models import build_model
from repro.serve import Request, ServeEngine


def main():
    cfg = reduced_config("yi-6b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 8) for _ in range(6)]

    results = {}
    for tag, kw, c in (
        ("fp", dict(quantize_weights=False), cfg),
        ("pdq-int8", dict(quantize_weights=True),
         dataclasses.replace(cfg, quant_kv="dynamic")),
    ):
        eng = ServeEngine(c, params, slots=3, max_len=64, **kw)
        reqs = [Request(uid=i, prompt=p, max_new=12)
                for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        toks = sum(len(r.generated) for r in reqs)
        results[tag] = [tuple(r.generated) for r in reqs]
        print(f"{tag:9s}: {toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s)")

    agree = np.mean([a == b for a, b in zip(results["fp"], results["pdq-int8"])])
    print(f"greedy sequence agreement fp vs pdq-int8: {agree:.2f} "
          "(random-weight demo model: near-uniform logits flip easily; "
          "tests/test_serve_and_fault.py checks parity on the same seeds)")


if __name__ == "__main__":
    main()
