"""Quickstart: the PDQ idea in 30 lines.

Calibrate once, then quantize a layer's output with parameters *predicted
from the input* - before the matmul runs (paper Sec. 4).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import run_calibration, spec_for_mode
from repro.core import qlinear


def model(params, x, *, spec, qstate, tape=None):
    """A 2-layer MLP whose pre-activations are quantized per the spec."""
    h = qlinear.dense(x, params[0], None, name="fc1",
                      policy=spec.resolve("fc1"), state=qstate, tape=tape)
    h = jax.nn.relu(h)
    return qlinear.dense(h, params[1], None, name="fc2",
                         policy=spec.resolve("fc2"), state=qstate, tape=tape)


def main():
    key = jax.random.PRNGKey(0)
    params = (0.1 * jax.random.normal(key, (256, 512)),
              0.1 * jax.random.normal(jax.random.PRNGKey(1), (512, 64)))

    # 1. calibrate (16 samples, shared by static & PDQ - as in the paper)
    calib = [jax.random.normal(jax.random.PRNGKey(i), (8, 256)) for i in range(2)]
    spec = spec_for_mode("pdq", per_channel=True)
    qstate = run_calibration(model, params, calib, spec)

    # 2. evaluate the three quantization modes under an input-scale shift
    x = 5.0 * jax.random.normal(jax.random.PRNGKey(9), (32, 256))
    ref = model(params, x, spec=spec_for_mode("none"), qstate={})
    for mode in ("static", "dynamic", "pdq"):
        out = model(params, x, spec=spec_for_mode(mode, per_channel=True),
                    qstate=qstate)
        err = float(jnp.abs(out - ref).mean() / jnp.abs(ref).mean())
        print(f"{mode:8s} rel-err under 5x input shift: {err:.4f}")
    print("-> PDQ tracks the shifted inputs (like dynamic) without ever "
          "materializing an unquantized output tensor (like static).")


if __name__ == "__main__":
    main()
