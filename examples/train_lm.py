"""End-to-end LM training driver (deliverable b).

Default: a ~20M-param GPT-style model for 200 steps on CPU (minutes).
--full trains a ~110M model for 300 steps - the assignment's "100M for a
few hundred steps" target - sized for a real accelerator.

    PYTHONPATH=src python examples/train_lm.py [--full] [--steps N]
"""
import argparse

from repro.data import DataConfig
from repro.models import build_model
from repro.models.config import ArchConfig
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def small_cfg(full: bool) -> ArchConfig:
    if full:  # ~110M params
        return ArchConfig(name="gpt-110m", n_layers=12, d_model=768,
                          n_heads=12, n_kv_heads=12, d_ff=3072, vocab=32000,
                          head_dim=64, loss_chunk=128, dtype="float32")
    return ArchConfig(name="gpt-20m", n_layers=6, d_model=384, n_heads=6,
                      n_kv_heads=6, d_ff=1536, vocab=8192, head_dim=64,
                      remat="none", loss_chunk=64, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = small_cfg(args.full)
    steps = args.steps or (300 if args.full else 200)
    bundle = build_model(cfg)
    trainer = Trainer(
        bundle, AdamWConfig(lr=3e-3),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch),
        TrainerConfig(total_steps=steps, ckpt_every=100,
                      ckpt_dir="/tmp/repro_train_lm", log_every=20))
    out = trainer.train()
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    print(f"{cfg.name}: loss {first:.3f} -> {last:.3f} over {steps} steps "
          f"(restarts={out['restarts']})")
    assert last < first, "training did not reduce the loss"


if __name__ == "__main__":
    main()
