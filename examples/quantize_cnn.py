"""Paper-track example: train a Mini-ResNet, calibrate, and compare
static / dynamic / PDQ quantization in-domain and under corruption.

    PYTHONPATH=src python examples/quantize_cnn.py
"""
import numpy as np

from repro.core import run_calibration, spec_for_mode
from repro.data.corruptions import corrupt_batch
from repro.models.cnn import CNNConfig, cnn_apply, make_gratings, train_cnn


def main():
    cfg = CNNConfig(arch="mini_resnet", width=16, res=20)
    print("training fp32 Mini-ResNet on synthetic gratings...")
    params = train_cnn(cfg, steps=150, batch=32)

    def apply_fn(p, x, *, spec, qstate, tape=None):
        return cnn_apply(p, x, cfg=cfg, spec=spec, qstate=qstate, tape=tape)

    import jax.numpy as jnp
    calib_imgs, _ = make_gratings(5, 16, res=cfg.res)
    spec = spec_for_mode("pdq", per_channel=True)
    qstate = run_calibration(apply_fn, params,
                             [jnp.asarray(calib_imgs)], spec)

    imgs, labels = make_gratings(77, 256, res=cfg.res)
    imgs_ood = corrupt_batch(imgs, np.random.default_rng(1))
    for name, data in (("in-domain", imgs), ("corrupted", imgs_ood)):
        print(f"\n{name}:")
        for mode in ("none", "static", "dynamic", "pdq"):
            sp = spec_for_mode(mode, per_channel=True)
            logits = apply_fn(params, jnp.asarray(data), spec=sp, qstate=qstate)
            acc = float((np.asarray(logits.argmax(-1)) == labels).mean())
            print(f"  {mode:8s} top-1 = {acc:.4f}")


if __name__ == "__main__":
    main()
