"""Paged KV-cache pool: bit-exactness against the slot-row engine.

Pins the PR-8 contract.  The paged pool (serve/pages.py + the paged ops
in models/api.py) changes the cache LAYOUT - fixed-size pages addressed
through per-slot indirection tables - but must never change a single
token: the decode step gathers the logical rows, runs the identical
program, and writes the frontier page back.  Every test here is a parity
pin against the slot-row engine on the SAME params:

  * mixed-length greedy + temperature workloads, across the GQA KV
    cache, the MLA compressed cache + SSM/conv tails, and the int8
    kernel-layout KV cache;
  * chunked prefill landing chunk by chunk into pages;
  * copy-on-write prefix sharing (a shared prompt page must produce the
    exact unshared stream, and the share must actually happen);
  * preempt-and-requeue under pool pressure ((uid, step)-keyed sampling
    regenerates the evicted tokens exactly);
  * host spill + warm restore (the resumed request continues from its
    spilled pages, same stream, without regenerating).

Plus the redesigned construction surface: ServeConfig/build_engine is
how every engine gets built, and the page-pool counters ride
engine.stats into ServeService.stats() (the GET /v1/stats payload).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import build_model
from repro.serve import (PageError, PagePool, Request, ServeConfig,
                         ServeEngine, ServeService, build_engine)

MIXED_LENS = [3, 5, 8, 9, 12, 16, 17, 23, 30, 4, 11, 27]

_MODELS = {}


def _model(arch, quant_kv=None):
    key = (arch, quant_kv)
    if key not in _MODELS:
        cfg = reduced_config(arch)
        if quant_kv:
            cfg = dataclasses.replace(cfg, quant_kv=quant_kv)
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        _MODELS[key] = (cfg, params)
    return _MODELS[key]


def _requests(cfg, lens, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                    max_new=max_new) for i, L in enumerate(lens)]


def _outputs(reqs):
    return {r.uid: (tuple(r.generated), r.finish_reason, r.error)
            for r in reqs}


def _run(cfg, params, lens, *, max_new=4, seed=0, **kw):
    eng = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16, 32),
                      **kw)
    reqs = _requests(cfg, lens, max_new=max_new, seed=seed)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    return eng, _outputs(reqs)


# ---------------------------------------------------------------------------
# layout parity: paged == slot-row, token for token
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["stablelm-1.6b",      # GQA KV
                                  "deepseek-v2-236b"])  # MLA + extra leaves
def test_paged_matches_slot_row(arch):
    cfg, params = _model(arch)
    _, want = _run(cfg, params, MIXED_LENS)
    eng, got = _run(cfg, params, MIXED_LENS, paged=True, page_size=16)
    assert got == want
    assert eng.stats["pages_total"] > 0


def test_paged_int8_kv_matches_slot_row():
    cfg, params = _model("gemma2-2b", quant_kv="dynamic")
    _, want = _run(cfg, params, MIXED_LENS)
    _, got = _run(cfg, params, MIXED_LENS, paged=True, page_size=16)
    assert got == want


def test_paged_temperature_matches_slot_row():
    """(uid, step)-keyed sampling is layout-independent: the paged engine
    draws the identical non-greedy stream."""
    cfg, params = _model("stablelm-1.6b")
    _, want = _run(cfg, params, MIXED_LENS, temperature=0.9)
    _, got = _run(cfg, params, MIXED_LENS, temperature=0.9,
                  paged=True, page_size=16)
    assert got == want


def test_paged_chunked_prefill_matches_slot_row():
    """Chunk continuations land page by page (prefill-pool rows scattered
    through the land map) and still reproduce the unchunked stream."""
    cfg, params = _model("stablelm-1.6b")
    lens = [3, 20, 40, 12, 33]            # beyond the 16 bucket
    ref = ServeEngine(cfg, params, slots=2, max_len=64, buckets=(8, 16),
                      chunked_prefill=True)
    reqs = _requests(cfg, lens, max_new=5)
    ref.run(reqs)
    want = _outputs(reqs)

    eng = ServeEngine(cfg, params, slots=2, max_len=64, buckets=(8, 16),
                      chunked_prefill=True, paged=True, page_size=16)
    reqs = _requests(cfg, lens, max_new=5)
    eng.run(reqs)
    assert _outputs(reqs) == want
    assert eng.stats["chunked_requests"] == 3


# ---------------------------------------------------------------------------
# prefix sharing
# ---------------------------------------------------------------------------


def test_prefix_share_hit_is_bit_exact():
    """A request arriving while an earlier one with the same prompt still
    holds its pages must SHARE the full prompt pages (copy-on-write) and
    still produce the exact unshared stream.  Liveness is staggered: A
    (long max_new) holds its prompt page while short fillers churn the
    other slots; B lands on a freed slot while A is live."""
    cfg, params = _model("stablelm-1.6b")
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, 200, size=20).astype(np.int32)

    def mk():
        reqs = [Request(uid=100, prompt=prompt.copy(), max_new=16)]
        r2 = np.random.default_rng(5)
        for i in range(3):
            reqs.append(Request(
                uid=101 + i,
                prompt=r2.integers(1, 200, size=5).astype(np.int32),
                max_new=2))
        reqs.append(Request(uid=104, prompt=prompt.copy(), max_new=8))
        return reqs

    ref = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16, 32),
                      temperature=0.7)
    ref.run(mk())
    eng = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16, 32),
                      temperature=0.7, paged=True, page_size=16)
    eng.run(mk())
    assert _outputs(eng.finished) == _outputs(ref.finished)
    assert eng.stats["prefix_hits"] > 0
    assert eng.stats["prefix_shared_pages"] > 0
    # no COW expected: only FULL prompt pages are ever shared, so the
    # write frontier of both sharers sits past the shared region by
    # construction - ensure_writable is the invariant guard, not a hot
    # path (cow_copies counts it if a future sharing scheme trips it)
    assert eng.stats["cow_copies"] == 0


def test_prefix_sharing_can_be_disabled():
    cfg, params = _model("stablelm-1.6b")
    eng, _ = _run(cfg, params, MIXED_LENS, paged=True, page_size=16,
                  prefix_sharing=False)
    assert eng.stats["prefix_hits"] == 0


# ---------------------------------------------------------------------------
# preemption + host spill
# ---------------------------------------------------------------------------


def _grow_reqs():
    # 17-token prompts claim 2 pages; max_new=30 forces a 3rd page
    # mid-decode, colliding in a 6-usable-page pool with 3 live rows
    rng = np.random.default_rng(7)
    return [Request(uid=50 + i,
                    prompt=rng.integers(1, 200, size=17).astype(np.int32),
                    max_new=30) for i in range(4)]


def _grow_ref(cfg, params):
    ref = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16, 32),
                      temperature=0.9)
    reqs = _grow_reqs()
    ref.run(reqs)
    return _outputs(reqs)


def test_preempt_and_requeue_is_token_exact():
    """Pool pressure mid-decode evicts the youngest victim; its requeue
    regenerates the dropped tokens exactly ((uid, step) sampling keys),
    so the client-visible stream is indistinguishable from no preemption."""
    cfg, params = _model("stablelm-1.6b")
    want = _grow_ref(cfg, params)
    eng = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16, 32),
                      temperature=0.9, paged=True, page_size=16,
                      pool_pages=7)
    reqs = _grow_reqs()
    eng.run(reqs)
    assert _outputs(reqs) == want
    assert eng.stats["preemptions"] > 0


def test_spill_warm_resume_is_token_exact():
    """With host spill on, the preempted request's pages round-trip
    through host memory and decode CONTINUES (no regeneration) - same
    stream, and the spill/restore counters prove the warm path ran."""
    cfg, params = _model("stablelm-1.6b")
    want = _grow_ref(cfg, params)
    eng = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16, 32),
                      temperature=0.9, paged=True, page_size=16,
                      pool_pages=7, spill=True)
    reqs = _grow_reqs()
    eng.run(reqs)
    assert _outputs(reqs) == want
    assert eng.stats["spills"] > 0
    assert eng.stats["spill_restores"] > 0


# ---------------------------------------------------------------------------
# the construction surface: ServeConfig + build_engine
# ---------------------------------------------------------------------------


def test_build_engine_single_device_paged():
    cfg, params = _model("stablelm-1.6b")
    sc = ServeConfig(slots=4, max_len=64, buckets=(8, 16, 32),
                     paged=True, page_size=16)
    eng = build_engine(sc, cfg=cfg, params=params)
    assert isinstance(eng, ServeEngine) and eng.paged
    reqs = _requests(cfg, MIXED_LENS)
    eng.run(reqs)
    _, want = _run(cfg, params, MIXED_LENS)
    assert _outputs(reqs) == want


def test_build_engine_resolves_model_from_config():
    sc = ServeConfig(arch="stablelm-1.6b", reduced=True, slots=2,
                     max_len=32, buckets=(8,))
    eng = build_engine(sc)
    assert isinstance(eng, ServeEngine)
    assert eng.cfg.name == reduced_config("stablelm-1.6b").name


def test_serve_config_validates():
    with pytest.raises(ValueError):
        ServeConfig(multihost=True).validate()          # multihost sans mesh
    with pytest.raises(ValueError):
        ServeConfig(mesh=object(), spill=True).validate()
    with pytest.raises(ValueError):
        ServeConfig(paged=True, batch_prefill=False).validate()
    with pytest.raises(ValueError):
        build_engine(ServeConfig(), cfg=object(), params=None)


# ---------------------------------------------------------------------------
# observability: page-pool counters ride stats into the service payload
# ---------------------------------------------------------------------------


def test_page_stats_surface_in_service_stats():
    cfg, params = _model("stablelm-1.6b")
    eng, _ = _run(cfg, params, MIXED_LENS, paged=True, page_size=16)
    page_keys = {"pages_total", "pages_used", "preemptions", "spills",
                 "spill_restores", "prefix_hits", "prefix_shared_pages",
                 "cow_copies"}
    assert page_keys <= set(eng.stats)
    # usable pages: pool minus the write-only dump page, per replica
    assert eng.stats["pages_total"] == (eng.pool_pages - 1) * eng.n_replicas
    assert eng.stats["pages_used"] == 0          # drained
    svc = ServeService(eng)                      # stats() needs no thread
    assert page_keys <= set(svc.stats())


def test_page_pool_reexported_from_serve():
    pool = PagePool(8, pages_per_seq=4, page=16)
    pool.attach(1)
    ids = pool.alloc(1, 3)
    assert pool.n_owned(1) == 3 and 0 not in ids
    with pytest.raises(PageError):
        pool.alloc(1, 99)
