"""Extra coverage: attention path parity, MoE bucketing properties,
corruption suite, paper-literal grid search, analytic flops model."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean envs: deterministic shim, see requirements-dev.txt
    from _hypo_compat import given, settings, strategies as st

from repro.models.attention import chunked_attention

HYPO = dict(max_examples=8, deadline=None, derandomize=True)


# ----------------------------------------------------- attention path parity
def _naive_attention(q, k, v, causal=True, window=None, cap=None):
    from repro.models.layers import softcap
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, Dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) / Dh ** 0.5
    s = softcap(s, cap)
    rel = jnp.arange(S)[:, None] - jnp.arange(S)[None, :]
    msk = jnp.ones((S, S), bool)
    if causal:
        msk &= rel >= 0
    if window is not None:
        msk &= rel < window
    s = jnp.where(msk, s, -2e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(B, S, H, Dh)


@settings(**HYPO)
@given(
    s=st.sampled_from([32, 64]),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2]),
    window=st.sampled_from([None, 16]),
    parallel_q=st.booleans(),
)
def test_chunked_attention_matches_naive(s, hkv, g, window, parallel_q):
    B, Dh = 2, 16
    H = hkv * g
    keys = jax.random.split(jax.random.PRNGKey(s + hkv + g), 3)
    q = jax.random.normal(keys[0], (B, s, H, Dh))
    k = jax.random.normal(keys[1], (B, s, hkv, Dh))
    v = jax.random.normal(keys[2], (B, s, hkv, Dh))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (B, s))
    got = chunked_attention(q, k, v, pos, pos, causal=True, window=window,
                            q_chunk=16, kv_chunk=16, parallel_q=parallel_q)
    want = _naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_parallel_q_equals_scan_q():
    """The SP-enabling batched-q path must be numerically identical to the
    memory-lean scanned-q path (hillclimb iteration 2)."""
    B, S, H, Dh = 2, 64, 4, 16
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (B, S, H, Dh))
    k = jax.random.normal(keys[1], (B, S, H, Dh))
    v = jax.random.normal(keys[2], (B, S, H, Dh))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    a = chunked_attention(q, k, v, pos, pos, q_chunk=16, kv_chunk=32,
                          parallel_q=False)
    b = chunked_attention(q, k, v, pos, pos, q_chunk=16, kv_chunk=32,
                          parallel_q=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


# -------------------------------------------------------- MoE bucket property
@settings(**HYPO)
@given(
    t=st.sampled_from([16, 64]),
    e=st.sampled_from([4, 8]),
    k=st.sampled_from([1, 2]),
)
def test_moe_bucket_roundtrip(t, e, k):
    """Every non-dropped assignment lands in its expert's bucket and is
    recovered exactly by the combine-side gather."""
    from repro.models.moe import _bucket
    key = jax.random.PRNGKey(t * e + k)
    x = jax.random.normal(key, (t * k, 8))
    ids = jax.random.randint(jax.random.PRNGKey(1), (t * k,), 0, e)
    C = max(1, int(t * k * 1.25 / e))
    buf, slot, valid = _bucket(x, ids, e, C)
    got = buf[ids, jnp.minimum(slot, C - 1)]
    got = jnp.where(valid[:, None], got, x)   # dropped ones unchecked
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-6)
    # capacity respected
    counts = np.bincount(np.asarray(ids)[np.asarray(valid)], minlength=e)
    assert counts.max() <= C


# ------------------------------------------------------------- corruptions
def test_corruptions_stay_in_range_and_differ():
    from repro.data.corruptions import CORRUPTIONS, corrupt_batch
    rng = np.random.default_rng(0)
    x = rng.random((4, 16, 16, 3)).astype(np.float32)
    for name, fn in CORRUPTIONS.items():
        y = fn(x.astype(np.float64), 3, rng)
        assert y.min() >= -1e-6 and y.max() <= 1 + 1e-6, name
    y = corrupt_batch(x, rng)
    assert y.shape == x.shape
    assert not np.allclose(y, x)


# -------------------------------------------------- paper-literal grid search
def test_grid_search_matches_quantile_method():
    from repro.core.interval import calibrate_alpha_beta, grid_search_alpha_beta
    rng = np.random.default_rng(0)
    u = rng.standard_normal(50_000)
    q = calibrate_alpha_beta(u, target_coverage=0.995)
    g = grid_search_alpha_beta(u, target_coverage=0.995)
    cov_q = np.mean((u >= -float(q.alpha)) & (u <= float(q.beta)))
    cov_g = np.mean((u >= -float(g.alpha)) & (u <= float(g.beta)))
    assert cov_q >= 0.993 and cov_g >= 0.995
    # quantile interval is never wider than the (coarse) grid pick
    assert float(q.alpha + q.beta) <= float(g.alpha + g.beta) + 0.3


# --------------------------------------------------------- analytic flops
def test_model_flops_sane():
    from repro.configs import get_config
    from repro.launch.model_flops import model_flops, param_counts
    cfg = get_config("yi-6b")
    counts = param_counts(cfg)
    assert 5.5e9 < counts["params_total"] < 7.5e9   # "yi-6b" really ~6B
    mf = model_flops(cfg, "train_4k")
    assert mf["total"] > 6 * counts["active"] * 256 * 4096 * 0.99
    # MoE: active < total
    cfg2 = get_config("deepseek-v2-236b")
    c2 = param_counts(cfg2)
    assert 2.0e11 < c2["params_total"] < 2.7e11      # ~236B
    assert c2["active"] < 0.2 * c2["params_total"]   # top-6 of 160


def test_dryrun_skip_rules():
    from repro.configs import get_config
    from repro.launch.dryrun import skip_reason
    assert skip_reason(get_config("yi-6b"), "long_500k") is not None
    assert skip_reason(get_config("mamba2-2.7b"), "long_500k") is None
    assert skip_reason(get_config("gemma3-12b"), "long_500k") is None
    assert skip_reason(get_config("yi-6b"), "train_4k") is None
