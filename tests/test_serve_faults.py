"""Fault-tolerant serving: request isolation, watchdogs, drain-and-resume.

The deterministic fault-injection harness (``distributed/fault.FaultPlan``)
drives every scenario from counters - scheduler round, protocol command
seq - never wall-clock, so each replay is exact:

  * a poisoned request (NaN logits, malformed prompt, raising launch)
    fails ALONE; its batch peers' token streams stay bit-exact vs a clean
    run (sampling keys derive from (uid, step), not batch composition);
  * a preempted run snapshots, and a fresh engine resumes it
    token-for-token equal to an uninterrupted run - same for a 2-process
    fleet whose worker is killed mid-decode;
  * a hung worker trips the coordinator's deadline watchdog: typed
    ABORT_DEADLINE exit (87) with the drain snapshot already on disk;
  * a corrupted command header is a typed ``ProtocolError``, not a hang;
  * an injected straggler delay is flagged in ``engine.stats`` within the
    EMA window;
  * the guarded PDQ path routes a poisoned projection to the fp-dequant
    fallback per launch, keeping requests finite.

Subprocess fleets ride the helpers in test_serve_multihost.py (ephemeral
port with EADDRINUSE retry, per-topology compilation-cache subdirs, hard
per-child timeouts).
"""
import collections
import dataclasses
import json
import os
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypo_compat import given, settings, strategies as st

from test_serve_multihost import _run, _spawn_fleet

from repro.configs import reduced_config
from repro.distributed.fault import (EXIT_DEADLINE, EXIT_KILLED,
                                     DeadlineWatchdog, FaultInjector,
                                     FaultPlan, StragglerWatchdog,
                                     load_snapshot, save_snapshot)
from repro.kernels import ops
from repro.models import build_model
from repro.models.linops import quantize_weight
from repro.serve import (CoordinatorAbort, MultiHostServeEngine,
                         ProtocolError, Request, ServeEngine, Telemetry,
                         resume_requests)
from repro.serve.multihost import ABORT_DEADLINE, CMD_ABORT


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced_config("stablelm-1.6b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _reqs(cfg, lens, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                    max_new=max_new) for i, L in enumerate(lens)]


def _toks(reqs):
    return {r.uid: tuple(r.generated) for r in reqs}


# ---------------------------------------------------------------------------
# Request isolation (single-process engine)
# ---------------------------------------------------------------------------


def test_nan_poisoned_prefill_fails_alone(small_model):
    """A request whose prefill logits carry NaN is failed + evicted; its
    batch peers (same prefill launch) are token-for-token unaffected."""
    cfg, m, params = small_model
    kw = dict(slots=4, max_len=64, temperature=0.7, rng=jax.random.PRNGKey(7))
    ref = _reqs(cfg, [4, 6, 5, 7])
    ServeEngine(cfg, params, **kw).run(ref)

    eng = ServeEngine(cfg, params, **kw,
                      fault=FaultPlan(nan_uid=1, nan_kind="prefill").injector())
    got = _reqs(cfg, [4, 6, 5, 7])
    eng.run(got)

    assert got[1].done and got[1].error == "non-finite logits at prefill"
    assert got[1].generated == []
    assert eng.stats["failed"] == 1
    assert eng.failures.count("nonfinite") == 1
    for uid in (0, 2, 3):                      # peers of the poisoned launch
        assert got[uid].error is None
        assert _toks(got)[uid] == _toks(ref)[uid]
    # the engine keeps serving after the eviction: freed slot is reusable
    late = _reqs(cfg, [5], max_new=3, seed=9)[0]
    late.uid = 99
    eng.run([late])
    assert late.done and late.error is None and len(late.generated) == 3


def test_nan_poisoned_decode_evicts_mid_stream(small_model):
    """NaN appearing at decode evicts that slot only; peers sharing the
    decode batch keep their exact streams."""
    cfg, m, params = small_model
    kw = dict(slots=4, max_len=64, temperature=0.7, rng=jax.random.PRNGKey(7))
    ref = _reqs(cfg, [4, 6, 5], max_new=8)
    ServeEngine(cfg, params, **kw).run(ref)

    eng = ServeEngine(cfg, params, **kw,
                      fault=FaultPlan(nan_uid=2, nan_kind="decode").injector())
    got = _reqs(cfg, [4, 6, 5], max_new=8)
    eng.run(got)

    assert got[2].done and got[2].error == "non-finite logits at decode"
    assert len(got[2].generated) == 1          # prefill token landed, then cut
    assert _toks(got)[2] == _toks(ref)[2][:1]  # ... and it matches the ref
    assert eng.failures.count("nonfinite") == 1
    for uid in (0, 1):
        assert got[uid].error is None
        assert _toks(got)[uid] == _toks(ref)[uid]


def test_malformed_prompt_fails_alone(small_model):
    """A structurally bad prompt fails at dequeue (kind='plan'); it never
    reaches a device launch and its co-submitted peers are unaffected."""
    cfg, m, params = small_model
    kw = dict(slots=4, max_len=64, temperature=0.7, rng=jax.random.PRNGKey(7))
    ref = _reqs(cfg, [4, 6, 5])
    ServeEngine(cfg, params, **kw).run(ref)

    eng = ServeEngine(cfg, params, **kw)
    got = _reqs(cfg, [4, 6, 5])
    bad = Request(uid=9, prompt=np.linspace(0.0, 1.0, 5), max_new=4)  # floats
    eng.run(got[:1] + [bad] + got[1:])

    assert bad.done and "malformed prompt" in bad.error
    assert bad.generated == []
    assert eng.failures.count("plan") == 1 and eng.stats["failed"] == 1
    assert _toks(got) == _toks(ref)


def test_raising_launch_fails_only_its_requests(small_model):
    """An exception inside one device launch fails that launch's requests
    and releases their slots; the engine keeps serving and later launches
    (including the SAME uids' peers) are exact."""
    cfg, m, params = small_model
    ref = _reqs(cfg, [4, 5, 6, 7], max_new=4)
    ServeEngine(cfg, params, slots=2, max_len=64).run(ref)

    plan = FaultPlan(raise_kind="prefill", raise_round=0)   # one-shot
    eng = ServeEngine(cfg, params, slots=2, max_len=64, fault=plan.injector())
    got = _reqs(cfg, [4, 5, 6, 7], max_new=4)
    eng.run(got)

    failed = [r for r in got if r.error]
    ok = [r for r in got if not r.error]
    assert len(failed) == 2                    # first admission group (2 slots)
    assert all("prefill launch failed" in r.error for r in failed)
    assert all("injected prefill launch fault" in r.error for r in failed)
    assert eng.stats["failed"] == 2 and eng.failures.count("exec") == 2
    assert len(ok) == 2
    for r in ok:
        assert _toks(got)[r.uid] == _toks(ref)[r.uid]


# ---------------------------------------------------------------------------
# Guarded PDQ -> fp-dequant fallback
# ---------------------------------------------------------------------------


def test_pdq_guard_passes_finite_results_through():
    """With the guard armed but the fast path healthy, pdq_dense output is
    bit-identical to the unguarded kernel."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 256), jnp.float32)
    rec = quantize_weight(
        jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32))
    fast = np.asarray(ops.pdq_dense(x, rec))
    with ops.pdq_guard():
        guarded = np.asarray(ops.pdq_dense(x, rec))
    np.testing.assert_array_equal(guarded, fast)


def test_pdq_fault_routes_to_fp_dequant_fallback():
    """A poisoned fast path makes the guard select the fp-dequant branch:
    the result equals the plain ``x @ (q * scale)`` reference exactly."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 256), jnp.float32)
    rec = quantize_weight(
        jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32))
    with ops.pdq_guard(), ops.pdq_fault():
        forced = np.asarray(ops.pdq_dense(x, rec))
    want = np.asarray(
        ops._fp_dequant_matmul(x, rec["q"], rec["scale"], jnp.float32))
    np.testing.assert_array_equal(forced, want)
    assert np.isfinite(forced).all()


def test_engine_pdq_fallback_survives_poisoned_kernels(small_model):
    """End-to-end: with every guarded projection's fast path poisoned, a
    pdq_fallback int8 engine still completes every request with finite
    logits (zero nonfinite evictions)."""
    cfg, m, params = small_model
    eng = ServeEngine(cfg, params, slots=2, max_len=64,
                      quantize_weights=True, pdq_fallback=True)
    reqs = _reqs(cfg, [4, 6], max_new=4)
    with ops.pdq_fault():             # trace-time: jits trace on first run
        eng.run(reqs)
    assert all(r.done and r.error is None for r in reqs)
    assert all(len(r.generated) == 4 for r in reqs)
    assert eng.stats["failed"] == 0


# ---------------------------------------------------------------------------
# Deadline watchdog + snapshot primitives
# ---------------------------------------------------------------------------


def test_deadline_watchdog_fires_cancels_and_disarms():
    fired = []
    with DeadlineWatchdog(0.05, reason="unit",
                          on_timeout=lambda r, s: fired.append((r, s))) as wd:
        time.sleep(0.4)
    assert wd.fired and fired == [("unit", 0.05)]

    with DeadlineWatchdog(5.0, on_timeout=lambda r, s: fired.append("no")) as wd:
        pass                                   # exits before expiry: cancelled
    time.sleep(0.1)
    assert not wd.fired and fired == [("unit", 0.05)]

    with DeadlineWatchdog(None, on_timeout=lambda r, s: fired.append("no")) as wd:
        assert wd._timer is None               # disarmed entirely
    assert not wd.fired


def test_snapshot_roundtrip_and_resume_clears_progress(tmp_path):
    snap = {
        "version": 1, "round": 5,
        "inflight": [{"uid": 2, "prompt": np.arange(4, dtype=np.int32),
                      "max_new": 8, "generated": [7, 9], "error": None}],
        "pending": [{"uid": 3, "prompt": np.arange(6, dtype=np.int32),
                     "max_new": 8, "generated": [], "error": None}],
        "finished": [{"uid": 1, "prompt": np.arange(3, dtype=np.int32),
                      "max_new": 2, "generated": [4, 4], "error": None}],
        "stats": {"completed": 1}, "failures": [],
    }
    path = os.path.join(tmp_path, "snap.npy")
    save_snapshot(path, snap)
    got = load_snapshot(path)
    assert got["version"] == 1 and got["round"] == 5
    np.testing.assert_array_equal(got["inflight"][0]["prompt"], np.arange(4))

    finished, todo = resume_requests(got)
    assert [r.uid for r in finished] == [1] and finished[0].done
    assert [r.uid for r in todo] == [2, 3]     # inflight first, then pending
    assert all(r.generated == [] and not r.done for r in todo)


def test_fault_plan_ships_over_json():
    """FaultPlan is the subprocess fixture format: asdict -> json -> init
    reproduces the plan (delay_rounds keys re-intified by the unpacker)."""
    plan = FaultPlan(nan_uid=3, kill_process=1, kill_at_seq=6,
                     delay_rounds={4: 5.0}, corrupt_header_at_seq=2)
    d = json.loads(json.dumps(dataclasses.asdict(plan)))
    d["delay_rounds"] = {int(k): v for k, v in d["delay_rounds"].items()}
    plan2 = FaultPlan(**d)
    assert plan2 == plan
    inj = plan2.injector()
    assert inj.exec_delay("decode", 4) == 5.0
    assert inj.exec_delay("decode", 3) == 0.0


# ---------------------------------------------------------------------------
# Heartbeat / typed protocol faults (no jax.distributed needed)
# ---------------------------------------------------------------------------


def _bare_mh(n_processes=2, process_id=0):
    eng = object.__new__(MultiHostServeEngine)
    eng.n_processes = n_processes
    eng.process_id = process_id
    eng.is_coordinator = process_id == 0
    # acks + per-process ingress counts + per-process launch-timing slots
    eng._hdr = 4 + 3 * n_processes
    eng._seq = 1
    eng._done_seq = 0
    eng._last_exec_us = 0
    eng._prev_kind = None
    eng.tel = Telemetry(enabled=False)
    eng._stopped = False
    eng._ingress_lock = threading.Lock()
    eng._out_q = collections.deque()
    eng._ingress_counts = [0] * n_processes
    eng._remote = {}
    eng._remote_seq = 1
    eng.fault = FaultInjector()
    return eng


def test_heartbeat_ack_mismatch_is_typed_desync():
    """The coordinator verifies every worker acked seq-1 on the command
    header exchange; a stale ack raises ProtocolError, a fresh one
    advances the stream."""
    eng = _bare_mh()

    def exchange(arrays, all_ranks=False):
        hdr = np.array(arrays[0], np.int32)
        hdr[4 + 1] = eng._seq - 1              # worker 1: correct heartbeat
        return [hdr]

    eng._broadcast = exchange
    eng._cmd(5)                                # seq 1 -> ok
    eng._cmd(5)                                # seq 2 -> ok
    assert eng._seq == 3

    def stale(arrays, all_ranks=False):
        hdr = np.array(arrays[0], np.int32)
        hdr[4 + 1] = 0                         # worker 1 stuck at seq 0
        return [hdr]

    eng._broadcast = stale
    with pytest.raises(ProtocolError, match="desynchronized"):
        eng._cmd(5)


def test_worker_refuses_to_drive_and_decodes_typed_abort():
    worker = _bare_mh(process_id=1)
    with pytest.raises(RuntimeError, match="worker"):
        worker._cmd(5)

    def abort(arrays, all_ranks=False):
        hdr = np.zeros_like(np.asarray(arrays[0], np.int32))
        hdr[0], hdr[1], hdr[2] = CMD_ABORT, ABORT_DEADLINE, 7
        return [hdr]

    worker._broadcast = abort
    with pytest.raises(CoordinatorAbort, match="deadline exceeded") as ei:
        worker._recv_cmd()
    assert ei.value.reason == ABORT_DEADLINE


# ---------------------------------------------------------------------------
# Straggler watchdog
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(warmup=st.integers(min_value=5, max_value=30),
       magnitude=st.floats(min_value=4.0, max_value=50.0),
       base=st.floats(min_value=1e-3, max_value=0.1))
def test_straggler_watchdog_flags_spikes_not_steady_state(warmup, magnitude,
                                                         base):
    """Any spike past factor x EMA after any warmup is flagged on THAT
    observation; a steady stream never flags."""
    wd = StragglerWatchdog()
    for _ in range(warmup):
        assert not wd.observe(base)
    assert wd.observe(base * magnitude)        # magnitude > factor (3.0)
    assert wd.flagged == 1

    steady = StragglerWatchdog()
    for _ in range(warmup + 1):
        steady.observe(base)
    assert steady.flagged == 0


def test_straggler_flag_surfaces_in_engine_stats(small_model):
    """An injected virtual decode delay (never actually slept) is flagged
    by the serving loop within the run and lands in stats + failure log."""
    cfg, m, params = small_model
    plan = FaultPlan(delay_rounds={6: 300.0})
    eng = ServeEngine(cfg, params, slots=2, max_len=64, fault=plan.injector())
    req = _reqs(cfg, [6], max_new=12)[0]
    eng.run([req])
    assert req.done and req.error is None
    assert eng.stats["straggler_flags"] >= 1
    assert eng.failures.count("straggler") >= 1
    detail = [e for e in eng.failures.events if e["kind"] == "straggler"]
    assert "EMA" in detail[0]["detail"]


def test_prefill_straggler_has_own_ema_and_event_kind(small_model):
    """Prefill launches feed their OWN watchdog: an injected virtual delay
    scoped to ``delay_kind='prefill'`` flags the prefill EMA (distinct
    'straggler_prefill' event kind) and never touches the decode EMA's
    flag count - the two streams have very different baselines, so one
    shared EMA would either mask prefill stragglers or false-flag every
    prefill after a decode-heavy stretch."""
    cfg, m, params = small_model
    # rounds 1-4 serve request 0's undelayed prefill + decode (warming the
    # prefill EMA); every LATER prefill is virtually 300s slow
    plan = FaultPlan(delay_rounds={r: 300.0 for r in range(5, 60)},
                     delay_kind="prefill")
    eng = ServeEngine(cfg, params, slots=1, max_len=64,
                      fault=plan.injector())
    assert eng.prefill_straggler is not eng.straggler   # independent EMAs
    reqs = _reqs(cfg, [5, 6, 4], max_new=4)
    eng.run(reqs)
    assert all(r.done and r.error is None for r in reqs)
    assert eng.stats["prefill_straggler_flags"] >= 1
    assert eng.failures.count("straggler_prefill") >= 1
    ev = [e for e in eng.failures.events if e["kind"] == "straggler_prefill"]
    assert "prefill launch" in ev[0]["detail"] and "EMA" in ev[0]["detail"]
    # the decode watchdog saw only real (undelayed) decode timings
    decode_flagged = [e for e in eng.failures.events
                      if e["kind"] == "straggler"]
    assert not any("300" in e["detail"].split("s >")[0]
                   for e in decode_flagged)


def test_chunked_launches_feed_the_prefill_straggler(small_model):
    """Chunked prefill launches ride the same prefill watchdog (they are
    the prefill path, just split), with the 'chunked' kind named in the
    flag detail."""
    cfg, m, params = small_model
    plan = FaultPlan(delay_rounds={r: 300.0 for r in range(3, 60)},
                     delay_kind="chunked")
    eng = ServeEngine(cfg, params, slots=2, max_len=64, buckets=(8, 16),
                      chunked_prefill=True, fault=plan.injector())
    reqs = _reqs(cfg, [20, 24, 18], max_new=4)
    eng.run(reqs)
    assert all(r.done and r.error is None for r in reqs)
    assert eng.stats["chunked_requests"] == 3
    assert eng.stats["prefill_straggler_flags"] >= 1
    ev = [e for e in eng.failures.events if e["kind"] == "straggler_prefill"]
    assert ev and "chunked launch" in ev[0]["detail"]


# ---------------------------------------------------------------------------
# Drain -> snapshot -> resume (single process)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_preempt_snapshot_resume_token_parity(small_model, tmp_path,
                                              temperature):
    """Preempt mid-serve, snapshot, resume on a FRESH engine: finished +
    regenerated streams are token-for-token the uninterrupted run, greedy
    and sampled (keys derive from (uid, step), not engine history)."""
    cfg, m, params = small_model
    kw = dict(slots=2, max_len=64, temperature=temperature,
              rng=jax.random.PRNGKey(3))
    lens = [4, 6, 9, 5, 7]
    ref = _reqs(cfg, lens, max_new=8)
    ServeEngine(cfg, params, **kw).run(ref)

    plan = FaultPlan(preempt_at_round=3)
    eng = ServeEngine(cfg, params, **kw, fault=plan.injector())
    eng.snapshot_path = os.path.join(tmp_path, f"snap{temperature}.npy")
    eng.run(_reqs(cfg, lens, max_new=8))
    assert eng.drained and os.path.exists(eng.snapshot_path)

    finished, todo = resume_requests(load_snapshot(eng.snapshot_path))
    assert todo                                # the preemption left real work
    eng2 = ServeEngine(cfg, params, **kw)      # fresh engine, no shared state
    eng2.run(todo)

    out = finished + todo
    assert {r.uid for r in out} == set(range(len(lens)))
    assert all(r.done and r.error is None for r in out)
    assert _toks(out) == _toks(ref)


# ---------------------------------------------------------------------------
# Multi-process fleets under injected faults (subprocess suite)
# ---------------------------------------------------------------------------
#
# 2 OS processes x 1 virtual CPU device each over a ('data','model') = 2x1
# mesh, temperature sampling, 20s launch deadlines.  The reference is the
# single-process ShardedServeEngine on the same logical mesh (the pinned
# multihost==sharded parity contract).

_FLEET = """
    import json
    import os
    import sys

    proc, port = int(sys.argv[1]), sys.argv[2]
    mode, out_path, snap_path = sys.argv[3], sys.argv[4], sys.argv[5]

    import jax
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(f"127.0.0.1:{port}", num_processes=2,
                               process_id=proc)
    import numpy as np
    from repro.configs import reduced_config
    from repro.distributed.fault import FaultPlan, load_snapshot
    from repro.launch.mesh import make_serve_mesh
    from repro.models import build_model
    from repro.serve import MultiHostServeEngine, Request, resume_requests

    assert jax.process_count() == 2
    cfg = reduced_config("stablelm-1.6b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    LENS = [3, 5, 8, 6, 4]

    def fresh_requests():
        rng = np.random.default_rng(0)
        return [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                        max_new=8) for i, L in enumerate(LENS)]

    plan = {"kill": FaultPlan(kill_process=1, kill_at_seq=6),
            "hang": FaultPlan(hang_process=1, hang_at_seq=5,
                              hang_seconds=600.0),
            "corrupt": FaultPlan(corrupt_header_at_seq=4),
            "resume": None}[mode]
    eng = MultiHostServeEngine(
        cfg, params, mesh=make_serve_mesh(2, 1), slots_per_replica=2,
        max_len=48, buckets=(8, 16), temperature=0.5,
        fault=None if plan is None else plan.injector(),
        launch_timeout=20.0,
        snapshot_path=snap_path if proc == 0 and snap_path != "-" else None)
    if proc == 0:
        if mode == "resume":
            finished, todo = resume_requests(load_snapshot(snap_path))
            eng.run(todo)
            eng.stop_workers()
            done = finished + todo
        else:
            done = fresh_requests()
            eng.run(done)
            eng.stop_workers()
        with open(out_path, "w") as f:
            json.dump({str(r.uid): [list(map(int, r.generated)), r.error]
                       for r in done}, f)
    else:
        eng.serve_worker()
    print("PROC", proc, "OK")
"""

_FLEET_REF = """
    import json
    import sys

    import jax
    import numpy as np
    from repro.configs import reduced_config
    from repro.launch.mesh import make_serve_mesh
    from repro.models import build_model
    from repro.serve import Request, ShardedServeEngine

    cfg = reduced_config("stablelm-1.6b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                    max_new=8) for i, L in enumerate([3, 5, 8, 6, 4])]
    eng = ShardedServeEngine(cfg, params, mesh=make_serve_mesh(2, 1),
                             slots_per_replica=2, max_len=48,
                             buckets=(8, 16), temperature=0.5)
    eng.run(reqs)
    assert all(r.done and r.error is None for r in reqs)
    with open(sys.argv[1], "w") as f:
        json.dump({str(r.uid): [list(map(int, r.generated)), r.error]
                   for r in reqs}, f)
    print("REF OK")
"""


def test_killed_worker_drains_and_fresh_fleet_resumes_token_exact():
    """Kill worker 1 mid-decode (injected os._exit at command seq 6): the
    coordinator dies typed-nonzero but persists the drain snapshot; a
    FRESH 2-process fleet resumes it and finished+resumed streams equal
    the uninterrupted single-process reference token-for-token."""
    with tempfile.TemporaryDirectory() as td:
        ref_path = os.path.join(td, "ref.json")
        ref = _run(_FLEET_REF, [ref_path], devices=2)
        assert ref.returncode == 0, ref.stderr[-3000:]

        snap = os.path.join(td, "snap.npy")
        procs, outs = _spawn_fleet(
            _FLEET, ["kill", os.path.join(td, "k.json"), snap],
            n_procs=2, devices=1)
        coord, worker = procs
        assert worker.returncode == EXIT_KILLED, outs[1][1][-2000:]
        assert "FAULT-INJECTION: killing process 1" in outs[1][1]
        # the coordinator loses the fleet either as a raised gloo error
        # (run()'s except path) or as a deadline abort - both nonzero,
        # both leave the snapshot behind
        assert coord.returncode not in (0, None), outs[0][1][-2000:]
        assert os.path.exists(snap), outs[0][1][-2000:]

        out_path = os.path.join(td, "resumed.json")
        procs, outs = _spawn_fleet(_FLEET, ["resume", out_path, snap],
                                   n_procs=2, devices=1)
        for p, (so, se) in zip(procs, outs):
            assert p.returncode == 0, (so[-1500:], se[-3000:])
        with open(out_path) as f:
            got = json.load(f)
        with open(ref_path) as f:
            want = json.load(f)
        assert got == want, {u: (got.get(u), want.get(u)) for u in want
                             if got.get(u) != want.get(u)}


def test_hung_worker_trips_deadline_watchdog():
    """Worker 1 sleeps inside the seq-5 header rendezvous: the
    coordinator's 20s deadline watchdog fires - typed ABORT_DEADLINE line,
    exit code 87, snapshot dumped from the side thread."""
    with tempfile.TemporaryDirectory() as td:
        snap = os.path.join(td, "snap.npy")
        procs, outs = _spawn_fleet(
            _FLEET, ["hang", os.path.join(td, "h.json"), snap],
            n_procs=2, devices=1, timeout=300, hang_ok=(1,))
        coord = procs[0]
        assert coord.returncode == EXIT_DEADLINE, outs[0][1][-3000:]
        assert "FATAL ABORT_DEADLINE" in outs[0][1]
        assert "FAULT-INJECTION: hanging process 1" in outs[1][1]
        assert os.path.exists(snap)


def test_corrupt_header_is_typed_protocol_error():
    """A corrupted command header (opcode 99 at seq 4) kills the worker
    with the typed ProtocolError message instead of a silent hang."""
    with tempfile.TemporaryDirectory() as td:
        procs, outs = _spawn_fleet(
            _FLEET, ["corrupt", os.path.join(td, "c.json"), "-"],
            n_procs=2, devices=1, timeout=300)
        coord, worker = procs
        assert worker.returncode not in (0, None), outs[1][1][-2000:]
        assert "unknown multi-host serve opcode 99" in outs[1][1]
        assert coord.returncode not in (0, None), outs[0][1][-2000:]


def test_extras_protocol_validation_is_typed():
    """Unsupported extras are typed ProtocolErrors raised at the entry
    point, BEFORE any command is issued (raising mid-admission would
    desync the fleet or leak a planned slot)."""
    eng = _bare_mh()
    eng.chunked_prefill = False
    eng.buckets = (8, 16)
    ok = {"patches": np.zeros((1, 4, 8), np.float32)}
    eng._validate_extras(5, ok)               # known key, float, 1..4 dims
    with pytest.raises(ProtocolError, match="not part of the multi-host"):
        eng._validate_extras(5, {"bogus": np.zeros((1, 2), np.float32)})
    with pytest.raises(ProtocolError, match="not a float type"):
        eng._validate_extras(5, {"frames": np.zeros((1, 2), np.int32)})
    with pytest.raises(ProtocolError, match="shape-tag"):
        eng._validate_extras(
            5, {"frames": np.zeros((1, 2, 3, 4, 5), np.float32)})
    eng.chunked_prefill = True                # oversized + extras: refused
    with pytest.raises(ProtocolError, match="chunked-prefill"):
        eng._validate_extras(40, ok)
    eng._validate_extras(5, ok)               # in-bucket prompt still fine


def test_worker_ingress_counts_ride_the_header_exchange():
    """submit_remote() queues locally under a fleet-unique namespaced uid;
    the queue LENGTH piggybacks on the very next header exchange (slot
    4+N+pid), and the coordinator harvests it from any command."""
    worker = _bare_mh(process_id=1)
    u1 = worker.submit_remote(np.array([3, 1], np.int32), max_new=4)
    u2 = worker.submit_remote(np.array([2], np.int32), max_new=2,
                              deadline_ms=50)
    assert (u1, u2) == ((1 << 20) | 1, (1 << 20) | 2)
    shipped = {}

    def exchange(arrays, all_ranks=False, src=0):
        hdr = np.array(arrays[0], np.int32)
        shipped["hdr"] = hdr.copy()
        hdr[0] = 8                            # coordinator sent CMD_POLL
        return [hdr]

    worker._broadcast = exchange
    op, arg, seq, n_ex = worker._recv_cmd()
    assert op == 8 and n_ex == 0
    assert shipped["hdr"][4 + 2 + 1] == 2     # 2 queued submits announced

    coord = _bare_mh()

    def cexchange(arrays, all_ranks=False, src=0):
        hdr = np.array(arrays[0], np.int32)
        hdr[4 + 1] = coord._seq - 1           # worker heartbeat in order
        hdr[4 + 2 + 1] = 2                    # ... announcing 2 queued
        return [hdr]

    coord._broadcast = cexchange
    coord._cmd(8)
    assert coord._ingress_counts == [0, 2]
