"""Pytest bootstrap: bare ``pytest`` does not prepend the cwd to sys.path
(``python -m pytest`` does), so make the repo root importable for
cross-test helpers (e.g. tests.test_hlo_and_linops._count_pallas_calls)
and ``src`` importable so PYTHONPATH=src is optional."""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)
