"""ShardedServeEngine: mesh-distributed serving tests.

Pins the PR-4 contract: a ('data', 'model') mesh engine produces
token-for-token greedy parity with the single-device engine (slots
data-parallel, PDQ/fp projection columns tensor-parallel with an
all-gather epilogue), the sharded decode step stays on the grouped
8-kernel path per replica, and the coordinator routes admits to the
least-loaded replicas.

Multi-device cases run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main test
process keeps its single-device view (same pattern as
tests/test_distributed.py).
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import serve_pool_specs


def _run_subprocess(code: str, devices: int = 8):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ------------------------------------------------------------- spec helper
def test_serve_pool_specs_slot_axis_layout():
    """Slot axis shards over 'data': axis 0 on head/tail leaves, axis 1 on
    lax.scan-stacked block leaves; nothing else is sharded."""
    caches = {
        "head": ({"k": jnp.zeros((8, 32, 2, 16)), "len": jnp.zeros((8,))},),
        "tail": (),
        "blocks": ({"k": jnp.zeros((6, 8, 32, 2, 16)),
                    "state": jnp.zeros((6, 8, 4, 16, 8))},),
    }
    specs = serve_pool_specs(caches)
    assert specs["head"][0]["k"] == P("data", None, None, None)
    assert specs["head"][0]["len"] == P("data")
    assert specs["blocks"][0]["k"] == P(None, "data", None, None, None)
    assert specs["blocks"][0]["state"] == P(None, "data", None, None, None)


# ----------------------------------------------------- mesh parity (greedy)
def _parity_case(body: str) -> str:
    """Prelude + test body, each dedented on its own (their indents differ)."""
    return textwrap.dedent(_PARITY_PRELUDE) + textwrap.dedent(body)


_PARITY_PRELUDE = """
    import jax
    import numpy as np
    from repro.configs import reduced_config
    from repro.models import build_model
    from repro.serve import Request, ServeEngine, ShardedServeEngine

    def requests(cfg, lens, max_new=4, seed=0):
        rng = np.random.default_rng(seed)
        return [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                        max_new=max_new) for i, L in enumerate(lens)]

    def outputs(eng, cfg, lens, max_new=4):
        reqs = requests(cfg, lens, max_new)
        eng.run(reqs)
        assert all(r.done for r in reqs)
        return [tuple(r.generated) for r in reqs]
"""


def test_sharded_matches_single_device_mixed_trace():
    """Acceptance pin: a data=4 x model=2 mesh engine (2 slots/replica)
    serves the 12-request mixed-length trace token-for-token identically
    to the single-device engine, admission routes to the least-loaded
    replicas, and the compile counts stay bucket-bounded."""
    out = _run_subprocess(_parity_case("""
        MIXED = [3, 5, 8, 9, 12, 16, 17, 23, 30, 4, 11, 27]
        cfg = reduced_config("stablelm-1.6b")
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        ref = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16, 32))
        want = outputs(ref, cfg, MIXED, max_new=6)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        eng = ShardedServeEngine(cfg, params, mesh=mesh, slots_per_replica=2,
                                 max_len=64, buckets=(8, 16, 32))
        got = outputs(eng, cfg, MIXED, max_new=6)
        assert got == want, [i for i, (a, b) in enumerate(zip(got, want))
                             if a != b]
        # coordinator accounting: every admit counted on some replica, and
        # the least-loaded routing spreads the trace across all replicas
        assert sum(eng.stats["replica_admits"]) == len(MIXED)
        assert min(eng.stats["replica_admits"]) >= 1
        assert eng.stats["replica_occupancy"] == [0, 0, 0, 0]   # drained
        assert eng.stats["prefill_compiles"] <= len(eng.buckets)
        assert eng.stats["decode_compiles"] == 1
        print("OK")
    """))
    assert "OK" in out


def test_sharded_parity_other_families():
    """SSM recurrent state and the MLA compressed cache survive the mesh:
    greedy decode equality on a 2x2 mesh for mamba2 and deepseek."""
    out = _run_subprocess(_parity_case("""
        for arch in ("mamba2-2.7b", "deepseek-v2-236b"):
            cfg = reduced_config(arch)
            params = build_model(cfg).init(jax.random.PRNGKey(0))
            lens = [3, 7, 11, 16, 5, 9]
            ref = ServeEngine(cfg, params, slots=2, max_len=48, buckets=(8, 16))
            want = outputs(ref, cfg, lens)
            mesh = jax.make_mesh((2, 2), ("data", "model"))
            eng = ShardedServeEngine(cfg, params, mesh=mesh,
                                     slots_per_replica=2, max_len=48,
                                     buckets=(8, 16))
            got = outputs(eng, cfg, lens)
            assert got == want, (arch, got, want)
            print("OK", arch)
        print("OK")
    """))
    assert "OK mamba2-2.7b" in out and "OK deepseek-v2-236b" in out


def test_sharded_quantized_and_chunked_parity():
    """The PDQ-int8 weight path (column-split W8A8 + all-gather epilogue)
    and chunked prefill both stay token-for-token exact on the mesh."""
    out = _run_subprocess(_parity_case("""
        cfg = reduced_config("stablelm-1.6b")
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        mesh = jax.make_mesh((2, 2), ("data", "model"))

        lens = [3, 9, 14, 6, 12, 30]
        ref = ServeEngine(cfg, params, slots=2, max_len=64, buckets=(8, 16, 32),
                          quantize_weights=True)
        want = outputs(ref, cfg, lens)
        eng = ShardedServeEngine(cfg, params, mesh=mesh, slots_per_replica=2,
                                 max_len=64, buckets=(8, 16, 32),
                                 quantize_weights=True)
        got = outputs(eng, cfg, lens)
        assert got == want, (got, want)
        print("OK int8")

        lens = [4, 20, 40, 11]          # 20/40 exceed the largest bucket
        ref = ServeEngine(cfg, params, slots=2, max_len=64, buckets=(8, 16),
                          chunked_prefill=True)
        want = outputs(ref, cfg, lens)
        eng = ShardedServeEngine(cfg, params, mesh=mesh, slots_per_replica=2,
                                 max_len=64, buckets=(8, 16),
                                 chunked_prefill=True)
        got = outputs(eng, cfg, lens)
        assert got == want, (got, want)
        assert eng.stats["chunked_requests"] == 2
        print("OK chunked")
    """))
    assert "OK int8" in out and "OK chunked" in out


def test_sharded_paged_pool_parity_and_preemption():
    """The paged KV pool on a 4x2 mesh: replica-local page tables ride
    the decode plan into ONE shard_map-ed gather/step/writeback launch,
    and a pool small enough to force mid-decode growth preempts + requeues
    with (uid, step)-keyed regeneration - both token-for-token equal to
    the single-device slot-row engine."""
    out = _run_subprocess(_parity_case("""
        MIXED = [3, 5, 8, 9, 12, 16, 17, 23, 30]
        cfg = reduced_config("stablelm-1.6b")
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        mesh = jax.make_mesh((4, 2), ("data", "model"))

        ref = ServeEngine(cfg, params, slots=8, max_len=64,
                          buckets=(8, 16, 32), temperature=0.9)
        want = outputs(ref, cfg, MIXED, max_new=6)
        eng = ShardedServeEngine(cfg, params, mesh=mesh, slots_per_replica=2,
                                 max_len=64, buckets=(8, 16, 32),
                                 temperature=0.9, paged=True, page_size=16)
        got = outputs(eng, cfg, MIXED, max_new=6)
        assert got == want, [i for i, (a, b) in enumerate(zip(got, want))
                             if a != b]
        print("OK paged parity")

        # 17-token prompts claim 2 pages each, max_new=30 forces a 3rd
        # mid-decode; 5 usable pages/replica under 2 slots -> preemption
        def grow():
            rng = np.random.default_rng(7)
            return [Request(uid=50 + i,
                            prompt=rng.integers(1, 200, 17).astype(np.int32),
                            max_new=30) for i in range(8)]
        ref2 = ServeEngine(cfg, params, slots=8, max_len=64,
                           buckets=(8, 16, 32), temperature=0.9)
        g0 = grow(); ref2.run(g0)
        eng2 = ShardedServeEngine(cfg, params, mesh=mesh, slots_per_replica=2,
                                  max_len=64, buckets=(8, 16, 32),
                                  temperature=0.9, paged=True, page_size=16,
                                  pool_pages=6)
        g1 = grow(); eng2.run(g1)
        assert ([tuple(r.generated) for r in g1]
                == [tuple(r.generated) for r in g0])
        assert eng2.stats["preemptions"] > 0
        print("OK paged preempt", eng2.stats["preemptions"])
    """))
    assert "OK paged parity" in out and "OK paged preempt" in out


# --------------------------------------------------------- kernel-count pin
def test_sharded_decode_block_is_eight_kernels_per_replica():
    """A quantized GQA block inside the shard_map body (TP over 'model')
    must still trace to the grouped 8 pallas_calls per replica: the
    column-split rides INSIDE the one-prologue-one-matmul pipeline (slice
    + all-gather add no kernel launches).  Under TP the fused SwiGLU MLP
    (7-launch single-device census, tools/check_census.py) intentionally
    falls back to the unfused 4-launch composition: each shard owns an
    N-slice of BOTH gate and up segments, but w_down's prologue needs the
    full silu(g)*u row, which only exists after the all-gather."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.kernels import ops
        from repro.models.attention import AttnDims, gqa_apply, gqa_init, init_cache
        from repro.models.context import shard_map
        from repro.models.layers import mlp_apply, mlp_init, rms_norm
        from repro.models.linops import quantize_param_tree
        from tests.test_hlo_and_linops import _count_pallas_calls

        dims = AttnDims(d_model=256, n_heads=4, n_kv_heads=2, head_dim=64)
        key = jax.random.PRNGKey(0)
        params = {"attn": gqa_init(key, dims, jnp.float32),
                  "attn_norm": jnp.zeros((256,)),
                  "ffn_norm": jnp.zeros((256,)),
                  "ffn": mlp_init(jax.random.fold_in(key, 1), 256, 512,
                                  jnp.float32)}
        qp = quantize_param_tree(params)
        cache = init_cache(dims, 8, 64, jnp.float32)

        def block(p, h, cache, positions):
            a, cache = gqa_apply(p["attn"], dims,
                                 rms_norm(h, p["attn_norm"]), positions,
                                 mode="decode", cache=cache)
            h = h + a
            return h + mlp_apply(p["ffn"], rms_norm(h, p["ffn_norm"])), cache

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cs = jax.tree.map(lambda c: P(*(("data",) + (None,) * (c.ndim - 1))),
                          cache)

        def sharded(p, h, cache, positions):
            def body(p, h, cache, positions):
                with ops.tp_shard("model", 2):
                    return block(p, h, cache, positions)
            return shard_map(body, mesh=mesh,
                             in_specs=(P(), P("data"), cs, P("data")),
                             out_specs=(P("data"), cs))(p, h, cache, positions)

        h = jnp.ones((8, 1, 256))
        pos = jnp.zeros((8, 1), jnp.int32) + 3
        ops.set_impl("kernel")
        try:
            jaxpr = jax.make_jaxpr(sharded)(qp, h, cache, pos)
        finally:
            ops.set_impl("auto")
        n = _count_pallas_calls(jaxpr)
        assert n == 8, f"expected 8 pallas_calls per sharded decode block, got {n}"
        print("OK", n)
    """)
    assert "OK 8" in out


# --------------------------------------------- service eviction isolation
def test_sharded_service_cancel_and_deadline_evict_in_isolation():
    """The streaming service over a mesh engine (PR-7): a mid-flight
    cancel and a round-clock deadline each evict exactly their own
    request - every other stream stays token-for-token equal to the
    single-device batch run, and all replica slots come back."""
    out = _run_subprocess(_parity_case("""
        import time
        from repro.serve import ServeService

        cfg = reduced_config("stablelm-1.6b")
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        lens = [3, 9, 12, 5, 17, 7]
        ref = ServeEngine(cfg, params, slots=4, max_len=64,
                          buckets=(8, 16, 32))
        refs = requests(cfg, lens, max_new=16)
        ref.run(refs)
        want = {r.uid: tuple(r.generated) for r in refs}

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        eng = ShardedServeEngine(cfg, params, mesh=mesh, slots_per_replica=1,
                                 max_len=64, buckets=(8, 16, 32))
        eng._clock = lambda: float(eng._round)   # deadlines in rounds
        svc = ServeService(eng, max_pending=16).start()
        prompts = [r.prompt for r in requests(cfg, lens, max_new=16)]
        streams = [svc.submit(p, max_new=16,
                              deadline_s=(4.0 if i == 2 else None))
                   for i, p in enumerate(prompts)]
        got1 = []
        while len(got1) < 2:                     # uid 1: cancel mid-flight
            got1.extend(streams[1].drain()[0])
            time.sleep(0.005)
        svc.cancel(1, reason="client gone")
        res = {s.uid: s.result(timeout=600) for s in streams}
        svc.stop()

        toks, fin, err = res[1]
        assert fin == "cancel" and err == "client gone"
        all1 = tuple(got1) + tuple(toks)
        assert all1 == want[1][:len(all1)] and len(all1) < 16
        toks2, fin2, err2 = res[2]
        assert fin2 == "deadline" and len(toks2) < 16
        assert tuple(toks2) == want[2][:len(toks2)]
        for uid in (0, 3, 4, 5):                 # untouched peers: exact
            toks, fin, _ = res[uid]
            assert fin == "complete" and tuple(toks) == want[uid], uid
        assert eng.stats["cancelled"] == 1
        assert eng.stats["deadline_expired"] == 1
        assert eng.stats["replica_occupancy"] == [0, 0, 0, 0]
        assert eng._free_total() == eng.slots
        print("OK")
    """))
    assert "OK" in out


# ------------------------------------------------- N-step decode fast path
def test_sharded_nstep_decode_matches_single_step():
    """decode_steps=4 on a 4x2 mesh: N decode steps per dispatch run
    inside one shard_map-ed scan (per-replica in-body sampling, one
    (slots, N) backhaul) and must equal the single-device N=1 engine
    token-for-token - fp and int8-KV, greedy and temperature, slot-row
    and paged."""
    out = _run_subprocess(_parity_case("""
        import dataclasses
        MIXED = [3, 5, 8, 9, 12, 16, 17, 23, 30, 4, 11, 27]
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cells = [("fp greedy slotrow", None, 0.0, {}),
                 ("fp temp paged", None, 0.9,
                  dict(paged=True, page_size=16)),
                 ("int8 temp paged", "dynamic", 0.9,
                  dict(paged=True, page_size=16))]
        for name, qkv, temp, kw in cells:
            cfg = reduced_config("stablelm-1.6b")
            if qkv:
                cfg = dataclasses.replace(cfg, quant_kv=qkv)
            params = build_model(cfg).init(jax.random.PRNGKey(0))
            ref = ServeEngine(cfg, params, slots=4, max_len=64,
                              buckets=(8, 16, 32), temperature=temp)
            want = outputs(ref, cfg, MIXED, max_new=9)
            eng = ShardedServeEngine(cfg, params, mesh=mesh,
                                     slots_per_replica=2, max_len=64,
                                     buckets=(8, 16, 32), temperature=temp,
                                     decode_steps=4, **kw)
            got = outputs(eng, cfg, MIXED, max_new=9)
            assert got == want, (name, [i for i, (a, b) in
                                        enumerate(zip(got, want)) if a != b])
            assert eng.stats["decode_compiles"] == 1
            # full blocks: dispatches-per-token is exactly 1/4 (two
            # admission waves of lockstep rows, 8 decode tokens each ->
            # 2 dispatches per wave)
            assert eng.stats["decode_tokens"] == len(MIXED) * 8
            assert eng.stats["decode_steps"] == 4
            print("OK", name)
        print("OK all")
    """))
    assert "OK all" in out
