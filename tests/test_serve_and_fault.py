"""Serving engine + fault-tolerant trainer behaviour tests."""
import os

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data import DataConfig
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.serve import Request, ServeEngine
from repro.train import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced_config("stablelm-1.6b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def test_continuous_batching_slot_reuse(small_model):
    cfg, m, params = small_model
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    reqs = [Request(uid=i, prompt=np.arange(4 + i) % cfg.vocab, max_new=5)
            for i in range(5)]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 5 for r in reqs)


def test_int8_weights_match_fp_greedy(small_model):
    cfg, m, params = small_model
    outs = {}
    for tag, q in (("fp", False), ("int8", True)):
        eng = ServeEngine(cfg, params, slots=2, max_len=64, quantize_weights=q)
        reqs = [Request(uid=i, prompt=np.arange(6) % cfg.vocab, max_new=8)
                for i in range(2)]
        eng.run(reqs)
        outs[tag] = [tuple(r.generated) for r in reqs]
    agree = np.mean([a == b for a, b in zip(outs["fp"], outs["int8"])])
    assert agree >= 0.5, outs     # PDQ-int8 greedy should mostly match fp


def test_trainer_restarts_and_recovers(tmp_path, small_model):
    cfg, m, _ = small_model
    boom = {"armed": True}

    def failure_hook(step):
        if step == 7 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected preemption")

    tr = Trainer(m, AdamWConfig(lr=1e-3),
                 DataConfig(vocab=cfg.vocab, seq_len=16, batch=2),
                 TrainerConfig(total_steps=12, ckpt_every=5,
                               ckpt_dir=os.path.join(tmp_path, "ck"),
                               log_every=4),
                 failure_hook=failure_hook)
    out = tr.train()
    assert out["restarts"] == 1
    assert out["history"][-1]["step"] == 12
    # checkpoint from before the failure was used: steps replayed exactly
    assert tr.ckpt.latest_step() == 12


def test_trainer_gives_up_after_max_restarts(tmp_path, small_model):
    cfg, m, _ = small_model

    def always_fail(step):
        raise RuntimeError("hard failure")

    tr = Trainer(m, AdamWConfig(),
                 DataConfig(vocab=cfg.vocab, seq_len=16, batch=2),
                 TrainerConfig(total_steps=5, ckpt_every=100,
                               ckpt_dir=os.path.join(tmp_path, "ck2"),
                               max_restarts=2),
                 failure_hook=always_fail)
    with pytest.raises(RuntimeError, match="max_restarts"):
        tr.train()


def test_resume_from_checkpoint_is_exact(tmp_path, small_model):
    """Stop at 10 steps, resume to 20 == one uninterrupted 20-step run."""
    cfg, m, _ = small_model
    data = DataConfig(vocab=cfg.vocab, seq_len=16, batch=2, seed=3)
    opt = AdamWConfig(lr=1e-3)

    t1 = Trainer(m, opt, data, TrainerConfig(
        total_steps=10, ckpt_every=10, ckpt_dir=os.path.join(tmp_path, "a"),
        log_every=10))
    t1.train()
    t2 = Trainer(m, opt, data, TrainerConfig(
        total_steps=20, ckpt_every=10, ckpt_dir=os.path.join(tmp_path, "a"),
        log_every=10))
    out_resumed = t2.train()

    t3 = Trainer(m, opt, data, TrainerConfig(
        total_steps=20, ckpt_every=20, ckpt_dir=os.path.join(tmp_path, "b"),
        log_every=10))
    out_straight = t3.train()
    np.testing.assert_allclose(out_resumed["final_loss"],
                               out_straight["final_loss"], rtol=2e-3)
