"""Multi-step fused decode (``decode_steps=N``): N-vs-1 parity + edges.

Pins the decode fast-path contract: with ``decode_steps=N`` the engine
runs N decode steps per host dispatch inside one ``lax.scan`` (cache
state stays on device between steps) and backhauls one ``(slots, N)``
token block - and this must never change a single token.  Every test
here compares against the same engine at ``N=1`` (itself pinned against
the pre-fast-path engine by the rest of the suite):

  * the parity matrix: N in {4, 16} x {slot-row, paged} x {greedy,
    temperature}, plus the int8 kernel-layout KV cache;
  * dispatch accounting: host dispatches per token is deterministically
    1/N (``stats["decode_steps"]`` counts dispatches,
    ``stats["decode_tokens"]`` consumed tokens), including non-divisible
    ``max_new`` and cache-headroom-capped tail blocks;
  * lifecycle edges quantize to dispatch boundaries: cancel/disconnect
    landing while a block is IN FLIGHT drops that whole block (the row's
    stream ends on the previous dispatch boundary), deadlines sweep at
    round boundaries so the delivered length is 1 + k*N, and in every
    case peers stay bit-exact;
  * preemption under pool pressure and drain -> snapshot -> resume
    regenerate token-exactly at N>1 ((uid, step) sampling keys are
    dispatch-shape-independent);
  * intra-round prefix sharing: identical prompts admitted in the SAME
    round share prompt pages (eager registration in ``_claim_pages``).
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.distributed.fault import FaultPlan, load_snapshot
from repro.models import build_model
from repro.serve import Request, ServeEngine, resume_requests

MIXED_LENS = [3, 5, 8, 9, 12, 16, 17, 23, 30, 4, 11, 27]

_MODELS = {}


def _model(quant_kv=None):
    if quant_kv not in _MODELS:
        cfg = reduced_config("stablelm-1.6b")
        if quant_kv:
            cfg = dataclasses.replace(cfg, quant_kv=quant_kv)
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        _MODELS[quant_kv] = (cfg, params)
    return _MODELS[quant_kv]


def _requests(cfg, lens, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                    max_new=max_new) for i, L in enumerate(lens)]


def _outputs(reqs):
    return {r.uid: (tuple(r.generated), r.finish_reason, r.error)
            for r in reqs}


def _run(cfg, params, lens, *, max_new=6, seed=0, **kw):
    eng = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16, 32),
                      **kw)
    reqs = _requests(cfg, lens, max_new=max_new, seed=seed)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    return eng, _outputs(reqs)


_REFS = {}


def _ref(temperature):
    """N=1 reference outputs for the mixed trace, cached per temperature."""
    if temperature not in _REFS:
        cfg, params = _model()
        _, out = _run(cfg, params, MIXED_LENS, temperature=temperature)
        _REFS[temperature] = out
    return _REFS[temperature]


# ---------------------------------------------------------------------------
# parity matrix: N-step == single-step, token for token
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.9])
@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("n", [4, 16])
def test_nstep_matches_single_step(n, paged, temperature):
    cfg, params = _model()
    kw = dict(paged=True, page_size=16) if paged else {}
    eng, got = _run(cfg, params, MIXED_LENS, decode_steps=n,
                    temperature=temperature, **kw)
    assert got == _ref(temperature)
    # the fused block is one program: still exactly one decode compile
    assert eng.stats["decode_compiles"] == 1


@pytest.mark.parametrize("temperature", [0.0, 0.9])
def test_nstep_matches_single_step_int8_kv(temperature):
    cfg, params = _model("dynamic")
    _, want = _run(cfg, params, MIXED_LENS, temperature=temperature)
    _, got = _run(cfg, params, MIXED_LENS, decode_steps=4,
                  temperature=temperature, paged=True, page_size=16)
    assert got == want


# ---------------------------------------------------------------------------
# dispatch accounting: host dispatches per token == 1/N, deterministically
# ---------------------------------------------------------------------------


def test_dispatches_per_token_is_one_over_n():
    """Solo row, max_new=33: prefill emits token 0, decode consumes the
    other 32.  At N=4 that is exactly 8 full-block dispatches."""
    cfg, params = _model()
    eng, out = _run(cfg, params, [5], max_new=33, decode_steps=4)
    assert eng.stats["decode_steps"] == 8
    assert eng.stats["decode_tokens"] == 32
    eng1, out1 = _run(cfg, params, [5], max_new=33)
    assert eng1.stats["decode_steps"] == 32
    assert out == out1


def test_non_divisible_budget_runs_partial_tail_block():
    """max_new=6 -> 5 decode tokens: one full block of 4 then a tail
    dispatch with a 1-step budget (rows beyond it are DECODE_PAD)."""
    cfg, params = _model()
    eng, out = _run(cfg, params, [5], max_new=6, decode_steps=4)
    assert eng.stats["decode_steps"] == 2
    assert eng.stats["decode_tokens"] == 5
    _, out1 = _run(cfg, params, [5], max_new=6)
    assert out == out1


def test_cache_headroom_caps_block_budget():
    """A row nearing max_len gets its per-row step budget capped by the
    cache headroom (last writable position max_len - 2), completes early
    without ever writing past the cache, and stays token-exact."""
    cfg, params = _model()

    def run(**kw):
        eng = ServeEngine(cfg, params, slots=4, max_len=40,
                          buckets=(8, 16, 32), **kw)
        reqs = _requests(cfg, [30, 5], max_new=30)
        eng.run(reqs)
        assert len(reqs[0].generated) < 30     # the cache, not max_new
        return _outputs(reqs)

    assert run(decode_steps=4) == run()


# ---------------------------------------------------------------------------
# lifecycle edges quantize to dispatch boundaries
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["cancel", "disconnect"])
def test_cancel_mid_block_drops_in_flight_block(kind):
    """A cancel landing while dispatch k's block is IN FLIGHT frees the
    slot before apply, so that whole block is dropped: the victim's
    stream ends on the previous dispatch boundary (1 prefill + (k-1)*N
    tokens) and peers are bit-exact."""
    cfg, params = _model()
    _, want = _run(cfg, params, [5, 9, 7], max_new=12)

    eng = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16, 32),
                      decode_steps=4)
    orig = eng._exec_decode
    calls = []

    def exec_then_cancel(plan):
        res = orig(plan)
        calls.append(plan)
        if len(calls) == 2:
            assert eng.cancel(1, kind=kind, reason="client gone")
        return res

    eng._exec_decode = exec_then_cancel
    reqs = _requests(cfg, [5, 9, 7], max_new=12)
    eng.run(reqs)
    assert reqs[1].done and reqs[1].finish_reason == kind
    assert len(reqs[1].generated) == 1 + 4          # block 2 dropped whole
    assert tuple(reqs[1].generated) == want[1][0][:5]
    for uid in (0, 2):
        assert _outputs([reqs[uid]])[uid] == want[uid]
    assert eng.stats["cancelled"] == 1
    assert eng._free_total() == eng.slots


def test_deadline_expiry_quantizes_to_dispatch_boundary():
    """On the deterministic round clock, the deadline sweep runs between
    dispatches: the victim's delivered length is 1 + k*N, a prefix of the
    uninterrupted stream, and peers are untouched."""
    cfg, params = _model()
    _, want = _run(cfg, params, [5, 9, 7], max_new=20)

    eng = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16, 32),
                      decode_steps=4)
    eng._clock = lambda: float(eng._round)          # rounds, not wall time
    reqs = [Request(uid=i, prompt=r.prompt, max_new=20,
                    deadline=(3.0 if i == 1 else None))
            for i, r in enumerate(_requests(cfg, [5, 9, 7], max_new=20))]
    eng.run(reqs)
    assert reqs[1].done and reqs[1].finish_reason == "deadline"
    n = len(reqs[1].generated)
    assert 0 < n < 20 and (n - 1) % 4 == 0          # dispatch-quantized
    assert tuple(reqs[1].generated) == want[1][0][:n]
    for uid in (0, 2):
        assert _outputs([reqs[uid]])[uid] == want[uid]
    assert eng.stats["deadline_expired"] == 1
    assert eng._free_total() == eng.slots


# ---------------------------------------------------------------------------
# preemption / snapshot-resume at N>1
# ---------------------------------------------------------------------------


def _grow_reqs():
    # 17-token prompts claim 2 pages; max_new=30 forces a 3rd page
    # mid-decode, colliding in a 6-usable-page pool with 3 live rows
    rng = np.random.default_rng(7)
    return [Request(uid=50 + i,
                    prompt=rng.integers(1, 200, size=17).astype(np.int32),
                    max_new=30) for i in range(4)]


def test_preempt_and_requeue_token_exact_at_n4():
    """Pool pressure with whole N-step page windows pre-allocated: the
    preempt-and-requeue path still regenerates the evicted rows exactly
    ((uid, step) keys do not see dispatch shapes)."""
    cfg, params = _model()
    ref = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16, 32),
                      temperature=0.9)
    want_reqs = _grow_reqs()
    ref.run(want_reqs)

    eng = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16, 32),
                      temperature=0.9, paged=True, page_size=16,
                      pool_pages=7, decode_steps=4)
    reqs = _grow_reqs()
    eng.run(reqs)
    assert _outputs(reqs) == _outputs(want_reqs)
    assert eng.stats["preemptions"] > 0


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_snapshot_resume_token_parity_at_n4(tmp_path, temperature):
    """Preempt mid-serve at N=4, snapshot, resume on a FRESH N=4 engine:
    finished + regenerated streams match the uninterrupted N=1 run."""
    cfg, params = _model()
    kw = dict(slots=2, max_len=64, temperature=temperature,
              rng=jax.random.PRNGKey(3))
    lens = [4, 6, 9, 5, 7]
    ref = _requests(cfg, lens, max_new=8)
    ServeEngine(cfg, params, **kw).run(ref)

    plan = FaultPlan(preempt_at_round=3)
    eng = ServeEngine(cfg, params, **kw, decode_steps=4,
                      fault=plan.injector())
    eng.snapshot_path = os.path.join(tmp_path, f"snap{temperature}.npy")
    eng.run(_requests(cfg, lens, max_new=8))
    assert eng.drained and os.path.exists(eng.snapshot_path)

    finished, todo = resume_requests(load_snapshot(eng.snapshot_path))
    assert todo                                # the preemption left work
    eng2 = ServeEngine(cfg, params, **kw, decode_steps=4)
    eng2.run(todo)

    out = finished + todo
    assert {r.uid for r in out} == set(range(len(lens)))
    assert all(r.done and r.error is None for r in out)
    assert ({r.uid: tuple(r.generated) for r in out}
            == {r.uid: tuple(r.generated) for r in ref})


# ---------------------------------------------------------------------------
# intra-round prefix sharing (eager registration in _claim_pages)
# ---------------------------------------------------------------------------


def test_identical_prompts_same_round_share_prompt_pages():
    """Three identical 17-token prompts admitted in the SAME round: the
    first claim registers its full prompt page eagerly, so both peers hit
    it within that round - and all three streams stay exact ((uid, step)
    keys diverge the sampled continuations)."""
    cfg, params = _model()
    rng = np.random.default_rng(11)
    prompt = rng.integers(1, 200, size=17).astype(np.int32)

    def mk():
        return [Request(uid=200 + i, prompt=prompt.copy(), max_new=8)
                for i in range(3)]

    ref = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16, 32),
                      temperature=0.7)
    want_reqs = mk()
    ref.run(want_reqs)

    eng = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16, 32),
                      temperature=0.7, paged=True, page_size=16,
                      decode_steps=4)
    reqs = mk()
    eng.run(reqs)
    assert _outputs(reqs) == _outputs(want_reqs)
    assert eng.stats["prefix_hits"] == 2        # both peers, same round
    assert eng.stats["prefix_shared_pages"] == 2
