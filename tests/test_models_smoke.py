"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + prefill/decode on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, reduced_config
from repro.models import build_model


def _batch(cfg, B, S, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }
    if cfg.frontend == "vision":
        batch["patches"] = 0.01 * jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = 0.01 * jax.random.normal(
            key, (B, 8, cfg.d_model)).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_train_step_smoke(arch):
    cfg = reduced_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch(cfg, B, S, jax.random.PRNGKey(1))
    loss, metrics = m.train_loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    # gradients flow and are finite
    g = jax.grad(lambda p: m.train_loss(p, batch)[0])(params)
    leaves = jax.tree.leaves(g)
    assert leaves, "no gradient leaves"
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32)))) for l in leaves), (
        f"{arch}: non-finite grads")


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_prefill_decode_smoke(arch):
    cfg = reduced_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S, jax.random.PRNGKey(1))
    batch.pop("labels")
    mem_len = 8 if cfg.family == "encdec" else 0
    P = cfg.frontend_tokens if cfg.frontend == "vision" else 0
    caches = m.init_caches(B, S + P + 4, mem_len)
    logits, caches = m.prefill(params, batch, caches)
    assert logits.shape == (B, cfg.vocab)
    for step in range(2):
        pos = jnp.full((B, 1), S + P + step, jnp.int32)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        logits, caches = m.decode_step(params, caches, tok, pos)
        assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite decode logits"


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "gemma2-2b", "zamba2-7b",
                                  "seamless-m4t-medium"])
def test_decode_matches_prefill(arch):
    """One-token decode after an (S-1)-prefill must reproduce the S-prefill
    logits (validates KV/ring/SSM/cross caches)."""
    cfg = reduced_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = _batch(cfg, B, S, jax.random.PRNGKey(2))
    batch.pop("labels")
    mem_len = 8 if cfg.family == "encdec" else 0
    caches = m.init_caches(B, S, mem_len)
    full, _ = m.prefill(params, batch, caches)
    caches = m.init_caches(B, S, mem_len)
    b2 = dict(batch)
    b2["tokens"] = batch["tokens"][:, : S - 1]
    _, caches = m.prefill(params, b2, caches)
    dec, _ = m.decode_step(params, caches, batch["tokens"][:, S - 1:],
                           jnp.full((B, 1), S - 1, jnp.int32))
    scale = float(jnp.abs(full).max()) + 1e-6
    assert float(jnp.abs(full - dec).max()) / scale < 0.05


def test_int8_kv_cache_decode_close_to_fp():
    """quant_kv='dynamic' decode stays near the fp cache path."""
    import dataclasses
    cfg = reduced_config("yi-6b")
    m_fp = build_model(cfg)
    m_q = build_model(dataclasses.replace(cfg, quant_kv="dynamic"))
    params = m_fp.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    outs = {}
    for tag, m in (("fp", m_fp), ("q", m_q)):
        caches = m.init_caches(B, S, 0)
        _, caches = m.prefill(params, {"tokens": toks[:, :S - 1]}, caches)
        logits, _ = m.decode_step(params, caches, toks[:, S - 1:],
                                  jnp.full((B, 1), S - 1, jnp.int32))
        outs[tag] = logits
    scale = float(jnp.abs(outs["fp"]).max()) + 1e-6
    assert float(jnp.abs(outs["fp"] - outs["q"]).max()) / scale < 0.08
