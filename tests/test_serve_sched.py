"""Bucketed batched prefill scheduler + cache pool plumbing tests.

Pins the PR-3 contract: a mixed-length workload compiles at most
len(buckets) prefill executables, batched prefill still rides the grouped
8-kernel PDQ path, bucket padding never leaks into attention or any cache,
and cache_slice/cache_merge/cache_scatter round-trip bit-exactly for fp
and int8 kernel-layout KV caches.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.kernels import ops
from repro.models import build_model
from repro.serve import Request, ServeEngine

MIXED_LENS = [3, 5, 8, 9, 12, 16, 17, 23, 30, 4, 11, 27]   # 12 requests


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced_config("stablelm-1.6b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _requests(cfg, lens, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                    max_new=max_new) for i, L in enumerate(lens)]


# ---------------------------------------------------------------------------
# compilation-count pin (the tentpole's reason to exist)
# ---------------------------------------------------------------------------


def test_mixed_length_workload_compiles_at_most_len_buckets(small_model):
    cfg, m, params = small_model
    eng = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16, 32))
    reqs = _requests(cfg, MIXED_LENS)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 4 for r in reqs)
    assert eng.stats["prefill_compiles"] <= len(eng.buckets), eng.stats
    assert eng.stats["decode_compiles"] == 1, eng.stats
    # admission actually batched: far fewer launches than requests
    assert eng.stats["prefill_batches"] < len(reqs), eng.stats
    assert eng.stats["prefill_requests"] == len(reqs)
    assert eng.stats["prefill_tokens"] == sum(MIXED_LENS)


def test_bucketed_outputs_match_per_request_prefill_exactly(small_model):
    """Bucket padding must never leak: the bucketed engine's greedy outputs
    are bit-identical to the legacy per-request-prefill engine's (pads are
    causally masked in attention, skipped exactly by the SSM recurrence,
    and their cache writes redirected onto the last real token)."""
    cfg, m, params = small_model
    outs = {}
    for tag, batched in (("bucketed", True), ("legacy", False)):
        eng = ServeEngine(cfg, params, slots=4, max_len=64,
                          buckets=(8, 16, 32), batch_prefill=batched)
        reqs = _requests(cfg, MIXED_LENS, max_new=6)
        eng.run(reqs)
        outs[tag] = [tuple(r.generated) for r in reqs]
    assert outs["bucketed"] == outs["legacy"]


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "deepseek-v2-236b",
                                  "seamless-m4t-medium", "phi-3-vision-4.2b"])
def test_bucketed_matches_legacy_other_families(arch):
    """SSM recurrent state, MLA compressed cache, encdec cross-K/V leaves
    and the vision patch-offset arithmetic all survive bucketing."""
    cfg = reduced_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = jnp.asarray(
            0.01 * rng.standard_normal((1, 8, cfg.d_model)), jnp.float32)
    if cfg.frontend == "vision":
        extras["patches"] = jnp.asarray(
            0.01 * rng.standard_normal((1, cfg.frontend_tokens, cfg.d_model)),
            jnp.float32)
    outs = {}
    for tag, batched in (("bucketed", True), ("legacy", False)):
        eng = ServeEngine(cfg, params, slots=2, max_len=48,
                          buckets=(8, 16), batch_prefill=batched)
        reqs = _requests(cfg, [3, 7, 11, 16], max_new=4)
        eng.run(reqs, extras=extras or None)
        outs[tag] = [tuple(r.generated) for r in reqs]
    assert outs["bucketed"] == outs["legacy"]


def test_prefill_many_matches_prefill_bitwise(small_model):
    """Bundle-level: one padded prefill_many call == N unpadded prefill
    calls, for the logits AND every cache leaf (bit-exact)."""
    cfg, m, params = small_model
    rng = np.random.default_rng(1)
    lens = [5, 9, 16]
    B, L, max_len = len(lens), 16, 32
    prompts = [rng.integers(0, cfg.vocab, s).astype(np.int32) for s in lens]
    toks = np.zeros((B, L), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    logits_b, caches_b = m.prefill_many(
        params, {"tokens": jnp.asarray(toks)}, m.init_caches(B, max_len, 0),
        jnp.asarray(lens, jnp.int32))
    caches_l = m.init_caches(B, max_len, 0)
    logits_l = []
    for i, p in enumerate(prompts):
        sub = m.cache_slice(caches_l, i, i + 1)
        lg, sub = m.prefill(params, {"tokens": jnp.asarray(p[None])}, sub)
        caches_l = m.cache_merge(caches_l, sub, i)
        logits_l.append(lg[0])
    np.testing.assert_array_equal(np.asarray(logits_b),
                                  np.asarray(jnp.stack(logits_l)))
    for a, b in zip(jax.tree.leaves(caches_b), jax.tree.leaves(caches_l)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pad_tokens_never_attended(small_model):
    """Changing the CONTENT of pad positions must not change anything: same
    prompts padded with zeros vs. padded with random junk give identical
    logits and caches."""
    cfg, m, params = small_model
    rng = np.random.default_rng(2)
    lens = [4, 7]
    B, L = 2, 16
    prompts = [rng.integers(0, cfg.vocab, s).astype(np.int32) for s in lens]
    outs = []
    for fill in (0, 1):
        toks = (np.zeros((B, L), np.int32) if fill == 0
                else rng.integers(0, cfg.vocab, (B, L)).astype(np.int32))
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        lg, caches = m.prefill_many(
            params, {"tokens": jnp.asarray(toks)}, m.init_caches(B, 32, 0),
            jnp.asarray(lens, jnp.int32))
        outs.append((lg, caches))
    np.testing.assert_array_equal(np.asarray(outs[0][0]), np.asarray(outs[1][0]))
    for a, b in zip(jax.tree.leaves(outs[0][1]), jax.tree.leaves(outs[1][1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# grouped-kernel pin: batched prefill rides the PR-2 pipeline
# ---------------------------------------------------------------------------


def test_quantized_gqa_batched_prefill_block_is_seven_kernels():
    """A quantized GQA block under BATCHED PADDED prefill must trace to the
    same 7 pallas_calls as decode (grouped QKV pair + wo pair + fused
    SwiGLU MLP triple - the gate/up matmul's epilogue emits w_down's PDQ
    prologue, see tools/check_census.py): bucketing must not push any
    projection off the grouped fused path."""
    from repro.models.attention import AttnDims, gqa_apply, gqa_init, init_cache
    from repro.models.layers import mlp_apply, mlp_init, rms_norm
    from repro.models.linops import quantize_param_tree
    from tests.test_hlo_and_linops import _count_pallas_calls

    dims = AttnDims(d_model=256, n_heads=4, n_kv_heads=2, head_dim=64)
    key = jax.random.PRNGKey(0)
    params = {"attn": gqa_init(key, dims, jnp.float32),
              "attn_norm": jnp.zeros((256,)),
              "ffn_norm": jnp.zeros((256,)),
              "ffn": mlp_init(jax.random.fold_in(key, 1), 256, 512, jnp.float32)}
    qp = quantize_param_tree(params)
    cache = init_cache(dims, 8, 64, jnp.float32)

    def block(p, h, cache, positions, seq_lens):
        a, cache = gqa_apply(p["attn"], dims, rms_norm(h, p["attn_norm"]),
                             positions, mode="prefill", cache=cache,
                             seq_lens=seq_lens)
        h = h + a
        return h + mlp_apply(p["ffn"], rms_norm(h, p["ffn_norm"])), cache

    h = jnp.ones((8, 16, 256))                       # batch of padded rows
    pos = jnp.broadcast_to(jnp.arange(16)[None], (8, 16)).astype(jnp.int32)
    seq_lens = jnp.asarray([3, 5, 7, 16, 9, 11, 2, 13], jnp.int32)
    ops.set_impl("kernel")
    try:
        jaxpr = jax.make_jaxpr(block)(qp, h, cache, pos, seq_lens)
    finally:
        ops.set_impl("auto")
    n = _count_pallas_calls(jaxpr)
    assert n == 7, f"expected 7 pallas_calls per quantized prefill block, got {n}"


# ---------------------------------------------------------------------------
# cache round-trips (fp and int8 kernel-layout caches)
# ---------------------------------------------------------------------------


def _filled_like(tree, seed):
    leaves, treedef = jax.tree.flatten(tree)
    rng = np.random.default_rng(seed)
    return jax.tree.unflatten(
        treedef, [jnp.asarray(rng.integers(-100, 100, l.shape), l.dtype)
                  for l in leaves])


@pytest.mark.parametrize("quant_kv", ["none", "dynamic"])
@pytest.mark.parametrize("impl", ["ref", "kernel"])
def test_cache_scatter_roundtrip_bit_exact(quant_kv, impl):
    """cache_scatter lands selected sub rows and keeps every untouched slot
    bit-exact, across fp and int8 kernel-layout KV leaves and both the jnp
    reference and the Pallas kernel (interpret mode off-TPU)."""
    cfg = dataclasses.replace(reduced_config("gemma2-2b"), quant_kv=quant_kv)
    m = build_model(cfg)
    pool = _filled_like(m.init_caches(4, 32, 0), 1)
    sub = _filled_like(m.init_caches(4, 32, 0), 2)
    src_map = jnp.asarray([-1, 2, -1, 0], jnp.int32)
    ops.set_impl(impl)
    try:
        out = m.cache_scatter(pool, sub, src_map)
    finally:
        ops.set_impl("auto")

    def rows(leaf, pool_leaf):
        # head/tail leaves: batch axis 0; stacked block leaves: axis 1
        ax = 0 if leaf.shape[0] == 4 else 1
        return (lambda i: jnp.take(leaf, i, axis=ax),
                lambda i: jnp.take(pool_leaf, i, axis=ax))

    for o, p, s in zip(jax.tree.leaves(out), jax.tree.leaves(pool),
                       jax.tree.leaves(sub)):
        get_o, get_p = rows(o, p)
        get_s, _ = rows(s, s)
        for slot, src in enumerate([-1, 2, -1, 0]):
            want = get_p(slot) if src < 0 else get_s(src)
            np.testing.assert_array_equal(np.asarray(get_o(slot)),
                                          np.asarray(want))


@pytest.mark.parametrize("quant_kv", ["none", "dynamic"])
def test_cache_slice_merge_roundtrip_bit_exact(quant_kv):
    """cache_merge(cache_slice(...)) is the identity and never perturbs the
    other slots, for fp and int8 kernel-layout caches."""
    cfg = dataclasses.replace(reduced_config("gemma2-2b"), quant_kv=quant_kv)
    m = build_model(cfg)
    pool = _filled_like(m.init_caches(3, 32, 0), 3)
    sub = m.cache_slice(pool, 1, 2)
    out = m.cache_merge(pool, sub, 1)
    for o, p in zip(jax.tree.leaves(out), jax.tree.leaves(pool)):
        np.testing.assert_array_equal(np.asarray(o), np.asarray(p))


def test_int8_kv_slot_reuse_does_not_attend_stale_tokens(small_model):
    """Regression: a freed slot's cache row must be reset before reuse.
    With int8 KV the decode kernel masks by cache['len'] alone, and
    _cache_write keeps max(stale_len, new_len), so a SHORTER request
    reusing a slot would attend the previous occupant's tokens if the
    engine prefillled into the stale row.  Both paths must match a fresh
    single-request engine exactly."""
    cfg, _, _ = small_model
    cfg = dataclasses.replace(cfg, quant_kv="dynamic")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    long_req, short_req = _requests(cfg, [20, 4], max_new=6, seed=9)
    truth = ServeEngine(cfg, params, slots=1, max_len=64, buckets=(8, 32))
    ref = _requests(cfg, [4], max_new=6, seed=9)[0]
    ref.prompt = short_req.prompt.copy()
    truth.run([ref])                                  # fresh engine = oracle
    for batched in (True, False):
        eng = ServeEngine(cfg, params, slots=1, max_len=64, buckets=(8, 32),
                          batch_prefill=batched)
        a, b = _requests(cfg, [20, 4], max_new=6, seed=9)
        eng.run([a, b])                               # b reuses a's slot
        assert tuple(b.generated) == tuple(ref.generated), (
            batched, b.generated, ref.generated)


def test_int8_kv_bucketed_decode_stays_masked(small_model):
    """int8 KV cache + bucketed prefill: the decode kernel's length mask
    must exclude bucket pad positions (cache['len'] == true length)."""
    cfg, _, _ = small_model
    cfg = dataclasses.replace(cfg, quant_kv="dynamic")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    outs = {}
    for tag, batched in (("bucketed", True), ("legacy", False)):
        eng = ServeEngine(cfg, params, slots=2, max_len=64,
                          buckets=(8, 16), batch_prefill=batched)
        reqs = _requests(cfg, [3, 7, 12, 15], max_new=4, seed=5)
        eng.run(reqs)
        outs[tag] = [tuple(r.generated) for r in reqs]
    assert outs["bucketed"] == outs["legacy"]


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "mamba2-2.7b",
                                  "deepseek-v2-236b", "gemma2-2b"])
def test_chunked_prefill_matches_unchunked(arch):
    """Prompts beyond the largest bucket split into bucket-sized chunks
    (first chunk via prefill_many, continuations via prefill_chunk against
    the accumulating cache rows) with greedy outputs identical to an
    engine whose bucket set admits the whole prompt at once - across the
    GQA KV cache, the SSM conv tail + recurrent state, the MLA compressed
    cache, and gemma2's sliding-window RING cache (regression: a
    continuation chunk must attend the pre-write ring + its own k/v -
    writing first evicts keys still inside earlier queries' windows)."""
    cfg = reduced_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    lens = [3, 20, 40, 12, 33]            # 20/40/33 exceed the 16 bucket
    whole = ServeEngine(cfg, params, slots=2, max_len=64, buckets=(8, 16))
    assert whole.buckets[-1] == 63        # capacity bucket admits unchunked
    reqs = _requests(cfg, lens, max_new=5)
    whole.run(reqs)
    want = [tuple(r.generated) for r in reqs]

    eng = ServeEngine(cfg, params, slots=2, max_len=64, buckets=(8, 16),
                      chunked_prefill=True)
    assert eng.buckets == (8, 16)         # no capacity-sized executable
    reqs = _requests(cfg, lens, max_new=5)
    eng.run(reqs)
    got = [tuple(r.generated) for r in reqs]
    assert got == want
    assert eng.stats["chunked_requests"] == 3
    assert eng.stats["chunk_batches"] >= 3
    # the compile bound that motivates chunking: every executable is
    # bucket-shaped (<= len(buckets) for each of the two prefill kinds)
    assert eng.stats["prefill_compiles"] <= len(eng.buckets)
    assert eng.stats["chunk_compiles"] <= len(eng.buckets)


def test_chunked_cobatch_shares_one_launch_sequence(small_model):
    """Oversized prompts with EQUAL chunk counts co-batch into ONE shared
    chunked launch sequence (one first-chunk launch + one continuation per
    window), instead of each burning a dummy-row-padded sequence alone -
    and the co-batched tokens stay bit-identical to an engine whose bucket
    set admits each prompt unchunked."""
    cfg, m, params = small_model
    lens = [20, 28, 26]                   # all ceil(L/16) == 2 with chunk=16
    whole = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16))
    assert whole.buckets[-1] == 63        # capacity bucket admits unchunked
    reqs = _requests(cfg, lens, max_new=5)
    whole.run(reqs)
    want = [tuple(r.generated) for r in reqs]

    eng = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16),
                      chunked_prefill=True)
    reqs = _requests(cfg, lens, max_new=5)
    eng.run(reqs)
    assert [tuple(r.generated) for r in reqs] == want
    # the co-batch pin: all three requests rode ONE plan - a single
    # batched first chunk plus a single shared continuation window
    assert eng.stats["chunked_requests"] == 3
    assert eng.stats["prefill_batches"] == 1
    assert eng.stats["chunk_batches"] == 1
    assert eng.stats["chunk_compiles"] <= 1
    assert eng.stats["replica_occupancy"] == [0]        # nothing leaked

    # mixed chunk counts do NOT co-batch: 40 needs 3 windows, 20 needs 2
    eng2 = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16),
                       chunked_prefill=True)
    reqs2 = _requests(cfg, [20, 40], max_new=4)
    eng2.run(reqs2)
    assert all(r.done and r.error is None for r in reqs2)
    assert eng2.stats["prefill_batches"] == 2           # one plan per count


def test_chunked_extras_rejected_without_leaking_the_slot(small_model):
    """Chunked prefill is text-only; the rejection must fire at the
    run()/submit() ENTRY - raising mid-admission would leak the planned
    slot and silently drop already-dequeued same-round peers."""
    cfg, m, params = small_model
    eng = ServeEngine(cfg, params, slots=2, max_len=64, buckets=(8,),
                      chunked_prefill=True)
    extras = {"patches": np.zeros((1, 2, 4), np.float32)}
    short, oversized = _requests(cfg, [5, 20])    # 20 needs chunking
    with pytest.raises(NotImplementedError, match="text-only"):
        eng.run([short, oversized], extras=extras)
    assert eng._free_total() == 2          # no slot leaked
    assert eng.stats["replica_occupancy"] == [0]
    assert not eng.pending                 # nothing queued, nothing dropped
    with pytest.raises(NotImplementedError, match="text-only"):
        eng.submit(oversized, extras=extras)
    assert eng._free_total() == 2
    eng.run([short, oversized])            # engine stays fully usable
    assert short.done and oversized.done


def test_chunked_prefill_rejects_beyond_capacity(small_model):
    """Chunking lifts the bucket limit, not the cache capacity: a prompt
    that cannot fit max_len (with the first decode slot reserved) still
    raises up front without dequeuing peers."""
    cfg, m, params = small_model
    eng = ServeEngine(cfg, params, slots=2, max_len=32, buckets=(8,),
                      chunked_prefill=True)
    ok = _requests(cfg, [20])[0]          # > bucket 8, <= capacity 31
    bad = _requests(cfg, [32])[0]         # would fill the cache exactly
    with pytest.raises(ValueError, match="exceeds the cache capacity"):
        eng.run([ok, bad])
    assert not eng.pending
    eng.run([ok])
    assert ok.done


# ---------------------------------------------------------------------------
# MoE router capacity: pad tokens masked out (DESIGN.md Sec. 4 fix)
# ---------------------------------------------------------------------------


def test_moe_pad_content_cannot_change_real_expert_assignment():
    """With capacity tight, UNMASKED pad tokens ahead of a row's real
    tokens steal expert-capacity slots (content-dependently); the
    token_mask must make real-token outputs invariant to pad content."""
    from repro.models.moe import MoEConfig, moe_ffn_tokens, moe_init

    cfg = dataclasses.replace(
        MoEConfig(n_experts=4, top_k=1, d_ff_expert=8), capacity_factor=1.0)
    p = moe_init(jax.random.PRNGKey(0), 16, cfg, jnp.float32)
    routed = {k: p[k] for k in ("router", "we_gate", "we_up", "we_down")}
    rng = np.random.default_rng(0)
    real = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    pads = [jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
            for _ in range(2)]
    # pads FIRST: in a flattened (B, S) prefill batch, row b's pads precede
    # row b+1's real tokens, so they can claim capacity slots first.
    mask = jnp.asarray([False] * 8 + [True] * 8)

    def run(pad, token_mask):
        x = jnp.concatenate([pad, real], axis=0)
        y, _ = moe_ffn_tokens(routed, x, cfg, token_mask=token_mask)
        return np.asarray(y[8:])

    unmasked = [run(p_, None) for p_ in pads]
    assert not np.array_equal(unmasked[0], unmasked[1]), (
        "expected tight capacity to make real tokens pad-content-dependent "
        "without the mask (the regression this test pins)")
    masked = [run(p_, mask) for p_ in pads]
    np.testing.assert_array_equal(masked[0], masked[1])


def test_moe_bucketed_prefill_pad_invariant_under_tight_capacity():
    """Bundle-level regression on a MoE arch with TIGHT expert capacity:
    junk written into the pad tail of a bucketed prefill must not change
    any real row's logits or caches.  Before the router mask, pad tokens
    claimed capacity slots content-dependently, so this exact comparison
    diverged; generous capacity_factor was the only thing hiding it (the
    old DESIGN.md Sec. 4 caveat)."""
    cfg = reduced_config("deepseek-v2-236b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    lens = [4, 7]
    B, L = 2, 16
    prompts = [rng.integers(0, cfg.vocab, s).astype(np.int32) for s in lens]
    outs = []
    for fill in (0, 1):
        toks = (np.zeros((B, L), np.int32) if fill == 0
                else rng.integers(0, cfg.vocab, (B, L)).astype(np.int32))
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        lg, caches = m.prefill_many(
            params, {"tokens": jnp.asarray(toks)}, m.init_caches(B, 32, 0),
            jnp.asarray(lens, jnp.int32))
        outs.append((lg, caches))
    np.testing.assert_array_equal(np.asarray(outs[0][0]), np.asarray(outs[1][0]))
    for a, b in zip(jax.tree.leaves(outs[0][1]), jax.tree.leaves(outs[1][1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_dummy_rows_claim_no_expert_capacity():
    """PR-5 fix for the ROADMAP caveat: a DUMMY row of a partially-filled
    prefill batch must route NOTHING - under the old convention its one
    'real' token claimed an expert-capacity slot ahead of later rows'
    real tokens, which at capacity_factor=1.0 evicts them."""
    from repro.models.moe import MoEConfig, moe_ffn_tokens, moe_init, route

    cfg = dataclasses.replace(
        MoEConfig(n_experts=4, top_k=1, d_ff_expert=8), capacity_factor=1.0)
    p = moe_init(jax.random.PRNGKey(0), 16, cfg, jnp.float32)
    routed = {k: p[k] for k in ("router", "we_gate", "we_up", "we_down")}

    # route(): an all-masked row contributes only sentinel ids (== E) and
    # zero gates, so _bucket drops every one of its assignments
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 16)),
                    jnp.float32)
    gates, ids, _ = route(x, p["router"], cfg, jnp.zeros((8,), bool))
    assert np.all(np.asarray(ids) == cfg.n_experts)
    assert np.all(np.asarray(gates) == 0.0)

    # capacity: 16 tokens, E=4, k=1, cf=1.0 -> C=4.  The dummy block leads
    # (replica-interleaved layout) and its tokens' router inputs EQUAL the
    # real tokens', so any dummy claim steals exactly a real token's slot.
    rng = np.random.default_rng(1)
    real = jnp.asarray(np.repeat(rng.standard_normal((1, 16)), 8, axis=0),
                       jnp.float32)
    dummy = real                                 # same routing as the reals
    batch = jnp.concatenate([dummy, real], axis=0)
    new_mask = jnp.asarray([False] * 8 + [True] * 8)     # dummy row: nothing
    old_mask = jnp.asarray([True] + [False] * 7 + [True] * 8)  # old: 1 token

    def reals_out(mask):
        y, _ = moe_ffn_tokens(routed, batch, cfg, token_mask=mask)
        return np.asarray(y[8:])

    want = reals_out(new_mask)
    assert not np.array_equal(want, reals_out(old_mask)), (
        "expected the old one-token dummy claim to evict a real token at "
        "capacity_factor=1.0 (the regression this test pins)")
    # dummy CONTENT is also inert once fully masked
    junk = jnp.concatenate([dummy + 3.0, real], axis=0)
    y2, _ = moe_ffn_tokens(routed, junk, cfg, token_mask=new_mask)
    np.testing.assert_array_equal(want, np.asarray(y2[8:]))


def test_engine_dummy_rows_have_zero_seq_len_and_are_inert(small_model):
    """The scheduler emits seq_lens == 0 for dummy rows, and prefill_many
    threads that through to a fully-masked row: at capacity_factor=1.0 on
    a MoE arch, real rows' logits are bit-identical whether the dummy row
    sits BETWEEN them (a multi-replica plan's interleaved layout) or at
    the end (the packed single-replica layout)."""
    cfg, m, params = small_model
    eng = ServeEngine(cfg, params, slots=4, max_len=64, buckets=(8, 16))
    plan = eng._plan_prefill(eng._assign(_requests(cfg, [5])), 8)
    assert list(plan.seq_lens) == [5, 0, 0, 0]
    assert list(plan.src_map) == [0, -1, -1, -1]

    mcfg = reduced_config("deepseek-v2-236b")
    mcfg = dataclasses.replace(
        mcfg, moe=dataclasses.replace(mcfg.moe, capacity_factor=1.0))
    mm = build_model(mcfg)
    mp = mm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    p1 = rng.integers(0, mcfg.vocab, 4).astype(np.int32)
    p2 = rng.integers(0, mcfg.vocab, 7).astype(np.int32)

    def prefill(seq_lens, rows):
        toks = np.zeros((3, 8), np.int32)
        for r, pr in rows.items():
            toks[r, :len(pr)] = pr
        lg, _ = mm.prefill_many(mp, {"tokens": jnp.asarray(toks)},
                                mm.init_caches(3, 32, 0),
                                jnp.asarray(seq_lens, jnp.int32))
        return np.asarray(lg)

    mid = prefill([4, 0, 7], {0: p1, 2: p2})
    end = prefill([4, 7, 0], {0: p1, 1: p2})
    np.testing.assert_array_equal(mid[0], end[0])
    np.testing.assert_array_equal(mid[2], end[1])


# ---------------------------------------------------------------------------
# scheduler behaviour
# ---------------------------------------------------------------------------


def test_submit_admits_immediately_and_reports_full(small_model):
    cfg, m, params = small_model
    eng = ServeEngine(cfg, params, slots=2, max_len=64, buckets=(8, 16, 32))
    reqs = _requests(cfg, [4, 6, 9], max_new=64)   # long-running
    assert eng.submit(reqs[0])
    assert eng.submit(reqs[1])
    assert not eng.submit(reqs[2])                 # both slots busy
    eng.run([reqs[2]])
    assert all(r.done for r in reqs)


def test_cache_capacity_always_rides_as_last_bucket(small_model):
    """Any prompt the legacy per-request path served safely stays
    servable: the capacity limit (max_len minus one decode slot) is
    appended to the bucket set, so a prompt above the largest configured
    bucket still admits (one extra executable)."""
    cfg, m, params = small_model
    eng = ServeEngine(cfg, params, slots=2, max_len=64, buckets=(8, 16))
    assert eng.buckets == (8, 16, 63)
    reqs = _requests(cfg, [20, 40, 63])
    eng.run(reqs)
    assert all(r.done for r in reqs)


def test_oversized_prompt_is_rejected_before_dequeuing(small_model):
    """A prompt beyond cache capacity raises up front, WITHOUT dequeuing
    (and thereby losing) admissible peers."""
    cfg, m, params = small_model
    eng = ServeEngine(cfg, params, slots=2, max_len=64, buckets=(8, 16))
    ok = _requests(cfg, [5])[0]
    bad = _requests(cfg, [64])[0]          # would fill the cache exactly
    with pytest.raises(ValueError, match="exceeds the largest prefill bucket"):
        eng.run([ok, bad])
    assert not eng.pending                 # queue untouched by the rejection
    with pytest.raises(ValueError, match="empty prompt"):
        eng.run([Request(uid=9, prompt=np.zeros((0,), np.int32))])
    eng.run([ok])                          # peer is still servable
    assert ok.done
