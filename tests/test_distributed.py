"""Distribution tests: sharding rules, MoE dispatch equivalence, compressed
collectives, fault handling.  Multi-device cases run in a subprocess with
XLA_FLAGS so the main test process keeps its single-device view."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import collectives, sharding as shd
from repro.distributed.fault import StragglerWatchdog


def _run_subprocess(code: str, devices: int = 8):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# -------------------------------------------------------------- spec rules
def test_param_spec_rules():
    assert shd.spec_for_param("blocks/0/attn/wq", jnp.zeros((8, 64, 128))) \
        == P(None, "data", "model")
    assert shd.spec_for_param("blocks/0/attn/wo", jnp.zeros((8, 128, 64))) \
        == P(None, "model", "data")
    assert shd.spec_for_param("blocks/0/ffn/we_gate", jnp.zeros((8, 16, 64, 32))) \
        == P(None, "model", "data", None)
    assert shd.spec_for_param("embed/embedding", jnp.zeros((1024, 64))) \
        == P("model", "data")
    assert shd.spec_for_param("final_norm", jnp.zeros((64,))) == P(None)


def test_param_spec_divisibility_fallback():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    # vocab 50280 % 16 != 0 -> vocab axis dropped, d axis kept
    spec = shd.spec_for_param("embed/embedding", jnp.zeros((50280, 2560)),
                              FakeMesh())
    assert spec == P(None, "data")


def test_cache_spec_stacked_blocks():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}
    caches = {"k": jnp.zeros((6, 8, 32, 2, 16)),      # stacked (blocks, B,...)
              "state": jnp.zeros((6, 8, 4, 16, 8)),
              "len": jnp.zeros((6, 8))}
    specs = shd.cache_spec(FakeMesh(), caches, batch=8)
    assert specs["k"] == P(None, ("data",), None, "model", None)
    assert specs["state"] == P(None, ("data",), "model", None, None)


# ------------------------------------------------------ compressed collective
def test_grad_compression_roundtrip_accuracy():
    g = jax.random.normal(jax.random.PRNGKey(0), (4096,)) * 0.01
    codes, scale, meta = collectives.quantize_grad(g)
    back = collectives.dequantize_grad(codes, scale, meta)
    rel = float(jnp.linalg.norm(back - g) / jnp.linalg.norm(g))
    assert rel < 0.05
    assert codes.dtype == jnp.int8


def test_compressed_psum_multi_device():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.collectives import compressed_psum
        n = jax.device_count()
        assert n == 8
        def f(g, e):
            return compressed_psum(g, "dp", e)
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 1024)) * 0.01
        e = jnp.zeros((8, 1024))
        out, err = jax.pmap(f, axis_name="dp")(g, e)
        want = jnp.sum(g, axis=0)
        rel = float(jnp.linalg.norm(out[0] - want) / jnp.linalg.norm(want))
        print("REL", rel)
        assert rel < 0.05, rel
        # error feedback: residual magnitude bounded by one quantization step
        assert float(jnp.abs(err).max()) <= float(jnp.abs(g).max())
        print("OK")
    """)
    assert "OK" in out


def test_moe_ep_matches_local_dispatch():
    """Expert-parallel (shard_map, 4-way a2a) MoE == local bucketing MoE."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.models.context import shard_map
        from repro.models.moe import MoEConfig, moe_init, moe_ffn_tokens
        cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16,
                        capacity_factor=8.0)
        p = moe_init(jax.random.PRNGKey(0), 32, cfg, jnp.float32)
        routed = {k: p[k] for k in ("router", "we_gate", "we_up", "we_down")}
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        y_local, aux_local = moe_ffn_tokens(routed, x, cfg, axis_name=None)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        def f(rp, xt):
            return moe_ffn_tokens(rp, xt, cfg, axis_name="model")
        y_ep, aux_ep = shard_map(
            f, mesh=mesh,
            in_specs=({"router": P(None, None), "we_gate": P("model", None, None),
                       "we_up": P("model", None, None),
                       "we_down": P("model", None, None)},
                      P(("data", "model"), None)),
            out_specs=(P(("data", "model"), None), P()),
            check_vma=False)(routed, x)
        err = float(jnp.abs(y_local - y_ep).max())
        print("ERR", err)
        assert err < 1e-4, err
        print("OK")
    """)
    assert "OK" in out


def test_full_train_step_on_host_mesh():
    """The fully-sharded train step runs (not just lowers) on an 8-device
    host mesh - DP x TP with MoE EP via shard_map."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.configs import reduced_config
        from repro.models import build_model
        from repro.models import context as mctx
        from repro.optim import AdamWConfig
        from repro.train.train_step import (build_train_step, make_state,
                                            dist_context_for)
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(data=4, model=2)
        mctx.set_context(dist_context_for(mesh))
        cfg = reduced_config("deepseek-v2-236b")
        bundle = build_model(cfg)
        opt = AdamWConfig(lr=1e-3)
        step, shardings = build_train_step(bundle, opt, mesh)
        state = make_state(bundle, opt, jax.random.PRNGKey(0))
        state = jax.device_put(state, shardings)
        batch = {"tokens": jnp.ones((8, 32), jnp.int32),
                 "labels": jnp.ones((8, 32), jnp.int32)}
        state, metrics = step(state, batch)
        state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        print("LOSS", loss)
        assert loss == loss and loss < 20
        print("OK")
    """)
    assert "OK" in out


def test_elastic_reshard_between_mesh_sizes():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.distributed.fault import reshard_state
        devs = np.array(jax.devices())
        mesh_a = Mesh(devs.reshape(4, 2), ("data", "model"))
        mesh_b = Mesh(devs[:4].reshape(2, 2), ("data", "model"))
        state = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
        spec = {"w": P("data", "model")}
        a = reshard_state(state, mesh_a, spec)
        b = reshard_state(jax.tree.map(np.asarray, jax.device_get(a)), mesh_b, spec)
        np.testing.assert_array_equal(np.asarray(b["w"]), state["w"])
        print("OK")
    """)
    assert "OK" in out


# ---------------------------------------------------------------- watchdog
def test_straggler_watchdog_flags_slow_steps():
    w = StragglerWatchdog(factor=3.0)
    for _ in range(20):
        w.observe(0.1)
    assert w.observe(1.0) is True
    assert w.flagged == 1
