"""Streaming serving front door: continuous admission, overload
backpressure, per-request deadlines & cancellation (PR-7 contract).

Pins:
  * continuously-admitted streams are token-for-token identical to batch
    ``engine.run()`` (per-(uid, step) sampling keys make the two paths the
    same computation),
  * the admission queue is bounded: past the watermark submits shed with a
    typed ``OverloadedError``/HTTP 429 + Retry-After, counted in stats,
    and an overload soak is DETERMINISTIC round-for-round,
  * cancel (client, disconnect, slow consumer) and deadline expiry evict
    ONLY their own request - batch peers stay bit-exact and the slot is
    reclaimed within a round,
  * the raw-asyncio HTTP layer maps every failure mode to a typed status
    (400/404/429/503) and SSE streams carry the engine's exact tokens,
  * SIGTERM during live HTTP traffic drains, snapshots, and ``--resume``
    regenerates the interrupted request token-exactly.
"""
import asyncio
import contextlib
import http.client
import json
import os
import re
import signal
import socket
import subprocess
import sys
import tempfile
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.distributed.fault import FaultInjector, FaultPlan
from repro.models import build_model
from repro.serve import (EngineDraining, HttpFrontend, OverloadedError,
                         Request, ServeConfig, ServeService, build_engine)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced_config("stablelm-1.6b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _engine(cfg, params, **kw):
    kw.setdefault("slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("buckets", (8, 16, 32))
    # the supported construction surface (PR 8): every engine through
    # ServeConfig + build_engine
    return build_engine(ServeConfig(**kw), cfg=cfg, params=params)


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, L).astype(np.int32) for L in lens]


def _batch_ref(cfg, params, reqs, **kw):
    """Run copies of ``reqs`` through a fresh engine's batch path; return
    {uid: tokens}.  Sampling keys are (uid, step)-derived, so this is THE
    reference the streamed tokens must equal bit-for-bit."""
    eng = _engine(cfg, params, **kw)
    copies = [Request(uid=r.uid, prompt=np.asarray(r.prompt),
                      max_new=r.max_new) for r in reqs]
    eng.run(copies)
    assert all(r.done and r.error is None for r in copies)
    return {r.uid: tuple(r.generated) for r in copies}


def _wait(pred, timeout=300.0, every=0.01):
    deadline = time.monotonic() + timeout
    while not pred():
        if time.monotonic() > deadline:
            raise TimeoutError("condition not reached")
        time.sleep(every)


class _SlowDecode(FaultInjector):
    """Really sleep before each decode launch (FaultPlan.delay_rounds is
    VIRTUAL - watchdog-only) so a cancel racing a fast tiny-model decode
    reliably lands while the request is still in flight."""

    def __init__(self, seconds: float = 0.03):
        self.seconds = seconds

    def on_exec(self, kind: str, rnd: int) -> None:
        if kind == "decode":
            time.sleep(self.seconds)


# ---------------------------------------------------------------------------
# continuous admission: streamed == batch
# ---------------------------------------------------------------------------


def test_streamed_tokens_match_batch_run(small_model):
    """Requests submitted continuously (staggered, mid-flight) through the
    service produce the same tokens as one batch run() - and the streams
    deliver them incrementally, first token before the request finishes."""
    cfg, m, params = small_model
    lens = [3, 9, 12, 5, 17, 7]
    prompts = _prompts(cfg, lens)
    reqs = [Request(uid=i, prompt=p, max_new=6) for i, p in
            enumerate(prompts)]
    want = _batch_ref(cfg, params, reqs)

    eng = _engine(cfg, params)
    svc = ServeService(eng, max_pending=16).start()
    streams = []
    for i, p in enumerate(prompts):
        streams.append(svc.submit(p, max_new=6))
        if i == 2:      # stagger: later submits land mid-decode
            _wait(lambda: eng.stats["decode_steps"] > 0)
    got = {s.uid: s.result(timeout=300) for s in streams}
    assert {u: tuple(t) for u, (t, _, _) in got.items()} == want
    assert all(fin == "complete" and err is None
               for _, fin, err in got.values())
    st = svc.stats()
    assert st["completed"] == len(lens) and st["shed"] == 0
    assert st["pending"] == 0 and st["free_slots"] == st["slots"]
    svc.stop()
    assert not svc._streams           # stream table drained, nothing leaked


# ---------------------------------------------------------------------------
# overload: bounded queue, deterministic shed, accepted work exact
# ---------------------------------------------------------------------------


def _soak(cfg, params, rounds=60, per_round=6):
    """Deterministic 3x-capacity open-loop soak via burst injection:
    ``per_round`` submits hit a 2-slot engine with a 4-deep admission
    queue at the top of every scheduler round."""
    burst = {r: [[3 + (r + i) % 6, 4] for i in range(per_round)]
             for r in range(rounds)}
    plan = FaultPlan(burst_rounds=dict(burst))
    eng = _engine(cfg, params, slots=2, buckets=(8,),
                  fault=plan.injector())
    svc = ServeService(eng, max_pending=4).start()
    # every offered request terminal (monotonic counters: no transient
    # window mid queue-to-slot handoff, unlike polling pending/active)
    _wait(lambda: eng.stats["shed"] + eng.stats["completed"]
          == rounds * per_round, timeout=600)
    svc.stop()
    accepted = list(eng.finished)
    stats = dict(eng.stats)
    return eng, accepted, stats


def test_overload_soak_sheds_deterministically_no_leak(small_model):
    cfg, m, params = small_model
    eng, accepted, stats = _soak(cfg, params)
    # sustained 3x overload: the bounded queue shed most of the offered
    # load, every shed is counted, and what WAS accepted all completed
    assert eng._round >= 50
    assert stats["shed"] > 0
    assert stats["completed"] == len(accepted) > 0
    assert stats["shed"] + stats["completed"] == 60 * 6
    assert all(r.done and r.finish_reason == "complete" for r in accepted)
    # no slot/queue leak after the storm
    assert eng._free_total() == eng.slots
    assert not eng.pending and all(r is None for r in eng.active)
    # accepted streams are token-for-token the batch-run tokens
    want = _batch_ref(cfg, params, accepted, slots=2, buckets=(8,))
    assert {r.uid: tuple(r.generated) for r in accepted} == want
    # the soak is deterministic: same plan, same rounds -> same shed
    # pattern and same accepted set, replayed end to end
    eng2, accepted2, stats2 = _soak(cfg, params)
    assert stats2["shed"] == stats["shed"]
    assert stats2["completed"] == stats["completed"]
    assert ([(r.uid, tuple(r.generated)) for r in accepted2]
            == [(r.uid, tuple(r.generated)) for r in accepted])


def test_overloaded_error_is_typed_and_counted(small_model):
    cfg, m, params = small_model
    eng = _engine(cfg, params, slots=2, buckets=(8,))
    svc = ServeService(eng, max_pending=2, retry_after=1.5)
    # not started: submits queue in ingress, so the watermark is exact
    p = _prompts(cfg, [4])[0]
    svc.submit(p, max_new=4)
    svc.submit(p, max_new=4)
    with pytest.raises(OverloadedError) as ei:
        svc.submit(p, max_new=4)
    assert ei.value.retry_after == 1.5
    assert eng.stats["shed"] == 1
    svc.start()
    svc.stop()


# ---------------------------------------------------------------------------
# cancellation: only the cancelled request is evicted
# ---------------------------------------------------------------------------


def test_cancel_midflight_evicts_only_own_request(small_model):
    cfg, m, params = small_model
    prompts = _prompts(cfg, [5, 9, 12, 7])
    reqs = [Request(uid=i, prompt=p, max_new=12) for i, p in
            enumerate(prompts)]
    want = _batch_ref(cfg, params, reqs)

    eng = _engine(cfg, params, fault=_SlowDecode())
    svc = ServeService(eng, max_pending=16).start()
    streams = [svc.submit(p, max_new=12) for p in prompts]
    victim = streams[1]
    got_early: list[int] = []

    def two_tokens_flowed():
        got_early.extend(victim.drain()[0])
        return len(got_early) >= 2

    _wait(two_tokens_flowed)
    svc.cancel(victim.uid, reason="user hit stop")
    results = {s.uid: s.result(timeout=300) for s in streams}
    svc.stop()

    toks, fin, err = results[victim.uid]
    assert fin == "cancel" and err == "user hit stop"
    early_and_late = tuple(got_early) + tuple(toks)
    assert early_and_late == want[victim.uid][:len(early_and_late)]
    assert len(early_and_late) < 12           # actually cut short
    # peers: bit-exact, untouched by the eviction
    for uid in (0, 2, 3):
        toks, fin, _ = results[uid]
        assert fin == "complete" and tuple(toks) == want[uid]
    assert eng.stats["cancelled"] == 1
    assert eng._free_total() == eng.slots     # slot reclaimed


def test_cancel_mid_chunked_prefill_reclaims_slot_same_round(small_model):
    """A cancel landing while the chunked-prefill launch sequence is IN
    FLIGHT (planned, not yet applied) is honoured at apply time: the slot
    is reclaimed within that same round, no token is emitted, and the
    co-batched peer's stream is bit-exact."""
    cfg, m, params = small_model
    prompts = _prompts(cfg, [20, 26, 4])
    reqs = [Request(uid=i, prompt=p, max_new=5) for i, p in
            enumerate(prompts)]
    want = _batch_ref(cfg, params, reqs, buckets=(8, 16))

    eng = _engine(cfg, params, buckets=(8, 16), chunked_prefill=True)
    orig = eng._exec_chunked

    def exec_then_cancel(plan, extras):
        res = orig(plan, extras)
        assert eng.cancel(0, reason="client gone mid-prefill")
        return res

    eng._exec_chunked = exec_then_cancel
    run = [Request(uid=i, prompt=p, max_new=5) for i, p in
           enumerate(prompts)]
    rounds_before = eng._round
    eng.run(run)
    assert run[0].done and run[0].finish_reason == "cancel"
    assert run[0].generated == []             # evicted before first token
    assert run[1].done and tuple(run[1].generated) == want[1]
    assert run[2].done and tuple(run[2].generated) == want[2]
    assert eng.stats["cancelled"] == 1
    assert eng.stats["replica_occupancy"] == [0]
    assert eng._free_total() == eng.slots
    assert eng._round > rounds_before         # and the run kept going


def test_cancel_of_finished_or_unknown_uid_is_noop(small_model):
    cfg, m, params = small_model
    eng = _engine(cfg, params)
    req = Request(uid=7, prompt=_prompts(cfg, [5])[0], max_new=3)
    eng.run([req])
    assert req.done and req.finish_reason == "complete"
    before = dict(eng.stats)
    assert eng.cancel(7) is False             # finished: no-op
    assert eng.cancel(999) is False           # never existed: no-op
    assert eng.stats == before
    assert req.finish_reason == "complete"    # untouched


def test_submit_after_drain_rejected_with_typed_error(small_model):
    cfg, m, params = small_model
    eng = _engine(cfg, params)
    svc = ServeService(eng, max_pending=8).start()
    s = svc.submit(_prompts(cfg, [5])[0], max_new=3)
    svc.request_drain()
    with pytest.raises(EngineDraining):
        svc.submit(_prompts(cfg, [4])[0], max_new=3)
    with pytest.raises(EngineDraining):
        eng.submit(Request(uid=99, prompt=np.array([1, 2], np.int32),
                           max_new=2))
    with pytest.raises(EngineDraining):
        eng.run([Request(uid=98, prompt=np.array([1], np.int32), max_new=2)])
    svc.join(60)
    toks, fin, _ = s.result(timeout=10)
    assert fin in ("drain", "complete")       # drained or just finished
    assert svc.error is None


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


def test_deadline_evicts_only_own_request_round_clock(small_model):
    """Deadlines on a deterministic round-counter clock: the expiring
    request is evicted alone (typed 'deadline' finish, counted), peers
    run to completion bit-exactly, the slot comes back."""
    cfg, m, params = small_model
    prompts = _prompts(cfg, [5, 9, 7])
    ref = _batch_ref(cfg, params, [Request(uid=i, prompt=p, max_new=10)
                                   for i, p in enumerate(prompts)])
    eng = _engine(cfg, params)
    eng._clock = lambda: float(eng._round)    # rounds, not wall time
    reqs = [Request(uid=0, prompt=prompts[0], max_new=10),
            Request(uid=1, prompt=prompts[1], max_new=10, deadline=3.0),
            Request(uid=2, prompt=prompts[2], max_new=10)]
    eng.run(reqs)
    assert reqs[1].done and reqs[1].finish_reason == "deadline"
    assert 0 < len(reqs[1].generated) < 10
    assert tuple(reqs[1].generated) == ref[1][:len(reqs[1].generated)]
    assert tuple(reqs[0].generated) == ref[0]
    assert tuple(reqs[2].generated) == ref[2]
    assert eng.stats["deadline_expired"] == 1
    assert eng.stats["cancelled"] == 0        # separate counters
    assert eng._free_total() == eng.slots


def test_deadline_through_service_submit(small_model):
    cfg, m, params = small_model
    eng = _engine(cfg, params)
    eng._clock = lambda: float(eng._round)
    svc = ServeService(eng, max_pending=8).start()
    s_ok = svc.submit(_prompts(cfg, [5])[0], max_new=8)
    s_dl = svc.submit(_prompts(cfg, [9], seed=1)[0], max_new=64,
                      deadline_s=4.0)
    toks_dl, fin_dl, err_dl = s_dl.result(timeout=300)
    toks_ok, fin_ok, _ = s_ok.result(timeout=300)
    svc.stop()
    assert fin_dl == "deadline" and "deadline" in err_dl
    assert len(toks_dl) < 64
    assert fin_ok == "complete" and len(toks_ok) == 8
    assert eng.stats["deadline_expired"] == 1


# ---------------------------------------------------------------------------
# injected ingress faults: disconnect + slow consumer
# ---------------------------------------------------------------------------


def test_injected_disconnect_and_slow_consumer(small_model):
    cfg, m, params = small_model
    plan = FaultPlan(disconnect_uid=0, disconnect_after=2,
                     stall_uid=1, stall_cap=2)
    eng = _engine(cfg, params, fault=plan.injector())
    svc = ServeService(eng, max_pending=8).start()
    s_disc = svc.submit(_prompts(cfg, [5])[0], max_new=16)
    s_stall = svc.submit(_prompts(cfg, [7], seed=1)[0], max_new=16)
    assert (s_disc.uid, s_stall.uid) == (0, 1)
    # disconnect: consumer drains normally but the injected client drop
    # cancels after 2 delivered tokens
    toks, fin, _ = s_disc.result(timeout=300)
    assert fin == "disconnect" and len(toks) <= 3
    # slow consumer: NOBODY drains this stream; the bounded buffer (cap 2
    # via stream_cap) overflows and the service cancels the request
    _wait(lambda: s_stall.finished, timeout=300)
    toks, fin = s_stall.drain()
    assert fin[0] == "slow_consumer" and "overflowed" in fin[1]
    assert len(toks) <= 2                     # nothing past the cap
    svc.stop()
    assert eng.stats["cancelled"] == 2
    assert eng._free_total() == eng.slots


# ---------------------------------------------------------------------------
# HTTP layer
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def _http(svc):
    fe = HttpFrontend(svc)
    ready = threading.Event()
    box = {}

    def run():
        async def amain():
            await fe.start()
            box["loop"] = asyncio.get_running_loop()
            box["stop"] = asyncio.Event()
            ready.set()
            await box["stop"].wait()
            await fe.stop()

        asyncio.run(amain())

    t = threading.Thread(target=run, daemon=True)
    t.start()
    assert ready.wait(30)
    try:
        yield fe
    finally:
        box["loop"].call_soon_threadsafe(box["stop"].set)
        t.join(10)


def _req(port, method, path, body=None, timeout=300):
    c = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    try:
        c.request(method, path, None if body is None else json.dumps(body),
                  {"Content-Type": "application/json"})
        r = c.getresponse()
        return r.status, r.read(), dict(r.getheaders())
    finally:
        c.close()


def _sse(port, body, timeout=300):
    """POST a stream=true completion; return (tokens, finish_event)."""
    c = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    try:
        c.request("POST", "/v1/completions", json.dumps(body),
                  {"Content-Type": "application/json"})
        r = c.getresponse()
        assert r.status == 200, r.read()
        toks, fin, saw_done = [], None, False
        for raw in r.fp:
            line = raw.decode().strip()
            if not line.startswith("data: "):
                continue
            if line == "data: [DONE]":
                saw_done = True
                break
            d = json.loads(line[6:])
            if "token" in d:
                assert d["index"] == len(toks)
                toks.append(d["token"])
            else:
                fin = d
        assert saw_done
        return toks, fin
    finally:
        c.close()


def test_http_endpoints_roundtrip(small_model):
    cfg, m, params = small_model
    prompts = _prompts(cfg, [5, 9])
    want = _batch_ref(cfg, params,
                      [Request(uid=i, prompt=p, max_new=5)
                       for i, p in enumerate(prompts)])
    eng = _engine(cfg, params)
    svc = ServeService(eng, max_pending=8).start()
    with _http(svc) as fe:
        st, body, _ = _req(fe.port, "GET", "/healthz")
        assert st == 200 and json.loads(body)["status"] == "serving"
        st, body, _ = _req(fe.port, "GET", "/v1/stats")
        stats = json.loads(body)
        assert {"shed", "completed", "watermark", "round"} <= set(stats)
        # non-streaming completion: exact batch tokens
        st, body, _ = _req(fe.port, "POST", "/v1/completions",
                           {"prompt": prompts[0].tolist(), "max_tokens": 5})
        out = json.loads(body)
        assert st == 200 and tuple(out["tokens"]) == want[0]
        assert out["finish_reason"] == "complete"
        # SSE: same tokens, one event each, typed finish, [DONE]
        toks, fin = _sse(fe.port, {"prompt": prompts[1].tolist(),
                                   "max_tokens": 5, "stream": True})
        assert tuple(toks) == want[1]
        assert fin["finish_reason"] == "complete" and fin["error"] is None
        # typed client errors
        st, body, _ = _req(fe.port, "POST", "/v1/completions",
                           {"max_tokens": 5})
        assert st == 400                      # no prompt
        st, body, _ = _req(fe.port, "POST", "/v1/completions",
                           {"prompt": list(range(500)), "max_tokens": 2})
        assert st == 400                      # oversized for every bucket
        st, _, _ = _req(fe.port, "GET", "/nope")
        assert st == 404
        # draining -> 503 with the drain state visible on healthz
        svc.request_drain()
        st, body, _ = _req(fe.port, "POST", "/v1/completions",
                           {"prompt": [1, 2], "max_tokens": 2})
        assert st == 503
        st, body, _ = _req(fe.port, "GET", "/healthz")
        assert json.loads(body)["status"] == "draining"
    svc.join(60)
    assert svc.error is None


def test_http_overload_returns_429_with_retry_after(small_model):
    cfg, m, params = small_model
    eng = _engine(cfg, params, slots=2, buckets=(8,))
    svc = ServeService(eng, max_pending=2, retry_after=0.7)
    p = _prompts(cfg, [4])[0]
    svc.submit(p, max_new=4)                  # service not started: the
    svc.submit(p, max_new=4)                  # queue sits at the watermark
    with _http(svc) as fe:
        st, body, hdrs = _req(fe.port, "POST", "/v1/completions",
                              {"prompt": p.tolist(), "max_tokens": 4})
        assert st == 429
        assert hdrs.get("Retry-After") == "0.7"
        assert "shed" in json.loads(body)["error"]
        assert eng.stats["shed"] == 1
        svc.start()
        _wait(lambda: json.loads(_req(fe.port, "GET", "/v1/stats")[1])
              ["completed"] == 2, timeout=300)
    svc.stop()


def test_http_client_disconnect_cancels_request(small_model):
    cfg, m, params = small_model
    eng = _engine(cfg, params, fault=_SlowDecode())
    svc = ServeService(eng, max_pending=8).start()
    with _http(svc) as fe:
        # raw socket: http.client hides its fd once the server announces
        # Connection: close, and this test needs an abrupt client close
        body = json.dumps({"prompt": _prompts(cfg, [5])[0].tolist(),
                           "max_tokens": 512, "stream": True})
        s = socket.create_connection(("127.0.0.1", fe.port), timeout=300)
        s.sendall((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                   f"Content-Type: application/json\r\n"
                   f"Content-Length: {len(body)}\r\n\r\n{body}").encode())
        buf = b""
        while b'"token"' not in buf:           # at least one token flowed
            chunk = s.recv(4096)
            assert chunk, f"stream closed early: {buf!r}"
            buf += chunk
        s.close()                             # client hangs up mid-stream
        # the connection watcher turns EOF into cancel(uid): the slot
        # comes back and the cancel is counted as a disconnect
        _wait(lambda: eng.stats["cancelled"] == 1
              and eng._free_total() == eng.slots, timeout=300)
    req = eng.finished[-1]
    assert req.finish_reason == "disconnect"
    assert len(req.generated) < 512
    svc.stop()


# ---------------------------------------------------------------------------
# SIGTERM during live HTTP traffic -> drain -> snapshot -> --resume
# ---------------------------------------------------------------------------


def _launch_env():
    env = dict(os.environ, PYTHONPATH="src")
    base = env.get("JAX_COMPILATION_CACHE_DIR")
    if base:
        env["JAX_COMPILATION_CACHE_DIR"] = os.path.join(base, "service")
    return env


def test_sigterm_under_live_http_traffic_snapshots_then_resumes(small_model):
    """launch/serve --http: SIGTERM while an SSE stream is mid-request
    drains at a round boundary (client sees a typed 'drain' finish),
    snapshots, exits 0; a --resume run regenerates the interrupted
    request token-for-token (prefix already streamed + resumed tokens ==
    the uninterrupted reference)."""
    cfg, m, params = small_model
    prompt = _prompts(cfg, [6])[0]
    ref_req = Request(uid=0, prompt=prompt, max_new=96)
    want = _batch_ref(cfg, params, [ref_req], max_len=128)[0]

    common = ["-m", "repro.launch.serve", "--arch", "stablelm-1.6b",
              "--reduced", "--slots", "4", "--max-len", "128",
              "--buckets", "8,16,32"]
    with tempfile.TemporaryDirectory() as td:
        snap = os.path.join(td, "drain.npy")
        proc = subprocess.Popen(
            [sys.executable, *common, "--http", "0", "--snapshot", snap],
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
            env=_launch_env(), cwd=REPO)
        try:
            port = None
            for line in proc.stdout:
                mo = re.search(r"serving HTTP on 127\.0\.0\.1:(\d+)", line)
                if mo:
                    port = int(mo.group(1))
                    break
            assert port, "server never reported its port"
            c = http.client.HTTPConnection("127.0.0.1", port, timeout=600)
            c.request("POST", "/v1/completions",
                      json.dumps({"prompt": prompt.tolist(),
                                  "max_tokens": 96, "stream": True}),
                      {"Content-Type": "application/json"})
            r = c.getresponse()
            assert r.status == 200
            first = r.fp.readline()           # live traffic: token flowing
            assert b"token" in first
            proc.send_signal(signal.SIGTERM)  # preempt mid-stream
            streamed, fin = [json.loads(first[6:])["token"]], None
            for raw in r.fp:
                line = raw.decode().strip()
                if not line.startswith("data: ") or line == "data: [DONE]":
                    continue
                d = json.loads(line[6:])
                if "token" in d:
                    streamed.append(d["token"])
                else:
                    fin = d
            c.close()
            out, _ = proc.communicate(timeout=600)
        finally:
            proc.kill()
        assert proc.returncode == 0, out[-3000:]
        assert tuple(streamed) == want[:len(streamed)]

        if fin is not None and fin["finish_reason"] == "drain":
            # the interesting path: preempted mid-request -> the snapshot
            # must exist and --resume must regenerate uid 0 token-exactly
            assert len(streamed) < 96
            assert os.path.exists(snap), out[-3000:]
            res = subprocess.run(
                [sys.executable, *common, "--resume", snap],
                capture_output=True, text=True, env=_launch_env(),
                cwd=REPO, timeout=600)
            assert res.returncode == 0, res.stderr[-3000:]
            assert "resuming 1 unfinished" in res.stdout
            mo = re.search(r"req 0: \[([\d, ]*)\]", res.stdout)
            assert mo, res.stdout[-2000:]
            resumed = tuple(int(x) for x in mo.group(1).split(",") if
                            x.strip())
            assert resumed == want
        else:
            # the request beat the signal: it must then be COMPLETE with
            # the full reference stream (still pins token-exact serving
            # under a drain racing live traffic)
            assert fin is not None and fin["finish_reason"] == "complete"
            assert tuple(streamed) == want
