"""HLO scaled-cost analyzer + PDQ-int8 linop tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.launch.hlo_analysis import analyze
from repro.models.linops import is_quantized, lin, quantize_param_tree, quantize_weight


def _count_pallas_calls(jaxpr) -> int:
    """Recursively count pallas_call eqns in a (Closed)Jaxpr."""
    if hasattr(jaxpr, "jaxpr"):              # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    n += _count_pallas_calls(sub)
    return n


def test_analyzer_scales_scan_bodies():
    """A scan of 10 matmuls must report ~10x one matmul's flops."""
    w = jnp.ones((64, 64))

    def one(x):
        return x @ w

    def scanned(x):
        def body(c, _):
            return c @ w, ()
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jnp.ones((32, 64))
    f1 = analyze(jax.jit(one).lower(x).compile().as_text()).dot_flops
    f10 = analyze(jax.jit(scanned).lower(x).compile().as_text()).dot_flops
    assert f1 > 0
    ratio = f10 / f1
    assert 8.0 <= ratio <= 12.0, ratio


def test_analyzer_flops_value():
    x = jnp.ones((128, 256))
    w = jnp.ones((256, 512))
    f = analyze(jax.jit(lambda a, b: a @ b).lower(x, w).compile().as_text())
    want = 2 * 128 * 256 * 512
    assert abs(f.dot_flops - want) / want < 0.05


def test_quantize_weight_record_and_lin():
    key = jax.random.PRNGKey(0)
    w = 0.1 * jax.random.normal(key, (128, 64))
    rec = quantize_weight(w)
    assert is_quantized(rec)
    assert rec["q"].dtype == jnp.int8
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 128))
    y_fp = lin(x, w)
    y_q = lin(x, rec)
    rel = float(jnp.abs(y_q - y_fp).mean() / jnp.abs(y_fp).mean())
    assert rel < 0.05, rel


def test_lin_quantized_is_one_prologue_one_matmul():
    """The fused serving path must trace to EXACTLY two kernels: the pdq
    prologue and the W8A8 matmul - no separate amax / quantize / act_stats
    launches and no requant->dequant pair on the output."""
    rec = quantize_weight(0.1 * jax.random.normal(jax.random.PRNGKey(0), (128, 128)))
    x = jnp.ones((8, 128))
    ops.set_impl("kernel")
    try:
        jaxpr = jax.make_jaxpr(lambda t: lin(t, rec))(x)
    finally:
        ops.set_impl("auto")
    n = _count_pallas_calls(jaxpr)
    assert n == 2, f"expected prologue + matmul, traced {n} pallas_calls"


def test_quantize_param_tree_selects_matrices_only():
    params = {"attn": {"wq": jnp.ones((32, 32)), "norm": jnp.ones((32,))},
              "embed": {"embedding": jnp.ones((100, 32))},
              "blocks": {"we_gate": jnp.ones((4, 32, 16))}}
    out = quantize_param_tree(params)
    assert is_quantized(out["attn"]["wq"])
    assert not is_quantized(out["attn"]["norm"])
    assert not is_quantized(out["embed"]["embedding"])   # embeddings stay fp
    assert not is_quantized(out["blocks"]["we_gate"])    # 3-D stacks stay fp
