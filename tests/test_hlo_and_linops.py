"""HLO scaled-cost analyzer + PDQ-int8 linop tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.launch.hlo_analysis import analyze
from repro.models.linops import (is_quantized, lin, lin_grouped,
                                 quantize_param_tree, quantize_weight)


def _count_pallas_calls(jaxpr) -> int:
    """Recursively count pallas_call eqns in a (Closed)Jaxpr."""
    if hasattr(jaxpr, "jaxpr"):              # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    n += _count_pallas_calls(sub)
    return n


def test_analyzer_scales_scan_bodies():
    """A scan of 10 matmuls must report ~10x one matmul's flops."""
    w = jnp.ones((64, 64))

    def one(x):
        return x @ w

    def scanned(x):
        def body(c, _):
            return c @ w, ()
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jnp.ones((32, 64))
    f1 = analyze(jax.jit(one).lower(x).compile().as_text()).dot_flops
    f10 = analyze(jax.jit(scanned).lower(x).compile().as_text()).dot_flops
    assert f1 > 0
    ratio = f10 / f1
    assert 8.0 <= ratio <= 12.0, ratio


def test_analyzer_flops_value():
    x = jnp.ones((128, 256))
    w = jnp.ones((256, 512))
    f = analyze(jax.jit(lambda a, b: a @ b).lower(x, w).compile().as_text())
    want = 2 * 128 * 256 * 512
    assert abs(f.dot_flops - want) / want < 0.05


def test_quantize_weight_record_and_lin():
    key = jax.random.PRNGKey(0)
    w = 0.1 * jax.random.normal(key, (128, 64))
    rec = quantize_weight(w)
    assert is_quantized(rec)
    assert rec["q"].dtype == jnp.int8
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 128))
    y_fp = lin(x, w)
    y_q = lin(x, rec)
    rel = float(jnp.abs(y_q - y_fp).mean() / jnp.abs(y_fp).mean())
    assert rel < 0.05, rel


def test_lin_quantized_is_one_prologue_one_matmul():
    """The fused serving path must trace to EXACTLY two kernels: the pdq
    prologue and the W8A8 matmul - no separate amax / quantize / act_stats
    launches and no requant->dequant pair on the output."""
    rec = quantize_weight(0.1 * jax.random.normal(jax.random.PRNGKey(0), (128, 128)))
    x = jnp.ones((8, 128))
    ops.set_impl("kernel")
    try:
        jaxpr = jax.make_jaxpr(lambda t: lin(t, rec))(x)
    finally:
        ops.set_impl("auto")
    n = _count_pallas_calls(jaxpr)
    assert n == 2, f"expected prologue + matmul, traced {n} pallas_calls"


def test_quantize_param_tree_selects_matrices_only():
    params = {"attn": {"wq": jnp.ones((32, 32)), "norm": jnp.ones((32,))},
              "embed": {"embedding": jnp.ones((100, 32))},
              "blocks": {"we_gate": jnp.ones((4, 32, 16))}}
    out = quantize_param_tree(params)
    assert is_quantized(out["attn"]["wq"])
    assert not is_quantized(out["attn"]["norm"])
    assert not is_quantized(out["embed"]["embedding"])   # embeddings stay fp
    assert not is_quantized(out["blocks"]["we_gate"])    # 3-D stacks stay fp


def test_quantize_param_tree_groups_sibling_sets():
    """wq/wk/wv (and w_gate/w_up) collapse to ONE grouped record; each
    sibling key holds a segment view so the tree structure is unchanged."""
    key = jax.random.PRNGKey(0)
    d = 128

    def w(i, n):
        return 0.1 * jax.random.normal(jax.random.fold_in(key, i), (d, n))

    params = {"attn": {"wq": w(0, 128), "wk": w(1, 64), "wv": w(2, 64),
                       "wo": w(3, d)},
              "ffn": {"w_gate": w(4, 256), "w_up": w(5, 256),
                      "w_down": jnp.transpose(w(6, 256))},
              "cross": {"wq": w(7, 128), "wk": w(8, 64), "wv": w(9, 64),
                        "wo": w(10, d)}}
    out = quantize_param_tree(params)
    # siblings share one group record, in declaration order
    for k in ("wq", "wk", "wv"):
        assert is_quantized(out["attn"][k]) and "group" in out["attn"][k]
    segs = out["attn"]["wq"]["group"]["segs"]
    assert segs.sizes == (128, 64, 64)
    assert all(out["attn"][k]["group"]["segs"] == segs
               for k in ("wq", "wk", "wv"))
    assert [out["attn"][k]["seg"].index for k in ("wq", "wk", "wv")] == [0, 1, 2]
    # non-sibling leaves stay per-projection records
    assert "q" in out["attn"]["wo"] and "q" in out["ffn"]["w_down"]
    assert out["ffn"]["w_gate"]["group"]["segs"].sizes == (256, 256)
    # cross-attention: wk/wv read the encoder memory, wq the decoder stream
    assert out["cross"]["wk"]["group"]["segs"].sizes == (64, 64)
    assert "q" in out["cross"]["wq"]
    # different layers' groups are never interchangeable
    assert out["attn"]["wq"]["group"]["segs"] != out["ffn"]["w_gate"]["group"]["segs"]
    # a segment view still answers plain lin(), matching the ungrouped record
    x = jax.random.normal(jax.random.fold_in(key, 42), (4, d))
    y_view = lin(x, out["attn"]["wk"])
    y_rec = lin(x, quantize_weight(params["attn"]["wk"]))
    np.testing.assert_allclose(np.asarray(y_view), np.asarray(y_rec),
                               rtol=1e-5, atol=1e-5)


def test_lin_grouped_falls_back_per_projection():
    """Any unquantized / ungrouped member routes through per-projection lin
    with identical numerics."""
    key = jax.random.PRNGKey(1)
    w1 = 0.1 * jax.random.normal(key, (64, 32))
    w2 = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (64, 48))
    x = jax.random.normal(jax.random.fold_in(key, 2), (4, 64))
    # fp weights: exact fallback
    y1, y2 = lin_grouped(x, (w1, w2))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(x @ w1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(x @ w2), rtol=1e-6)
    # mixed quantized/fp: still per-projection
    r1 = quantize_weight(w1)
    y1q, y2f = lin_grouped(x, (r1, w2))
    np.testing.assert_allclose(np.asarray(y1q), np.asarray(lin(x, r1)), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(y2f), np.asarray(x @ w2), rtol=1e-6)


def _decode_block_census(quant_kv: str) -> int:
    """Trace a full quantized GQA decode block (attn norm -> QKV -> attend ->
    wo, ffn norm -> gate/up -> down) under kernel impl and count
    pallas_calls."""
    from repro.models.attention import AttnDims, gqa_apply, gqa_init, init_cache
    from repro.models.layers import mlp_apply, mlp_init, rms_norm

    dims = AttnDims(d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
                    quant_kv=quant_kv)
    key = jax.random.PRNGKey(0)
    params = {"attn": gqa_init(key, dims, jnp.float32),
              "attn_norm": jnp.zeros((256,)),
              "ffn_norm": jnp.zeros((256,)),
              "ffn": mlp_init(jax.random.fold_in(key, 1), 256, 512, jnp.float32)}
    qp = quantize_param_tree(params)
    cache = init_cache(dims, 8, 64, jnp.float32)

    def block(p, h, cache, positions):
        a, cache = gqa_apply(p["attn"], dims, rms_norm(h, p["attn_norm"]),
                             positions, mode="decode", cache=cache)
        h = h + a
        return h + mlp_apply(p["ffn"], rms_norm(h, p["ffn_norm"])), cache

    h = jnp.ones((8, 1, 256))
    pos = jnp.zeros((8, 1), jnp.int32)
    ops.set_impl("kernel")
    try:
        jaxpr = jax.make_jaxpr(block)(qp, h, cache, pos)
    finally:
        ops.set_impl("auto")
    return _count_pallas_calls(jaxpr)


def test_quantized_gqa_decode_block_is_seven_kernels():
    """A full quantized GQA decode block (fp KV cache) must trace to EXACTLY
    7 pallas_calls: one prologue + one wide matmul for each of the grouped
    QKV triple and the wo projection, plus the fused SwiGLU MLP triple
    (the gate/up matmul's epilogue computes silu(g)*u AND w_down's PDQ
    prologue, so no standalone prologue launch runs between the MLP
    matmuls).  A regression to per-projection dispatch would trace 14;
    losing the SwiGLU fusion regresses to 8 (tools/check_census.py pins
    the same table in the lint job)."""
    n = _decode_block_census("none")
    assert n == 7, f"expected 7 pallas_calls per quantized decode block, got {n}"


def test_quantized_gqa_decode_block_int8kv_is_seven_kernels():
    """The int8-KV decode block also traces to EXACTLY 7 pallas_calls: the
    flash-decode attend kernel's output stage emits the wo projection's
    PDQ prologue (decode_attend_i8kv_fused_p), so wo costs ONE W8A8
    matmul launch - QKV pair + fused attend + wo matmul + fused MLP
    triple.  Losing the attend fold regresses to 9 (attend + wo
    prologue + wo matmul)."""
    n = _decode_block_census("dynamic")
    assert n == 7, f"expected 7 pallas_calls per int8-KV decode block, got {n}"
