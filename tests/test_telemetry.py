"""Serving telemetry plane (serve/telemetry.py) + its engine hook points.

Pins the observability contract:
  * the metrics registry renders VALID Prometheus text exposition 0.0.4:
    HELP/TYPE lines, cumulative ``_bucket{le=...}`` series ending in +Inf,
    ``_sum``/``_count``, label escaping of backslash/quote/newline;
  * histograms never lose observations through any observe/merge
    interleaving (hypothesis property: sum(counts) == count == total
    observations, sum preserved exactly);
  * the tracer exports Chrome-trace-event JSON Perfetto accepts: every
    span is a "X" complete event with numeric ts/dur and int pid/tid, and
    process/thread metadata rows name every (pid, tid) in the trace;
  * a served engine populates the standard series (TTFT, per-token, queue
    wait, launch wall time, round occupancy, pdq health) and ``GET
    /metrics`` + ``GET /v1/events`` serve them over the front door;
  * /v1/stats and /metrics survive a concurrent scrape storm racing the
    serving loop (the PR-9 snapshot-under-lock fix - list-valued counters
    used to be serialized while the loop thread resized them);
  * the device-side pdq collector counts clip saturation and guard
    fallbacks without adding pallas_calls (census pinned elsewhere).
"""
import http.client
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypo_compat import given, settings, strategies as st

from test_serve_service import _http, _prompts, _req, _wait

from repro.configs import reduced_config
from repro.kernels import ops
from repro.models import build_model
from repro.models.linops import quantize_weight
from repro.serve import Request, ServeConfig, ServeService, build_engine
from repro.serve.telemetry import (LATENCY_BUCKETS, Histogram,
                                   MetricsRegistry, Telemetry, Tracer)


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced_config("stablelm-1.6b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _engine(cfg, params, **kw):
    kw.setdefault("slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("buckets", (8, 16, 32))
    return build_engine(ServeConfig(**kw), cfg=cfg, params=params)


# ---------------------------------------------------------------------------
# Prometheus exposition correctness
# ---------------------------------------------------------------------------


def test_prometheus_exposition_names_types_and_series():
    m = MetricsRegistry()
    m.counter("reqs_total", "requests seen").inc(3)
    m.gauge("pool_free", "free pages").set(41)
    h = m.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(7.0)
    text = m.render()
    lines = text.splitlines()
    assert text.endswith("\n")
    assert "# HELP reqs_total requests seen" in lines
    assert "# TYPE reqs_total counter" in lines
    assert "reqs_total 3" in lines
    assert "# TYPE pool_free gauge" in lines
    assert "pool_free 41" in lines
    assert "# TYPE lat_seconds histogram" in lines
    # cumulative buckets, +Inf == _count, integral values print as ints
    assert 'lat_seconds_bucket{le="0.1"} 1' in lines
    assert 'lat_seconds_bucket{le="1"} 2' in lines
    assert 'lat_seconds_bucket{le="+Inf"} 3' in lines
    assert "lat_seconds_count 3" in lines
    assert any(l.startswith("lat_seconds_sum 7.55") for l in lines)
    # families are sorted and every non-comment line belongs to a family
    fams = [l.split()[2] for l in lines if l.startswith("# TYPE")]
    assert fams == sorted(fams)


def test_prometheus_label_escaping_and_label_sets():
    m = MetricsRegistry()
    m.counter("c_total", "c", kind='we"ird\\path\nx').inc()
    m.counter("c_total", "c", kind="plain").inc(2)
    text = m.render()
    # one TYPE line, two children, escaped backslash/quote/newline
    assert text.count("# TYPE c_total counter") == 1
    assert 'c_total{kind="we\\"ird\\\\path\\nx"} 1' in text
    assert 'c_total{kind="plain"} 2' in text
    # same (name, labels) returns the same child
    assert m.counter("c_total", kind="plain").value == 2.0


def test_registry_is_shared_by_handle_and_lookup():
    tel = Telemetry(enabled=True)
    tel.ttft.observe(0.2)
    again = tel.metrics.histogram("serve_ttft_seconds")
    assert again is tel.ttft and again.count == 1
    text = tel.metrics.render()
    for name in ("serve_ttft_seconds", "serve_per_token_seconds",
                 "serve_queue_wait_seconds", "serve_round_occupancy",
                 "serve_shed_total", "pdq_fallbacks", "pdq_clip_hits",
                 "pdq_clip_total", "pdq_clip_rate"):
        assert f"# TYPE {name}" in text, name


def test_disabled_telemetry_renders_empty_and_spans_are_noops():
    tel = Telemetry(enabled=False, trace=True)
    assert tel.metrics.render() == "\n"
    with tel.span("launch:decode"):
        pass
    assert tel.tracer.events() == []
    assert tel.summary() == {}
    tel.observe_pdq(1, 2, 3)          # must not raise, must not record
    assert tel.metrics.render() == "\n"


# ---------------------------------------------------------------------------
# histogram properties
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(groups=st.lists(st.lists(st.floats(min_value=0.0, max_value=100.0,
                                          allow_nan=False), max_size=30),
                       min_size=1, max_size=6),
       data=st.data())
def test_histogram_observe_merge_never_loses_counts(groups, data):
    """Observations spread over several histograms and merged in any order
    conserve count, per-bucket counts and sum exactly."""
    parts = [Histogram(buckets=(0.5, 1.0, 5.0, 50.0)) for _ in groups]
    for h, vals in zip(parts, groups):
        for v in vals:
            h.observe(v)
    total = Histogram(buckets=(0.5, 1.0, 5.0, 50.0))
    order = data.draw(st.permutations(range(len(parts))))
    for i in order:
        total.merge(parts[i])
    all_vals = [v for vals in groups for v in vals]
    assert total.count == len(all_vals)
    assert sum(total.counts) == total.count
    assert total.sum == pytest.approx(sum(all_vals))
    # bucket membership matches a direct histogram of the same values
    direct = Histogram(buckets=(0.5, 1.0, 5.0, 50.0))
    for v in all_vals:
        direct.observe(v)
    assert total.counts == direct.counts


def test_histogram_percentiles_bracket_the_data():
    h = Histogram(buckets=LATENCY_BUCKETS)
    assert h.percentile(0.5) == 0.0           # empty: defined, zero
    for v in [0.002] * 90 + [0.2] * 10:
        h.observe(v)
    assert 0.001 <= h.percentile(0.50) <= 0.0025
    assert 0.1 <= h.percentile(0.99) <= 0.25
    h2 = Histogram(buckets=(1.0,))
    h2.observe(100.0)                         # overflow bucket
    assert h2.percentile(0.99) == 1.0         # reports the edge


def test_histogram_merge_rejects_mismatched_buckets():
    with pytest.raises(AssertionError):
        Histogram(buckets=(1.0,)).merge(Histogram(buckets=(2.0,)))


# ---------------------------------------------------------------------------
# tracer: Chrome trace-event JSON schema
# ---------------------------------------------------------------------------


def test_tracer_exports_valid_chrome_trace():
    clock = iter(np.arange(0.0, 10.0, 0.001))
    tr = Tracer(enabled=True, pid=0, clock=lambda: next(clock))
    with tr.span("launch:decode", cat="phase", tid=2, rows=4):
        pass
    tr.add("launch:prefill", ts=100.0, dur=250.0, pid=1, tid=2,
           args={"process": 1})
    tr.name_process(1, "jax process 1")
    tr.name_thread(1, 2, "launch")
    obj = json.loads(json.dumps(tr.export()))    # JSON-serializable
    evs = obj["traceEvents"]
    spans = [e for e in evs if e["ph"] == "X"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert len(spans) == 2
    for e in spans:
        assert isinstance(e["name"], str) and isinstance(e["cat"], str)
        assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0
        assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
    assert {e["pid"] for e in spans} == {0, 1}
    # metadata names every pid and every (pid, tid)
    proc_rows = {e["pid"] for e in meta if e["name"] == "process_name"}
    thread_rows = {(e["pid"], e["tid"]) for e in meta
                   if e["name"] == "thread_name"}
    assert {0, 1} <= proc_rows
    assert {(0, 2), (1, 2)} <= thread_rows
    named = {e["pid"]: e["args"]["name"] for e in meta
             if e["name"] == "process_name"}
    assert named[1] == "jax process 1"
    # args values are JSON primitives
    assert spans[1]["args"]["process"] == 1


def test_tracer_ring_is_bounded_and_counts_drops():
    clock = iter(np.arange(0.0, 10.0, 0.001))
    tr = Tracer(enabled=True, capacity=4, clock=lambda: next(clock))
    for i in range(10):
        tr.add(f"s{i}", ts=float(i), dur=1.0)
    assert len(tr.events()) == 4
    assert tr.dropped == 6
    assert tr.export()["otherData"]["dropped_spans"] == 6
    assert [e["name"] for e in tr.events()] == ["s6", "s7", "s8", "s9"]


# ---------------------------------------------------------------------------
# device-side pdq health collector (kernels/ops.pdq_telemetry)
# ---------------------------------------------------------------------------


def test_pdq_collector_counts_clip_and_fallbacks():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 256), jnp.float32)
    rec = quantize_weight(
        jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32))

    def prog(x):
        with ops.pdq_guard(), ops.pdq_telemetry() as col:
            y = ops.pdq_dense(x, rec)
            return y, col.summary()

    y, tel = jax.jit(prog)(x)
    fb, hits, total = np.asarray(tel)
    assert total == x.shape[0] * rec["q"].shape[1]    # every output checked
    assert 0 <= hits <= total
    assert fb == 0.0                                  # healthy fast path

    def poisoned(x):
        with ops.pdq_guard(), ops.pdq_fault(), ops.pdq_telemetry() as col:
            y = ops.pdq_dense(x, rec)
            return y, col.summary()

    y2, tel2 = jax.jit(poisoned)(x)
    assert np.asarray(tel2)[0] == 1.0                 # the guard fired once
    assert np.isfinite(np.asarray(y2)).all()


def test_pdq_collector_disabled_is_constant_zeros():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 256), jnp.float32)
    rec = quantize_weight(
        jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32))

    def prog(x):
        with ops.pdq_telemetry(enable=False) as col:
            return ops.pdq_dense(x, rec), col.summary()

    _, tel = jax.jit(prog)(x)
    assert np.asarray(tel).tolist() == [0.0, 0.0, 0.0]
    assert np.asarray(tel).shape == (ops.PDQ_TEL_WIDTH,)


# ---------------------------------------------------------------------------
# engine integration: standard series populated, trace spans emitted
# ---------------------------------------------------------------------------


def test_served_engine_populates_standard_series(small_model):
    cfg, m, params = small_model
    eng = _engine(cfg, params, trace=True)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                    max_new=4) for i, L in enumerate([3, 9, 12])]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    tel = eng.tel
    assert tel.ttft.count == 3
    assert tel.per_token.count == sum(len(r.generated) - 1 for r in reqs)
    assert tel.queue_wait.count == 3
    assert tel.round_occupancy.count > 0
    kinds = {k for labels, _ in
             tel.metrics.get("serve_launch_seconds").items()
             for lk, k in labels if lk == "kind"}
    assert {"prefill", "decode"} <= kinds
    summ = tel.summary()
    for key in ("ttft", "per_token", "queue_wait"):
        s = summ[key]
        assert s["count"] > 0 and 0 <= s["p50"] <= s["p90"] <= s["p99"]
    names = {e["name"] for e in tel.tracer.events()}
    assert {"plan:prefill", "launch:prefill", "apply:prefill",
            "plan:decode", "launch:decode", "apply:decode"} <= names
    assert any(n.startswith("req 0") for n in names)
    # request spans ride the request thread row with uid attribution
    req_spans = [e for e in tel.tracer.events() if e["tid"] == 0]
    assert all("uid" in (e.get("args") or {}) for e in req_spans)


def test_telemetry_disabled_engine_serves_identically(small_model):
    cfg, m, params = small_model
    rng = np.random.default_rng(0)
    lens = [3, 9, 12, 5]
    mk = lambda: [Request(uid=i, prompt=np.asarray(p), max_new=4)
                  for i, p in enumerate(_prompts(cfg, lens))]
    on = _engine(cfg, params, telemetry=True)
    off = _engine(cfg, params, telemetry=False)
    r_on, r_off = mk(), mk()
    on.run(r_on)
    off.run(r_off)
    assert ([tuple(r.generated) for r in r_on]
            == [tuple(r.generated) for r in r_off])
    assert off.tel.metrics.render() == "\n"


# ---------------------------------------------------------------------------
# front door: /metrics + /v1/events + the scrape storm
# ---------------------------------------------------------------------------


def test_metrics_and_events_endpoints(small_model):
    cfg, m, params = small_model
    eng = _engine(cfg, params)
    svc = ServeService(eng, max_pending=8).start()
    with _http(svc) as fe:
        streams = [svc.submit(p, max_new=4)
                   for p in _prompts(cfg, [5, 9, 30])]
        for s in streams:
            s.result(timeout=300)
        st, body, hdrs = _req(fe.port, "GET", "/metrics")
        assert st == 200
        assert hdrs.get("Content-Type", "").startswith("text/plain")
        text = body.decode()
        for name in ("serve_ttft_seconds_bucket", "serve_ttft_seconds_count",
                     "serve_per_token_seconds_sum",
                     "serve_queue_wait_seconds_count",
                     "serve_launch_seconds_bucket", "serve_round_occupancy",
                     "pdq_fallbacks", "pdq_clip_rate"):
            assert name in text, name
        assert 'serve_launch_seconds_bucket{kind="prefill"' in text
        assert "serve_ttft_seconds_count 3" in text
        st, body, hdrs = _req(fe.port, "GET", "/v1/events")
        assert st == 200
        events = [json.loads(l) for l in body.decode().splitlines()]
        assert all({"t", "step", "kind", "detail"} <= set(e)
                   for e in events)
    svc.stop()


def test_stats_and_metrics_survive_concurrent_scrape_storm(small_model):
    """Regression for the /v1/stats race: scrape threads hammer /v1/stats,
    /metrics and /v1/events while the loop thread serves a 3x-overload
    burst (list-valued stats resized per admission); every response must
    parse and no scrape may crash the serializer."""
    cfg, m, params = small_model
    eng = _engine(cfg, params, slots=2, buckets=(8,))
    svc = ServeService(eng, max_pending=4).start()
    errs: list = []
    stop = threading.Event()

    def scrape(path, check):
        while not stop.is_set():
            try:
                c = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
                c.request("GET", path)
                r = c.getresponse()
                check(r.status, r.read())
                c.close()
            except Exception as e:          # noqa: BLE001 - collect, assert
                errs.append((path, repr(e)))
                return

    with _http(svc) as fe:
        port = fe.port
        threads = [
            threading.Thread(target=scrape, args=(
                "/v1/stats",
                lambda s, b: (json.loads(b), )[0] if s == 200
                else errs.append(("status", s)))),
            threading.Thread(target=scrape, args=(
                "/metrics",
                lambda s, b: b.decode() if s == 200
                else errs.append(("status", s)))),
            threading.Thread(target=scrape, args=(
                "/v1/events",
                lambda s, b: [json.loads(l) for l in b.splitlines()]
                if s == 200 else errs.append(("status", s)))),
        ]
        for t in threads:
            t.start()
        streams = []
        for i in range(24):
            try:
                streams.append(svc.submit(
                    _prompts(cfg, [4 + i % 5], seed=i)[0], max_new=4))
            except Exception:
                pass                        # shed: part of the storm
        for s in streams:
            s.result(timeout=300)
        stop.set()
        for t in threads:
            t.join(60)
    svc.stop()
    assert not errs, errs[:5]
    snap = eng.stats_snapshot()
    assert snap["completed"] == len(streams)
    assert isinstance(snap["replica_admits"], list)
