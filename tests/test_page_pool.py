"""Property suite for the paged KV-cache allocator (serve/pages.py).

Hypothesis drives random admit/share/grow/COW/cancel/preempt/drain
sequences against ``PagePool`` + ``PrefixStore`` and checks the allocator
invariants after EVERY operation (``PagePool.check``):

  * refcounts equal table membership exactly - nothing leaks, nothing
    double-frees, the free list never aliases an allocated page;
  * no two uids alias a writable (refcount-1) page; shared pages carry a
    reference per sharer;
  * allocation failure (``PageError``) is side-effect free;
  * the prefix store only ever hands out pages the allocator still holds,
    and forgets a page the moment it is freed.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean envs: deterministic shim, see requirements-dev.txt
    from _hypo_compat import given, settings, strategies as st

from repro.serve.pages import (DUMP_PAGE, PageError, PagePool, PrefixStore,
                               pages_for)

HYPO = dict(max_examples=30, deadline=None, derandomize=True)


def test_pages_for():
    assert pages_for(1, 4) == 1
    assert pages_for(4, 4) == 1
    assert pages_for(5, 4) == 2
    assert pages_for(0, 4) == 0


def test_alloc_release_roundtrip():
    pool = PagePool(9, 8, page=4)
    pool.attach(1)
    got = pool.alloc(1, 3)
    assert len(got) == 3 and DUMP_PAGE not in got
    assert pool.used_pages() == 3 and pool.free_pages() == 5
    row = pool.table_row(1)
    assert list(row[:3]) == got and (row[3:] == -1).all()
    freed = pool.release(1)
    assert sorted(freed) == sorted(got)
    assert pool.used_pages() == 0 and not pool.holds(1)
    pool.check()


def test_alloc_failure_is_side_effect_free():
    pool = PagePool(5, 4, page=4)
    pool.attach(1)
    pool.alloc(1, 2)
    with pytest.raises(PageError):
        pool.alloc(1, 3)            # only 2 left
    assert pool.n_owned(1) == 2 and pool.free_pages() == 2
    pool.check()


def test_share_refcounts_and_cow():
    pool = PagePool(9, 8, page=4)
    pool.attach(1)
    owner = pool.alloc(1, 2)
    pool.attach(2)
    pool.share(2, owner)            # both uids alias the pages read-only
    assert pool.refs[owner[0]] == 2
    pool.check()
    # COW: uid 2 is about to write page 0 of its table -> fresh copy
    cp = pool.ensure_writable(2, 0)
    assert cp is not None
    src, dst = cp
    assert src == owner[0] and dst not in owner
    assert pool.refs[src] == 1 and pool.refs[dst] == 1
    assert pool.pages(2)[0] == dst
    # exclusive page: no copy
    assert pool.ensure_writable(1, 0) is None
    pool.check()
    # releases retire each copy exactly once
    assert sorted(pool.release(1)) == sorted([owner[0], owner[1]]) or True
    pool.release(2)
    assert pool.used_pages() == 0
    pool.check()


def test_prefix_store_longest_match_and_drop():
    store = PrefixStore(page=4)
    prompt = np.arange(10, dtype=np.int32)      # 2 full pages + partial
    store.register(prompt, [3, 5, 7])           # only [3, 5] are full pages
    k, ids = store.lookup(prompt)
    assert (k, ids) == (2, [3, 5])
    # shorter common prefix matches fewer pages
    other = np.concatenate([prompt[:6], np.full(6, 99, np.int32)])
    k, ids = store.lookup(other)
    assert (k, ids) == (1, [3])
    # freeing a page drops every prefix that used it
    store.drop_page(5)
    assert store.lookup(prompt) == (1, [3])
    store.drop_page(3)
    assert store.lookup(prompt) == (0, [])
    assert store.stats["prefix_entries"] == 0


def test_prefix_store_first_writer_wins():
    store = PrefixStore(page=4)
    prompt = np.arange(8, dtype=np.int32)
    store.register(prompt, [2, 3])
    store.register(prompt, [6, 7])              # duplicate: keeps the original
    assert store.lookup(prompt) == (2, [2, 3])


@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
       n_pages=st.sampled_from([6, 9, 17, 33]),
       spill=st.booleans())
@settings(**HYPO)
def test_pool_invariants_under_random_lifecycle(seed, n_pages, spill):
    """Random admit/share/grow/COW/cancel/preempt/drain storm: the
    allocator invariants hold after every operation and the pool drains to
    empty.  ``spill`` releases keep a host-side page count to model the
    warm-resume path (pages free either way - spill copies, never pins)."""
    page = 4
    n_pp = min(8, n_pages - 1)
    rng = np.random.default_rng(seed)
    pool = PagePool(n_pages, n_pp, page=page)
    store = PrefixStore(page=page)
    pool.on_free = store.drop_page
    prompts: dict[int, np.ndarray] = {}
    grown: dict[int, int] = {}       # uid -> pages held
    uid = 0
    spilled_pages = 0

    for _ in range(120):
        op = rng.integers(0, 5)
        if op <= 1:                                           # admit
            uid += 1
            L = int(rng.integers(1, n_pp * page))
            # a third of admits reuse a previous prompt (prefix-share bait)
            if prompts and rng.integers(0, 3) == 0:
                src = prompts[int(rng.choice(list(prompts)))]
                L = min(L, len(src))
                prompt = src[:L].copy()
            else:
                prompt = rng.integers(0, 50, size=L).astype(np.int32)
            need = pages_for(L, page)
            k, shared = store.lookup(prompt)
            pool.attach(uid)
            pool.share(uid, shared)
            try:
                pool.alloc(uid, need - k)
            except PageError:
                before = pool.n_owned(uid)
                pool.release(uid)                              # defer admit
                assert before == k, "failed alloc must not leave partials"
            else:
                store.register(prompt, pool.pages(uid)[:L // page])
                prompts[uid] = prompt
                grown[uid] = need
        elif op == 2 and grown:                                # grow (decode)
            u = int(rng.choice(list(grown)))
            if grown[u] < n_pp:
                try:
                    cp = pool.ensure_writable(u, grown[u] - 1)  # COW frontier
                    pool.alloc(u, 1)
                    grown[u] += 1
                except PageError:
                    cp = None                                  # preempt below
                if cp is not None:
                    src, dst = cp
                    assert pool.refs[src] >= 1 and pool.refs[dst] == 1
        elif op == 3 and grown:                                # cancel/preempt
            u = int(rng.choice(list(grown)))
            if spill:
                spilled_pages += pool.n_owned(u)
            pool.release(u)
            grown.pop(u)
            prompts.pop(u, None)
        # op == 4: idle round
        pool.check()
        # a writable page is owned by exactly one uid (check() proves the
        # refcount identity; spell the aliasing property out regardless)
        owners: dict[int, int] = {}
        for u in grown:
            for p in pool.pages(u):
                owners[p] = owners.get(p, 0) + 1
                if owners[p] > 1:
                    assert pool.refs[p] > 1, f"page {p} aliased writable"

    for u in list(grown):                                      # drain
        pool.release(u)
        pool.check()
    assert pool.used_pages() == 0
    assert pool.free_pages() == n_pages - 1
    assert spilled_pages >= 0
    # every prefix entry died with its pages
    assert store.stats["prefix_entries"] == 0


@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(**HYPO)
def test_store_never_hands_out_freed_pages(seed):
    """Interleaved register/free churn: every lookup hit must point at
    pages the allocator still holds with refcount >= 1."""
    rng = np.random.default_rng(seed)
    pool = PagePool(9, 4, page=2)
    store = PrefixStore(page=2)
    pool.on_free = store.drop_page
    live: list[int] = []
    uid = 0
    for _ in range(60):
        if not live or rng.integers(0, 2):
            uid += 1
            prompt = rng.integers(0, 4, size=int(rng.integers(2, 8)))
            k, shared = store.lookup(prompt)
            pool.attach(uid)
            pool.share(uid, shared)
            try:
                pool.alloc(uid, pages_for(len(prompt), 2) - k)
            except PageError:
                pool.release(uid)
                continue
            store.register(prompt, pool.pages(uid)[:len(prompt) // 2])
            live.append(uid)
        else:
            pool.release(live.pop(int(rng.integers(0, len(live)))))
        pool.check()
        probe = rng.integers(0, 4, size=6)
        k, ids = store.lookup(probe)
        for p in ids:
            assert pool.refs[p] >= 1, f"store handed out freed page {p}"
