"""Minimal stand-in for the slice of the hypothesis API this suite uses.

Clean environments (the container image, fresh CI runners before
``pip install -r requirements-dev.txt``) don't ship hypothesis; without
this shim 4 of 8 test modules died at *collection* with
ModuleNotFoundError, silently shrinking the tier-1 suite.  Test modules
import it as a fallback:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypo_compat import given, settings, strategies as st

The shim draws ``max_examples`` deterministic pseudo-random examples per
test (seeded by the test name, i.e. always "derandomized").  It covers
exactly the strategies the suite uses: sampled_from, booleans, floats,
integers.  Real hypothesis, when installed, takes precedence and adds
shrinking + database replay on top.
"""
from __future__ import annotations

import functools
import random


class _Strategy:
    def __init__(self, draw):
        self.draw = draw  # callable(rng) -> value


class strategies:
    @staticmethod
    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[rng.randrange(len(opts))])

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def floats(min_value, max_value, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def integers(min_value, max_value, **_):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def lists(elements, min_size=0, max_size=10, **_):
        def draw(rng):
            n = rng.randint(min_size, max_size if max_size is not None
                            else min_size + 10)
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def permutations(values):
        vals = list(values)

        def draw(rng):
            out = list(vals)
            rng.shuffle(out)
            return out
        return _Strategy(draw)

    @staticmethod
    def data():
        return _Strategy(lambda rng: _InteractiveData(rng))


class _InteractiveData:
    """Shim for hypothesis's interactive ``data()`` object: draws from a
    strategy mid-test with the same rng stream."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.draw(self._rng)


def settings(max_examples: int = 10, **_):
    """deadline/derandomize/etc. are accepted and ignored: the shim has no
    deadlines and is always deterministic."""
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", 10)
            rng = random.Random(fn.__qualname__)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)
        # hide the original signature: pytest must not mistake the drawn
        # arguments (m, n, requant, ...) for fixtures
        del wrapper.__wrapped__
        return wrapper
    return deco
