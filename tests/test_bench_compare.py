"""benchmarks/_compare.py: the CI bench regression gate, itself pinned.

Every BENCH_*.json smoke step stands on ``compare()`` returning the right
exit code; a silent bug here (gate that never fails, or one that crashes
on a mangled committed baseline) would disable the perf trajectory checks
without anyone noticing.  Cases: pass, >25% geomean regression, improved
speedup, unmatched cells, malformed/corrupt baselines, backend skip.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks"))
from _compare import compare  # noqa: E402

KEYS = ("requests", "slots")


def _result(cells, backend="cpu"):
    return {"meta": {"backend": backend}, "cells": cells}


def _cell(requests, slots, speedup):
    return {"requests": requests, "slots": slots, "speedup": speedup}


def _baseline(tmp_path, payload, raw: str | None = None):
    p = tmp_path / "baseline.json"
    p.write_text(raw if raw is not None else json.dumps(payload))
    return str(p)


def test_matching_speedups_pass(tmp_path):
    cells = [_cell(8, 4, 2.0), _cell(16, 4, 3.0)]
    path = _baseline(tmp_path, _result(cells))
    assert compare(_result(cells), path, KEYS) == 0


def test_within_threshold_passes_and_beyond_fails(tmp_path):
    path = _baseline(tmp_path, _result([_cell(8, 4, 2.0)]))
    # -20% geomean: inside the 25% budget
    assert compare(_result([_cell(8, 4, 1.6)]), path, KEYS) == 0
    # -30%: regression
    assert compare(_result([_cell(8, 4, 1.4)]), path, KEYS) == 1


def test_geomean_absorbs_one_noisy_cell_but_not_systemic_loss(tmp_path):
    base = [_cell(8, 4, 2.0), _cell(16, 4, 2.0), _cell(24, 8, 2.0)]
    path = _baseline(tmp_path, _result(base))
    one_bad = [_cell(8, 4, 1.3), _cell(16, 4, 2.0), _cell(24, 8, 2.0)]
    assert compare(_result(one_bad), path, KEYS) == 0
    all_bad = [_cell(r, s, 1.3) for r, s, _ in
               [(8, 4, 0), (16, 4, 0), (24, 8, 0)]]
    assert compare(_result(all_bad), path, KEYS) == 1


def test_improvement_passes(tmp_path):
    path = _baseline(tmp_path, _result([_cell(8, 4, 2.0)]))
    assert compare(_result([_cell(8, 4, 5.0)]), path, KEYS) == 0


def test_unmatched_cells_warn_but_do_not_fail(tmp_path):
    """A sweep whose shapes don't intersect the baseline checks nothing -
    that must be a visible no-op, not a pass/fail coin flip."""
    path = _baseline(tmp_path, _result([_cell(999, 2, 2.0)]))
    assert compare(_result([_cell(8, 4, 0.01)]), path, KEYS) == 0


def test_partial_match_only_scores_matched_cells(tmp_path):
    path = _baseline(tmp_path, _result([_cell(8, 4, 2.0)]))
    cur = [_cell(8, 4, 2.0), _cell(64, 32, 0.01)]   # extra cell: ignored
    assert compare(_result(cur), path, KEYS) == 0


def test_backend_mismatch_skips(tmp_path):
    """A TPU baseline checked from a CPU CI host is a skip, not a fail."""
    path = _baseline(tmp_path, _result([_cell(8, 4, 9.0)], backend="tpu"))
    assert compare(_result([_cell(8, 4, 1.0)]), path, KEYS) == 0


@pytest.mark.parametrize("raw", [
    "{not json",                                        # corrupt file
    json.dumps({"meta": {"backend": "cpu"}}),           # no cells
    json.dumps({"meta": {"backend": "cpu"},
                "cells": [{"requests": 8, "slots": 4}]}),   # no speedup
    json.dumps({"meta": {"backend": "cpu"},
                "cells": [{"requests": 8, "slots": 4,
                           "speedup": "fast"}]}),       # non-numeric speedup
    json.dumps({"meta": {"backend": "cpu"},
                "cells": [{"requests": 8, "slots": 4,
                           "speedup": "2.0"}]}),        # numeric STRING: log()
                                                        # would TypeError
    json.dumps({"meta": {"backend": "cpu"},
                "cells": [{"requests": 8, "slots": 4,
                           "speedup": 0.0}]}),          # log(0): domain error
    json.dumps({"meta": {"backend": "cpu"},
                "cells": [{"speedup": 2.0}]}),          # missing shape keys
])
def test_malformed_baseline_fails_loudly(tmp_path, raw):
    """A mangled committed baseline must FAIL the gate with a message -
    crashing (or silently passing) would disable the regression check."""
    path = _baseline(tmp_path, None, raw=raw)
    assert compare(_result([_cell(8, 4, 2.0)]), path, KEYS) == 1


def test_missing_baseline_file_fails_loudly(tmp_path):
    assert compare(_result([_cell(8, 4, 2.0)]),
                   str(tmp_path / "nope.json"), KEYS) == 1
