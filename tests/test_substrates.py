"""Substrate tests: data determinism, checkpointing, optimizer, schedules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean envs: deterministic shim, see requirements-dev.txt
    from _hypo_compat import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.checkpoint.io import load_pytree, save_pytree
from repro.data import DataConfig, make_source
from repro.optim import AdamWConfig, adamw
from repro.optim.schedule import warmup_cosine

HYPO = dict(max_examples=10, deadline=None, derandomize=True)


# ------------------------------------------------------------------- data
def test_data_is_deterministic_in_step():
    cfg = DataConfig(vocab=1000, seq_len=32, batch=4, seed=7)
    s1, s2 = make_source(cfg), make_source(cfg)
    for step in (0, 5, 11):
        b1, b2 = s1.batch_at(step), s2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s1.batch_at(0)["tokens"], s1.batch_at(1)["tokens"])


def test_data_shards_differ_and_labels_shift():
    a = make_source(DataConfig(vocab=500, seq_len=16, batch=4, shard_id=0,
                               num_shards=4))
    b = make_source(DataConfig(vocab=500, seq_len=16, batch=4, shard_id=1,
                               num_shards=4))
    ba, bb = a.batch_at(3), b.batch_at(3)
    assert not np.array_equal(ba["tokens"], bb["tokens"])
    # labels are next-token targets
    np.testing.assert_array_equal(ba["tokens"][:, 1:], ba["labels"][:, :-1])


def test_file_tokens_source(tmp_path):
    path = os.path.join(tmp_path, "toks.bin")
    np.arange(10_000, dtype=np.uint16).tofile(path)
    cfg = DataConfig(vocab=500, seq_len=32, batch=4, kind="file", path=path)
    b = make_source(cfg).batch_at(0)
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].max() < 500


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_integrity(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
            "empty": (),
            "d": jnp.int32(7)}
    d = os.path.join(tmp_path, "ck")
    save_pytree(tree, d, extra_meta={"step": 3})
    out, meta = load_pytree(tree, d)
    assert meta["step"] == 3
    np.testing.assert_allclose(np.asarray(out["a"]), np.arange(10))
    np.testing.assert_allclose(np.asarray(out["b"]["c"], np.float32), 1.0)
    # corrupt -> digest failure
    import json
    with open(os.path.join(d, "meta.json")) as f:
        m = json.load(f)
    m["digest"] = "0" * 64
    with open(os.path.join(d, "meta.json"), "w") as f:
        json.dump(m, f)
    with pytest.raises(IOError):
        load_pytree(tree, d)


def test_manager_rotation_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"w": jnp.zeros((4,))}
    for step in (10, 20, 30):
        mgr.save(step, {"w": jnp.full((4,), step, jnp.float32)}, block=True)
    assert mgr.steps() == [20, 30]
    out, meta = mgr.restore(tree)
    assert meta["step"] == 30
    assert float(np.asarray(out["w"])[0]) == 30.0


# -------------------------------------------------------------- optimizer
def _quadratic_params():
    return {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5),
            "mat": jnp.ones((4, 4))}


@pytest.mark.parametrize("quant_state", [False, True])
def test_adamw_descends_quadratic(quant_state):
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, quant_state=quant_state)
    params = _quadratic_params()
    state = adamw.init(params, cfg)

    def loss(p):
        return (jnp.sum(p["w"] ** 2) + p["b"] ** 2
                + jnp.sum((p["mat"] - 0.5) ** 2))

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = adamw.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 0.25 * l0
    assert int(state.step) == 60


def test_quant_state_roundtrip_accuracy():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 0.1
    q, s = adamw._q8(x)
    back = adamw._dq8(q, s, x.shape)
    assert float(jnp.abs(back - x).max()) < float(jnp.abs(x).max()) / 100


@settings(**HYPO)
@given(step=st.integers(0, 20_000))
def test_warmup_cosine_bounds(step):
    v = float(warmup_cosine(jnp.int32(step), warmup=100, total=10_000))
    assert 0.0 <= v <= 1.0


def test_global_norm_clip_applied():
    cfg = AdamWConfig(lr=1e-9, grad_clip=1.0)
    params = {"w": jnp.zeros((3,))}
    state = adamw.init(params, cfg)
    g = {"w": jnp.full((3,), 1e6)}
    new_params, _ = adamw.apply_updates(params, g, state, cfg)
    # with clipping, the update magnitude stays ~lr-scale
    assert float(jnp.abs(new_params["w"]).max()) < 1.0
