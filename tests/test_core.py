"""Core PDQ library: unit + hypothesis property tests.

Invariants tested:
  * affine quantize/dequantize round-trip error is bounded by scale/2
  * qparams_from_range represents 0 exactly and covers [m, M]
  * the surrogate moments match empirical moments for truly-Gaussian weights
    (the paper's i.i.d. assumption, Eqs. 8-12)
  * I(alpha,beta) calibration achieves its target coverage on held-in data
  * static/dynamic/pdq modes all keep quantization error bounded
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean envs: deterministic shim, see requirements-dev.txt
    from _hypo_compat import given, settings, strategies as st

from repro.core import (affine, interval, qlinear, run_calibration,
                        spec_for_mode, surrogate)

HYPO = dict(max_examples=15, deadline=None, derandomize=True)


@settings(**HYPO)
@given(
    lo=st.floats(-100.0, -0.01),
    width=st.floats(0.1, 1000.0),
    bits=st.sampled_from([4, 8, 16]),
)
def test_affine_roundtrip_error_bound(lo, width, bits):
    m, M = lo, lo + width
    qp = affine.qparams_from_range(jnp.float32(m), jnp.float32(M), bits)
    x = jnp.linspace(m, M, 257)
    err = jnp.abs(affine.fake_quant(x, qp) - x)
    # the round-trip cannot beat float32 itself: allow a few ulps at |x|max
    # on top of the half-step bound (matters for bits=16 over wide ranges)
    slack = 4.0 * float(np.spacing(np.float32(max(abs(m), abs(M)))))
    assert float(err.max()) <= float(qp.scale) * 0.5 + slack + 1e-6


@settings(**HYPO)
@given(lo=st.floats(-50.0, -0.1), hi=st.floats(0.1, 50.0))
def test_affine_zero_is_exact(lo, hi):
    qp = affine.qparams_from_range(jnp.float32(lo), jnp.float32(hi), 8)
    assert float(affine.fake_quant(jnp.float32(0.0), qp)) == 0.0


@settings(**HYPO)
@given(
    d=st.sampled_from([64, 256]),
    h=st.sampled_from([32, 128]),
    mu=st.floats(-0.2, 0.2),
    sd=st.floats(0.01, 0.3),
)
def test_surrogate_matches_gaussian_weights(d, h, mu, sd):
    """Under the paper's assumption (i.i.d. Gaussian W), Eqs. 8-9 are exact
    in expectation; empirical moments over h outputs concentrate."""
    key = jax.random.PRNGKey(d * h)
    W = mu + sd * jax.random.normal(key, (d, h))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, d))
    ws = surrogate.weight_stats(W, reduce_axes=(0,), per_channel=False)
    pred = surrogate.linear_moments(x, ws, per_channel=False)
    emp = surrogate.empirical_moments(x @ W, per_channel=False)
    # variance ratio within 25%; mean error small relative to sigma
    ratio = np.asarray(pred.var / jnp.maximum(emp.var, 1e-9))
    assert np.all(ratio > 0.6) and np.all(ratio < 1.7)
    merr = np.asarray(jnp.abs(pred.mean - emp.mean) / jnp.sqrt(emp.var + 1e-9))
    assert float(merr.max()) < 0.8


def test_surrogate_conv_matches_empirical():
    key = jax.random.PRNGKey(0)
    k = 0.05 * jax.random.normal(key, (3, 3, 8, 32)) + 0.01
    # non-centered inputs so channel means are signal, not noise
    x = 0.5 + jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16, 8))
    ws = surrogate.weight_stats(k, reduce_axes=(0, 1, 2), per_channel=True)
    pred = surrogate.conv_moments(x, ws, (3, 3), (1, 1), "SAME", per_channel=True)
    import jax.lax as lax
    dn = lax.conv_dimension_numbers(x.shape, k.shape, ("NHWC", "HWIO", "NHWC"))
    y = lax.conv_general_dilated(x, k, (1, 1), "SAME", dimension_numbers=dn)
    emp = surrogate.empirical_moments(y, per_channel=True)
    mcorr = np.corrcoef(np.asarray(pred.mean).ravel(), np.asarray(emp.mean).ravel())[0, 1]
    scorr = np.corrcoef(np.asarray(pred.std).ravel(), np.asarray(emp.std).ravel())[0, 1]
    assert mcorr > 0.8, mcorr
    # the dispersion estimate (what sets the PDQ scale) must track reality
    assert scorr > 0.5, scorr
    ratio = np.asarray(pred.std).mean() / np.asarray(emp.std).mean()
    assert 0.5 < ratio < 2.0, ratio


@settings(**HYPO)
@given(cov=st.sampled_from([0.99, 0.999]))
def test_interval_calibration_hits_coverage(cov):
    rng = np.random.default_rng(0)
    u = rng.standard_normal((200_000,))
    ip = interval.calibrate_alpha_beta(u, target_coverage=cov)
    got = np.mean((u >= -float(ip.alpha)) & (u <= float(ip.beta)))
    assert got >= cov - 0.002


def test_gamma_stride_reduces_positions_not_quality_much():
    key = jax.random.PRNGKey(0)
    W = 0.1 * jax.random.normal(key, (128, 64)) + 0.02
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 128))
    ws = surrogate.weight_stats(W, reduce_axes=(0,), per_channel=False)
    m1 = surrogate.linear_moments(x, ws, per_channel=False, gamma=1)
    m8 = surrogate.linear_moments(x, ws, per_channel=False, gamma=8)
    assert np.allclose(np.asarray(m1.var), np.asarray(m8.var), rtol=0.5)


def _tiny_apply(params, batch, *, spec, qstate, tape=None):
    W1, W2 = params
    h = qlinear.dense(batch, W1, None, name="fc1", policy=spec.resolve("fc1"),
                      state=qstate, tape=tape)
    h = jax.nn.relu(h)
    return qlinear.dense(h, W2, None, name="fc2", policy=spec.resolve("fc2"),
                         state=qstate, tape=tape)


@pytest.mark.parametrize("per_channel", [False, True])
def test_three_modes_bounded_error(per_channel):
    key = jax.random.PRNGKey(0)
    params = (0.1 * jax.random.normal(key, (64, 128)),
              0.1 * jax.random.normal(jax.random.PRNGKey(1), (128, 32)))
    calib = [jax.random.normal(jax.random.PRNGKey(i), (8, 64)) for i in range(4)]
    spec = spec_for_mode("pdq", per_channel=per_channel)
    qstate = run_calibration(_tiny_apply, params, calib, spec)
    x = jax.random.normal(jax.random.PRNGKey(9), (16, 64))
    ref = _tiny_apply(params, x, spec=spec_for_mode("none"), qstate={})
    for mode in ("static", "dynamic", "pdq"):
        out = _tiny_apply(params, x, spec=spec_for_mode(mode, per_channel=per_channel),
                          qstate=qstate)
        rel = float(jnp.abs(out - ref).mean() / jnp.abs(ref).mean())
        assert rel < 0.15, f"{mode} per_channel={per_channel}: rel err {rel}"


def test_pdq_adapts_to_input_scale_static_does_not():
    """The paper's central claim: under input-distribution shift, the PDQ
    scale tracks the inputs while the static scale is frozen."""
    key = jax.random.PRNGKey(0)
    params = (0.1 * jax.random.normal(key, (64, 128)),
              0.1 * jax.random.normal(jax.random.PRNGKey(1), (128, 32)))
    calib = [jax.random.normal(jax.random.PRNGKey(i), (8, 64)) for i in range(4)]
    spec_pdq = spec_for_mode("pdq", per_channel=False)
    qstate = run_calibration(_tiny_apply, params, calib, spec_pdq)
    # shift: inputs 6x larger than calibration
    x = 6.0 * jax.random.normal(jax.random.PRNGKey(9), (16, 64))
    ref = _tiny_apply(params, x, spec=spec_for_mode("none"), qstate={})
    errs = {}
    for mode in ("static", "dynamic", "pdq"):
        out = _tiny_apply(params, x, spec=spec_for_mode(mode, per_channel=False),
                          qstate=qstate)
        errs[mode] = float(jnp.abs(out - ref).mean() / jnp.abs(ref).mean())
    assert errs["pdq"] < errs["static"] * 0.5, errs
    assert errs["dynamic"] <= errs["pdq"] * 1.5, errs
