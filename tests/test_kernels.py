"""Per-kernel correctness: Pallas (interpret=True) vs the pure-jnp oracle.

Shapes and dtypes are swept with hypothesis; every kernel must match ref.py
to float32 tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean envs: deterministic shim, see requirements-dev.txt
    from _hypo_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.act_stats import act_stats_p
from repro.kernels.kv_cache import decode_attend_i8kv_fused_p, decode_attend_i8kv_p
from repro.kernels.pdq_prologue import pdq_prologue_p
from repro.kernels.quantize import dequantize_p, quantize_p
from repro.kernels.w8a8_matmul import w8a8_matmul_p, w8a8_swiglu_matmul_p
from repro.models.linops import group_quantize_weights, quantize_weight

jax.config.update("jax_enable_x64", False)

HYPO = dict(max_examples=8, deadline=None, derandomize=True)


def _rand_i8(key, shape):
    return jax.random.randint(key, shape, -128, 128, dtype=jnp.int32).astype(jnp.int8)


# ---------------------------------------------------------------------------
# w8a8 matmul
# ---------------------------------------------------------------------------


@settings(**HYPO)
@given(
    m=st.sampled_from([128, 256]),
    n=st.sampled_from([128, 384]),
    k=st.sampled_from([128, 256]),
    requant=st.booleans(),
    per_channel=st.booleans(),
)
def test_w8a8_matmul_kernel_vs_ref(m, n, k, requant, per_channel):
    keys = jax.random.split(jax.random.PRNGKey(m * n + k), 4)
    x_q = _rand_i8(keys[0], (m, k))
    w_q = _rand_i8(keys[1], (k, n))
    s_x = jax.random.uniform(keys[2], (m, 1), minval=0.01, maxval=0.1)
    z_x = jax.random.randint(keys[3], (m, 1), -10, 10, dtype=jnp.int32)
    s_w = (jax.random.uniform(keys[2], (1, n), minval=0.001, maxval=0.01)
           if per_channel else jnp.full((1, n), 0.005))
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=0, keepdims=True)
    s_out = jnp.full((m, 1), 0.7, jnp.float32)
    z_out = jnp.full((m, 1), 3, jnp.int32)

    got = w8a8_matmul_p(x_q, w_q, s_x, z_x, s_w, colsum, s_out, z_out,
                        requant=requant, interpret=True)
    want = ref.w8a8_matmul_ref(x_q, w_q, s_x, z_x, s_w,
                               s_out if requant else None, z_out if requant else None)
    if requant:
        # rounding ties may differ by 1 ulp of the int grid
        assert np.abs(np.asarray(got, np.int32) - np.asarray(want, np.int32)).max() <= 1
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_w8a8_matmul_ops_padding_and_lead_dims():
    ops.set_impl("kernel")
    try:
        key = jax.random.PRNGKey(0)
        x_q = _rand_i8(key, (2, 3, 70))            # ragged K, leading dims
        w_q = _rand_i8(jax.random.PRNGKey(1), (70, 50))
        y = ops.w8a8_matmul(x_q, w_q, 0.05, 2, jnp.full((50,), 0.01))
        want = ref.w8a8_matmul_ref(
            x_q.reshape(6, 70), w_q, jnp.full((6, 1), 0.05), jnp.full((6, 1), 2),
            jnp.full((1, 50), 0.01))
        np.testing.assert_allclose(y.reshape(6, 50), want, rtol=1e-5)
    finally:
        ops.set_impl("auto")


# ---------------------------------------------------------------------------
# act_stats
# ---------------------------------------------------------------------------


@settings(**HYPO)
@given(
    m=st.sampled_from([256, 512]),
    k=st.sampled_from([512, 1024]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_act_stats_kernel_vs_ref(m, k, dtype):
    x = jax.random.normal(jax.random.PRNGKey(m + k), (m, k)).astype(dtype)
    s1, s2 = act_stats_p(x, interpret=True)
    w1, w2 = ref.act_stats_ref(x)
    np.testing.assert_allclose(s1, w1, rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-2)
    np.testing.assert_allclose(s2, w2, rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-2)


def test_act_stats_ops_gamma_stride():
    ops.set_impl("kernel")
    try:
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 100, 33))
        s1, s2 = ops.act_stats(x, gamma=4)
        w1, w2 = ref.act_stats_ref(x[:, ::4].reshape(-1, 33))
        np.testing.assert_allclose(s1.reshape(-1), w1, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(s2.reshape(-1), w2, rtol=1e-4, atol=1e-4)
    finally:
        ops.set_impl("auto")


# ---------------------------------------------------------------------------
# quantize / dequantize
# ---------------------------------------------------------------------------


@settings(**HYPO)
@given(
    m=st.sampled_from([256, 300]),
    n=st.sampled_from([256, 290]),
    per_channel=st.booleans(),
)
def test_quantize_roundtrip_kernel_vs_ref(m, n, per_channel):
    x = 4.0 * jax.random.normal(jax.random.PRNGKey(m * n), (m, n))
    if per_channel:
        s = jnp.linspace(0.01, 0.2, n).reshape(1, n)
        z = jnp.zeros((1, n), jnp.int32)
    else:
        s = jnp.full((m, 1), 0.05)
        z = jnp.full((m, 1), 4, jnp.int32)
    mp, np_ = -(-m // 256) * 256, -(-n // 256) * 256
    xp = jnp.pad(x, ((0, mp - m), (0, np_ - n)))
    sp = jnp.pad(s, ((0, 0), (0, np_ - n)), constant_values=1.0) if per_channel \
        else jnp.pad(s, ((0, mp - m), (0, 0)), constant_values=1.0)
    zp = jnp.pad(z, ((0, 0), (0, np_ - n))) if per_channel \
        else jnp.pad(z, ((0, mp - m), (0, 0)))
    q = quantize_p(xp, sp, zp, interpret=True)[:m, :n]
    want = ref.quantize_ref(x, s, z)
    assert np.abs(np.asarray(q, np.int32) - np.asarray(want, np.int32)).max() <= 1
    y = dequantize_p(jnp.pad(want, ((0, mp - m), (0, np_ - n))), sp, zp,
                     interpret=True)[:m, :n]
    np.testing.assert_allclose(y, ref.dequantize_ref(want, s, z), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# int8-KV flash decode
# ---------------------------------------------------------------------------


@settings(**HYPO)
@given(
    s=st.sampled_from([256, 512]),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 4]),
    dh=st.sampled_from([64, 128]),
    frac=st.sampled_from([0.4, 1.0]),
)
def test_decode_i8kv_kernel_vs_ref(s, hkv, g, dh, frac):
    keys = jax.random.split(jax.random.PRNGKey(s + hkv * 7 + g * 13 + dh), 5)
    H = hkv * g
    q = jax.random.normal(keys[0], (H, dh))
    k_q = _rand_i8(keys[1], (s, hkv, dh))
    v_q = _rand_i8(keys[2], (s, hkv, dh))
    k_s = jax.random.uniform(keys[3], (s, hkv), minval=0.01, maxval=0.05)
    v_s = jax.random.uniform(keys[4], (s, hkv), minval=0.01, maxval=0.05)
    length = jnp.int32(int(s * frac))

    want = ref.decode_attend_i8kv_ref(q, k_q, v_q, k_s, v_s, length)
    got = decode_attend_i8kv_p(
        q.reshape(hkv, g, dh),
        jnp.transpose(k_q, (1, 0, 2)), jnp.transpose(v_q, (1, 0, 2)),
        jnp.transpose(k_s, (1, 0)), jnp.transpose(v_s, (1, 0)),
        jnp.full((1, 1), length, jnp.int32), bs=128, interpret=True,
    ).reshape(H, dh)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("s", [200, 256])   # ragged (padded per call) + aligned
def test_decode_i8kv_ops_batched(s):
    """ops takes the cache in KERNEL layout (B, Hkv, S, Dh); the oracle
    keeps the logical (S, Hkv) layout."""
    B, Hkv, G, Dh = 2, 2, 2, 64
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(keys[0], (B, Hkv * G, Dh))
    k_q = _rand_i8(keys[1], (B, Hkv, s, Dh))
    v_q = _rand_i8(keys[2], (B, Hkv, s, Dh))
    k_s = jax.random.uniform(keys[3], (B, Hkv, s), minval=0.01, maxval=0.05)
    v_s = jax.random.uniform(keys[4], (B, Hkv, s), minval=0.01, maxval=0.05)
    lens = jnp.array([130, 57], jnp.int32)
    ops.set_impl("kernel")
    try:
        got = ops.decode_attend_i8kv(q, k_q, v_q, k_s, v_s, lens, bs=128)
    finally:
        ops.set_impl("auto")
    want = jax.vmap(ref.decode_attend_i8kv_ref)(
        q, jnp.transpose(k_q, (0, 2, 1, 3)), jnp.transpose(v_q, (0, 2, 1, 3)),
        jnp.transpose(k_s, (0, 2, 1)), jnp.transpose(v_s, (0, 2, 1)), lens)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# fused PDQ prologue + pdq_dense (one prologue + one matmul serving path)
# ---------------------------------------------------------------------------


@settings(**HYPO)
@given(
    m=st.sampled_from([128, 256]),
    k=st.sampled_from([512, 1024]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_pdq_prologue_kernel_vs_ref(m, k, dtype):
    x = 3.0 * jax.random.normal(jax.random.PRNGKey(m + k), (m, k)).astype(dtype)
    got = pdq_prologue_p(x, block=(128, 512), interpret=True)
    want = ref.pdq_prologue_ref(x.reshape(m, k))
    np.testing.assert_allclose(got[1], want[1], rtol=1e-5, atol=1e-6)   # s_x
    # quantization may differ by 1 at exact rounding ties
    assert np.abs(np.asarray(got[0], np.int32) - np.asarray(want[0], np.int32)).max() <= 1
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got[2], want[2], rtol=tol, atol=1e-2)    # s1
    np.testing.assert_allclose(got[3], want[3], rtol=tol, atol=1e-2)    # s2


def test_pdq_prologue_ops_padding_and_lead_dims():
    """Non-multiple (M, K) + leading batch dims exercise every _pad_to branch."""
    ops.set_impl("kernel")
    try:
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 65, 257))
        x_q, s_x, s1, s2 = ops.pdq_prologue(x)
        wq, wsx, ws1, ws2 = ref.pdq_prologue_ref(x.reshape(130, 257))
        assert x_q.shape == (2, 65, 257) and s_x.shape == (2, 65, 1)
        assert np.abs(np.asarray(x_q, np.int32).reshape(130, 257)
                      - np.asarray(wq, np.int32)).max() <= 1
        np.testing.assert_allclose(s_x.reshape(130, 1), wsx, rtol=1e-5)
        np.testing.assert_allclose(s1.reshape(130, 1), ws1, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(s2.reshape(130, 1), ws2, rtol=1e-4, atol=1e-4)
    finally:
        ops.set_impl("auto")


@pytest.mark.parametrize("impl", ["ref", "kernel"])
@pytest.mark.parametrize("shape", [(6, 128, 64), (130, 257, 100)])
def test_pdq_dense_fp_matches_unfused_requant_dequant(impl, shape):
    """fp-out epilogue == requant->dequant to within ONE int8 step per row,
    for both the jnp oracle and the interpreted kernels, on block-multiple
    and ragged shapes."""
    M, K, N = shape
    w = 0.05 * jax.random.normal(jax.random.PRNGKey(0), (K, N))
    rec = quantize_weight(w)
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    ops.set_impl(impl)
    try:
        y_fused = ops.pdq_dense(x, rec, out="fp")
        y_unfused, s_out = ops.pdq_dense_unfused(x, rec)
    finally:
        ops.set_impl("auto")
    step = np.asarray(s_out).reshape(M, 1)
    err = np.abs(np.asarray(y_fused) - np.asarray(y_unfused))
    assert (err <= step + 1e-6).all(), float((err / step).max())


@pytest.mark.parametrize("impl", ["ref", "kernel"])
def test_pdq_dense_int8_out_matches_unfused(impl):
    M, K, N = 130, 257, 100       # ragged: every _pad_to branch
    w = 0.05 * jax.random.normal(jax.random.PRNGKey(2), (K, N))
    rec = quantize_weight(w)
    x = jax.random.normal(jax.random.PRNGKey(3), (M, K))
    ops.set_impl(impl)
    try:
        y_q, s_out, z_out = ops.pdq_dense(x, rec, out="int8")
        x_q, s_x, s1, s2 = ops.pdq_prologue(x)
    finally:
        ops.set_impl("auto")
    assert y_q.dtype == jnp.int8 and y_q.shape == (M, N)
    assert s_out.shape == (M, 1) and z_out.dtype == jnp.int32
    # against the fully-unfused integer pipeline on the same quantized input
    acc = x_q.astype(jnp.int32) @ rec["q"].astype(jnp.int32)
    yf = s_x * rec["scale"][None, :] * acc.astype(jnp.float32)
    want = jnp.clip(jnp.round(yf / s_out) + z_out.astype(jnp.float32), -128, 127)
    assert np.abs(np.asarray(y_q, np.int32) - np.asarray(want, np.int32)).max() <= 1


def test_pdq_dense_per_channel_weight_scale_roundtrip():
    """Per-output-channel weight scales flow through both epilogues."""
    K, N = 128, 128
    w = jnp.concatenate([0.01 * jnp.ones((K, N // 2)),
                         0.2 * jnp.ones((K, N // 2))], axis=1)
    w = w * jax.random.normal(jax.random.PRNGKey(4), (K, N))
    rec = quantize_weight(w)
    assert rec["scale"].shape == (N,)
    x = jax.random.normal(jax.random.PRNGKey(5), (8, K))
    y = ops.pdq_dense(x, rec, out="fp")
    rel = float(jnp.abs(y - x @ w).mean() / jnp.abs(x @ w).mean())
    assert rel < 0.05, rel


def test_w8a8_fp_clamp_epilogue_kernel_vs_ref():
    m, k, n = 128, 128, 128
    keys = jax.random.split(jax.random.PRNGKey(7), 4)
    x_q = _rand_i8(keys[0], (m, k))
    w_q = _rand_i8(keys[1], (k, n))
    s_x = jax.random.uniform(keys[2], (m, 1), minval=0.01, maxval=0.1)
    s_w = jnp.full((1, n), 0.005)
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=0, keepdims=True)
    lo = jnp.full((m, 1), -1.0)
    hi = jnp.full((m, 1), 1.5)
    got = w8a8_matmul_p(x_q, w_q, s_x, jnp.zeros((m, 1), jnp.int32), s_w,
                        colsum, jnp.ones((m, 1)), jnp.zeros((m, 1), jnp.int32),
                        lo, hi, requant=False, fp_clamp=True, interpret=True)
    want = jnp.clip(ref.w8a8_matmul_ref(x_q, w_q, s_x,
                                        jnp.zeros((m, 1), jnp.int32), s_w),
                    lo, hi)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# grouped projections: per-(row, N-block) epilogue + pdq_dense_grouped
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["requant", "fp_clamp"])
def test_w8a8_per_nblock_epilogue_kernel_vs_ref(mode):
    """per_nblock=True: each 128-lane output block applies its own
    (s_out, z_out) / [lo, hi] - the grouped-matmul epilogue contract."""
    m, k, n = 128, 128, 384                 # 3 N-blocks
    nb = n // 128
    keys = jax.random.split(jax.random.PRNGKey(11), 6)
    x_q = _rand_i8(keys[0], (m, k))
    w_q = _rand_i8(keys[1], (k, n))
    s_x = jax.random.uniform(keys[2], (m, 1), minval=0.01, maxval=0.1)
    z_x = jnp.zeros((m, 1), jnp.int32)
    s_w = jnp.full((1, n), 0.005)
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=0, keepdims=True)
    s_out = jax.random.uniform(keys[3], (m, nb), minval=0.3, maxval=0.9)
    z_out = jax.random.randint(keys[4], (m, nb), -5, 5, dtype=jnp.int32)
    lo = -jax.random.uniform(keys[5], (m, nb), minval=0.5, maxval=2.0)
    hi = -1.5 * lo
    requant = mode == "requant"
    got = w8a8_matmul_p(x_q, w_q, s_x, z_x, s_w, colsum, s_out, z_out,
                        lo, hi, requant=requant, fp_clamp=not requant,
                        per_nblock=True, interpret=True)
    y_fp = ref.w8a8_matmul_ref(x_q, w_q, s_x, z_x, s_w)
    expand = lambda a: jnp.repeat(a, 128, axis=-1)     # block -> channel
    if requant:
        want = jnp.clip(jnp.round(y_fp / expand(s_out)) + expand(z_out),
                        -128, 127)
        assert np.abs(np.asarray(got, np.int32) - np.asarray(want, np.int32)).max() <= 1
    else:
        want = jnp.clip(y_fp, expand(lo), expand(hi))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**HYPO)
@given(
    m=st.sampled_from([8, 130]),
    k=st.sampled_from([256, 257]),
    sizes=st.sampled_from([(64, 96), (128, 100, 200), (32, 32, 32)]),
    impl=st.sampled_from(["ref", "kernel"]),
)
def test_pdq_dense_grouped_segments_match_per_projection(m, k, sizes, impl):
    """Property (acceptance): every grouped output segment matches the
    per-projection pdq_dense result to within one int8 step of that
    segment's predicted grid - the shared (s1, s2) moments depend only on
    the input, so the grouped interval math is exact, not approximate."""
    key = jax.random.PRNGKey(m * k + sum(sizes))
    ws = [0.05 * jax.random.normal(jax.random.fold_in(key, i), (k, n))
          for i, n in enumerate(sizes)]
    x = jax.random.normal(jax.random.fold_in(key, 99), (m, k))
    grec = group_quantize_weights(ws)
    ops.set_impl(impl)
    try:
        ys = ops.pdq_dense_grouped(x, grec, out="fp")
        _, _, s1, s2 = ops.pdq_prologue(x)
        for i, w in enumerate(ws):
            rec = quantize_weight(w)
            y_ind = ops.pdq_dense(x, rec, out="fp")
            _, _, s_out, _ = ops.pdq_interval(rec, s1, s2)
            err = np.abs(np.asarray(ys[i]) - np.asarray(y_ind))
            step = np.asarray(s_out)
            assert (err <= step + 1e-6).all(), (i, float((err / step).max()))
    finally:
        ops.set_impl("auto")


@pytest.mark.parametrize("impl", ["ref", "kernel"])
def test_pdq_dense_grouped_int8_out(impl):
    """Grouped int8 epilogue: per-segment grids applied per N-block."""
    key = jax.random.PRNGKey(21)
    sizes = (100, 64)
    ws = [0.05 * jax.random.normal(jax.random.fold_in(key, i), (256, n))
          for i, n in enumerate(sizes)]
    x = jax.random.normal(jax.random.fold_in(key, 9), (16, 256))
    grec = group_quantize_weights(ws)
    ops.set_impl(impl)
    try:
        ys, s_out, z_out = ops.pdq_dense_grouped(x, grec, out="int8")
        for i, w in enumerate(ws):
            rec = quantize_weight(w)
            y_ind, s_ind, z_ind = ops.pdq_dense(x, rec, out="int8")
            np.testing.assert_allclose(s_out[..., i:i + 1], s_ind, rtol=1e-6)
            assert np.abs(np.asarray(ys[i], np.int32)
                          - np.asarray(y_ind, np.int32)).max() <= 1
    finally:
        ops.set_impl("auto")
    assert s_out.shape == (16, 2) and z_out.dtype == jnp.int32


# ---------------------------------------------------------------------------
# block-divisibility guards on the raw kernels
# ---------------------------------------------------------------------------


def test_raw_kernels_reject_non_block_multiples():
    x = jnp.zeros((130, 300))
    q = jnp.zeros((130, 300), jnp.int8)
    s = jnp.ones((130, 1))
    z = jnp.zeros((130, 1), jnp.int32)
    with pytest.raises(AssertionError, match="block-multiple"):
        quantize_p(x, s, z)
    with pytest.raises(AssertionError, match="block-multiple"):
        dequantize_p(q, s, z)
    with pytest.raises(AssertionError, match="block-multiple"):
        act_stats_p(x)
    with pytest.raises(AssertionError, match="block-multiple"):
        pdq_prologue_p(x)
    with pytest.raises(AssertionError, match="block-multiple"):
        w8a8_matmul_p(q, jnp.zeros((300, 100), jnp.int8), s, z,
                      jnp.ones((1, 100)), jnp.zeros((1, 100), jnp.int32),
                      s, z, requant=True)
    with pytest.raises(AssertionError, match="block-multiple"):
        decode_attend_i8kv_p(jnp.zeros((2, 2, 64)),
                             jnp.zeros((2, 200, 64), jnp.int8),
                             jnp.zeros((2, 200, 64), jnp.int8),
                             jnp.ones((2, 200)), jnp.ones((2, 200)),
                             jnp.ones((1, 1), jnp.int32), bs=128)


# ---------------------------------------------------------------------------
# fused decode epilogues (ISSUE 10): attend + wo prologue, SwiGLU + w_down
# prologue - the launches behind the 7-pallas_call decode census
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("frac", [0.3, 1.0])
def test_decode_i8kv_fused_wo_prologue_kernel_vs_ref(frac):
    """decode_attend_i8kv_fused_p must return the SAME o as the plain attend
    kernel plus the wo prologue ref run over the flattened (H*Dh,) row."""
    s, hkv, g, dh = 256, 2, 2, 64
    H = hkv * g
    keys = jax.random.split(jax.random.PRNGKey(41), 5)
    q = jax.random.normal(keys[0], (H, dh))
    k_q = _rand_i8(keys[1], (hkv, s, dh))
    v_q = _rand_i8(keys[2], (hkv, s, dh))
    k_s = jax.random.uniform(keys[3], (hkv, s), minval=0.01, maxval=0.05)
    v_s = jax.random.uniform(keys[4], (hkv, s), minval=0.01, maxval=0.05)
    length = jnp.full((1, 1), int(s * frac), jnp.int32)

    o_plain = decode_attend_i8kv_p(q.reshape(hkv, g, dh), k_q, v_q, k_s, v_s,
                                   length, bs=128, interpret=True)
    o, o_q, s_x, s1, s2 = decode_attend_i8kv_fused_p(
        q.reshape(hkv, g, dh), k_q, v_q, k_s, v_s, length,
        bs=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(o_plain))
    wq, wsx, ws1, ws2 = ref.pdq_prologue_ref(o_plain.reshape(1, H * dh))
    np.testing.assert_allclose(s_x.reshape(1, 1), wsx, rtol=1e-5)
    np.testing.assert_allclose(s1.reshape(1, 1), ws1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s2.reshape(1, 1), ws2, rtol=1e-4, atol=1e-4)
    assert np.abs(np.asarray(o_q, np.int32).reshape(1, H * dh)
                  - np.asarray(wq, np.int32)).max() <= 1


@pytest.mark.parametrize("impl", ["ref", "kernel"])
def test_decode_i8kv_ops_wo_prologue_batched(impl):
    """ops.decode_attend_i8kv(wo_prologue=True) == plain attend + prologue
    ref, in BOTH impls (the ref path must be bit-identical to the unfused
    composition so CPU engine parity is unaffected)."""
    B, Hkv, G, Dh, s = 3, 2, 2, 64, 256
    keys = jax.random.split(jax.random.PRNGKey(7), 5)
    q = jax.random.normal(keys[0], (B, Hkv * G, Dh))
    k_q = _rand_i8(keys[1], (B, Hkv, s, Dh))
    v_q = _rand_i8(keys[2], (B, Hkv, s, Dh))
    k_s = jax.random.uniform(keys[3], (B, Hkv, s), minval=0.01, maxval=0.05)
    v_s = jax.random.uniform(keys[4], (B, Hkv, s), minval=0.01, maxval=0.05)
    lens = jnp.array([256, 57, 1], jnp.int32)
    ops.set_impl(impl)
    try:
        o, o_q, s_x, s1, s2 = ops.decode_attend_i8kv(
            q, k_q, v_q, k_s, v_s, lens, wo_prologue=True,
            pro_dtype=jnp.float32)
        o_plain = ops.decode_attend_i8kv(q, k_q, v_q, k_s, v_s, lens)
    finally:
        ops.set_impl("auto")
    np.testing.assert_allclose(o, o_plain, rtol=1e-6, atol=1e-6)
    wq, wsx, ws1, ws2 = ref.pdq_prologue_ref(
        np.asarray(o_plain).reshape(B, Hkv * G * Dh))
    np.testing.assert_allclose(s_x.reshape(B, 1), wsx, rtol=1e-5)
    np.testing.assert_allclose(s1.reshape(B, 1), ws1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s2.reshape(B, 1), ws2, rtol=1e-4, atol=1e-4)
    assert np.abs(np.asarray(o_q, np.int32).reshape(B, -1)
                  - np.asarray(wq, np.int32)).max() <= 1
    if impl == "ref":
        # ref path is the EXACT unfused composition
        np.testing.assert_array_equal(np.asarray(o), np.asarray(o_plain))
        np.testing.assert_array_equal(np.asarray(o_q).reshape(B, -1),
                                      np.asarray(wq))


def test_w8a8_swiglu_matmul_kernel_vs_unfused():
    """The raw SwiGLU-epilogue matmul == plain clamped matmul + jnp
    silu(g)*u + prologue ref, including the padded-lane columns (zero
    weight cols produce hsw == 0, which the prologue must tolerate)."""
    M, K, N = 128, 256, 512          # P = 256: gate cols [0:256), up [256:512)
    P = N // 2
    keys = jax.random.split(jax.random.PRNGKey(3), 6)
    x_q = _rand_i8(keys[0], (M, K))
    w_q = _rand_i8(keys[1], (K, N))
    s_x = jax.random.uniform(keys[2], (M, 1), minval=0.01, maxval=0.05)
    z_x = jnp.zeros((M, 1), jnp.int32)
    s_w = jax.random.uniform(keys[3], (1, N), minval=0.001, maxval=0.01)
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=0, keepdims=True)
    nb = N // 128
    lo = -20.0 * jnp.ones((M, nb))
    hi = 20.0 * jnp.ones((M, nb))

    y, hsw, hsw_q, sxo, s1o, s2o = w8a8_swiglu_matmul_p(
        x_q, w_q, s_x, z_x, s_w, colsum, lo, hi, interpret=True)
    y_want = w8a8_matmul_p(x_q, w_q, s_x, z_x, s_w, colsum,
                           jnp.ones((M, nb)), jnp.zeros((M, nb), jnp.int32),
                           lo, hi, requant=False, fp_clamp=True,
                           per_nblock=True, interpret=True)
    np.testing.assert_allclose(y, y_want, rtol=1e-5, atol=1e-5)
    hsw_want = jax.nn.silu(y_want[:, :P]) * y_want[:, P:]
    np.testing.assert_allclose(hsw, hsw_want, rtol=1e-5, atol=1e-5)
    wq_, wsx, ws1, ws2 = ref.pdq_prologue_ref(hsw_want)
    np.testing.assert_allclose(sxo, wsx, rtol=1e-5)
    np.testing.assert_allclose(s1o, ws1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s2o, ws2, rtol=1e-4, atol=1e-4)
    assert np.abs(np.asarray(hsw_q, np.int32)
                  - np.asarray(wq_, np.int32)).max() <= 1


@pytest.mark.parametrize("impl", ["ref", "kernel"])
@pytest.mark.parametrize("shape", [(8, 1, 256, 512), (130, 257, 384)])
def test_pdq_mlp_fused_matches_unfused(impl, shape):
    """ops.pdq_mlp == pdq_dense_grouped + jnp silu(g)*u + pdq_dense, in both
    impls (ref falls back to EXACTLY that composition; the kernel path
    must agree to float tolerance), with ragged shapes covering padding."""
    *lead, d_model, d_ff = shape
    keys = jax.random.split(jax.random.PRNGKey(11), 4)
    wg = 0.1 * jax.random.normal(keys[0], (d_model, d_ff))
    wu = 0.1 * jax.random.normal(keys[1], (d_model, d_ff))
    wd = 0.1 * jax.random.normal(keys[2], (d_ff, d_model))
    grec = group_quantize_weights((wg, wu))
    drec = quantize_weight(wd)
    x = jax.random.normal(keys[3], (*lead, d_model))
    ops.set_impl(impl)
    try:
        y = ops.pdq_mlp(x, grec, drec, out_dtype=jnp.float32)
        g, u = ops.pdq_dense_grouped(x, grec, out="fp", out_dtype=jnp.float32)
        want = ops.pdq_dense(jax.nn.silu(g) * u, drec, out="fp",
                             out_dtype=jnp.float32)
    finally:
        ops.set_impl("auto")
    assert y.shape == want.shape
    if impl == "ref":
        np.testing.assert_array_equal(np.asarray(y), np.asarray(want))
    else:
        np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)
