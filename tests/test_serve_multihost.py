"""MultiHostServeEngine: real multi-process ``jax.distributed`` serving.

Pins the PR-5 contract: 2 OS processes x 4 virtual CPU devices each,
joined into one ('data', 'model') = 4x2 logical mesh by
``jax.distributed.initialize`` (gloo CPU collectives), serve
token-for-token identically to the single-process ``ShardedServeEngine``
on the SAME logical mesh - fp and PDQ-int8 - with the coordinator on
process 0 owning admission and the workers following the broadcast
command stream.

Every subprocess gets a HARD timeout: a hung coordinator/worker pair
(desynced collective, dead peer) fails the test in minutes, not the CI
job's multi-hour default.
"""
import json
import os
import socket
import subprocess
import sys
import tempfile
import textwrap

from repro.distributed.sharding import process_replicas

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TIMEOUT = 900           # hard per-subprocess cap (seconds)

# the acceptance trace: mixed lengths spanning all three buckets
_CASES = """
    import json
    import sys

    MIXED = [3, 5, 8, 9, 12, 16, 17, 23, 30, 4, 11, 27]

    def requests(cfg, lens, max_new, seed=0):
        rng = np.random.default_rng(seed)
        return [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                        max_new=max_new) for i, L in enumerate(lens)]

    # (name, lens, max_new, engine kwargs) - identical for ref and
    # multi-host runs; every case runs on the 4x2 logical mesh with 2
    # slots per data replica.
    CASES = [
        ("fp", MIXED, 6, dict(max_len=64, buckets=(8, 16, 32))),
        ("int8", MIXED, 6, dict(max_len=64, buckets=(8, 16, 32),
                                quantize_weights=True)),
        ("chunked", [4, 20, 40, 11], 4, dict(max_len=64, buckets=(8, 16),
                                             chunked_prefill=True)),
        # paged KV pool over the wire: land maps + page tables ride the
        # command payloads (single-device parity is pinned in
        # test_serve_paged.py; here paged-multihost == paged-sharded).
        # The tight pool (5 usable pages/replica, 2-page prompts growing
        # to 3) forces preempt-and-requeue through the broadcast stream.
        ("paged", MIXED, 6, dict(max_len=64, buckets=(8, 16, 32),
                                 temperature=0.9, paged=True,
                                 page_size=16)),
        ("paged_tight", [17] * 8, 30, dict(max_len=64,
                                           buckets=(8, 16, 32),
                                           temperature=0.9, paged=True,
                                           page_size=16, pool_pages=6)),
        # N-step fused decode over the wire: CMD_DECODE ships the block
        # size (workers verify lockstep), decode runs 4 steps per
        # dispatch inside the shard_map-ed scan, and ONE (slots, N)
        # token block comes back per round.  The reference run strips
        # decode_steps, so these pin multihost N=4 == sharded N=1
        # token-for-token - including preempt-and-requeue under the
        # tight pool.
        ("nstep", MIXED, 9, dict(max_len=64, buckets=(8, 16, 32),
                                 temperature=0.9, decode_steps=4)),
        ("nstep_tight", [17] * 8, 30, dict(max_len=64, buckets=(8, 16, 32),
                                           temperature=0.9, paged=True,
                                           page_size=16, pool_pages=6,
                                           decode_steps=4)),
    ]
"""

_REF = _CASES + """
    import jax
    import numpy as np
    from repro.configs import reduced_config
    from repro.launch.mesh import make_serve_mesh
    from repro.serve import Request, ShardedServeEngine

    cfg = reduced_config("stablelm-1.6b")
    from repro.models import build_model
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    mesh = make_serve_mesh(4, 2)
    out = {}
    for name, lens, max_new, kw in CASES:
        # the reference always decodes single-step: a decode_steps case
        # therefore pins multihost N-step == sharded N=1 across engines
        eng = ShardedServeEngine(cfg, params, mesh=mesh, slots_per_replica=2,
                                 **{k: v for k, v in kw.items()
                                    if k != "decode_steps"})
        reqs = requests(cfg, lens, max_new)
        eng.run(reqs)
        assert all(r.done for r in reqs)
        out[name] = [list(map(int, r.generated)) for r in reqs]
    with open(sys.argv[1], "w") as f:
        json.dump(out, f)
    print("REF OK")
"""

_MULTI = _CASES + """
    proc, port, out_path = int(sys.argv[1]), sys.argv[2], sys.argv[3]
    import jax
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(f"127.0.0.1:{port}", num_processes=2,
                               process_id=proc)
    import numpy as np
    from repro.configs import reduced_config
    from repro.launch.mesh import make_serve_mesh
    from repro.serve import MultiHostServeEngine, Request

    assert jax.process_count() == 2 and len(jax.devices()) == 8
    cfg = reduced_config("stablelm-1.6b")
    from repro.models import build_model
    params = build_model(cfg).init(jax.random.PRNGKey(0))   # same seed: host-replicated
    mesh = make_serve_mesh(4, 2)
    out = {"host_stats": {}}
    for name, lens, max_new, kw in CASES:
        eng = MultiHostServeEngine(cfg, params, mesh=mesh,
                                   slots_per_replica=2, **kw)
        if proc == 0:
            reqs = requests(cfg, lens, max_new)
            eng.run(reqs)
            eng.stop_workers()
            assert all(r.done for r in reqs)
            out[name] = [list(map(int, r.generated)) for r in reqs]
            out["host_stats"][name] = {str(k): v
                                       for k, v in eng.host_stats().items()}
            out.setdefault("stats", {})[name] = {
                k: v for k, v in eng.stats.items()
                if k.endswith("_compiles") or k.startswith("replica_")
                or k in ("preemptions", "pages_total")}
        else:
            eng.serve_worker()
    if proc == 0:
        with open(out_path, "w") as f:
            json.dump(out, f)
    print("PROC", proc, "OK")
"""


def _env(devices: int) -> dict:
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    # point the subprocesses at their own compilation-cache subdir: the
    # SPMD executables of the 2-process topology are traced ONLY here, so
    # this is where the CI job's persistent cache gets populated - while
    # staying out of the surrounding suite's cache namespace
    base = env.get("JAX_COMPILATION_CACHE_DIR")
    if base:
        env["JAX_COMPILATION_CACHE_DIR"] = os.path.join(
            base, f"multihost{devices}")
    return env


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _run(code: str, argv: list[str], devices: int) -> subprocess.CompletedProcess:
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code), *argv],
                          capture_output=True, text=True, env=_env(devices),
                          cwd=REPO, timeout=TIMEOUT)


_BIND_RACE = ("EADDRINUSE", "Address already in use",
              "address already in use")


def _spawn_fleet(code: str, argv: list[str], *, n_procs: int = 2,
                 devices: int = 4, attempts: int = 3, timeout: int = TIMEOUT,
                 hang_ok: tuple[int, ...] = ()):
    """Spawn an n-process ``jax.distributed`` fleet on a fresh ephemeral
    port; each child gets [process_id, port, *argv].  Returns
    (procs, [(stdout, stderr), ...]).

    The coordination-service port is probed with ``_free_port()`` and can
    be grabbed by another process between the probe and jax binding it
    (parallel CI shards on one host), so an EADDRINUSE death of the fleet
    is retried on a NEW port instead of failing the test.

    ``hang_ok`` names process indices that are EXPECTED to hang (injected
    fault): they are killed once every other process has exited, instead
    of burning the full timeout waiting for them."""
    for attempt in range(attempts):
        port = _free_port()
        procs = [subprocess.Popen(
            [sys.executable, "-c", textwrap.dedent(code),
             str(i), str(port), *argv],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env=_env(devices), cwd=REPO) for i in range(n_procs)]
        outs: list = [None] * n_procs
        try:
            for i, p in enumerate(procs):
                if i not in hang_ok:
                    outs[i] = p.communicate(timeout=timeout)
            for i in hang_ok:
                procs[i].kill()
                outs[i] = procs[i].communicate(timeout=60)
        finally:
            for p in procs:
                p.kill()
        raced = any(p.returncode not in (0, None)
                    and any(m in se for m in _BIND_RACE)
                    for p, (_, se) in zip(procs, outs))
        if raced and attempt < attempts - 1:
            continue
        return procs, outs
    raise AssertionError("unreachable")


def test_multihost_matches_single_process_sharded_engine():
    """Acceptance pin: 2 jax.distributed processes (4 virtual devices
    each) serve the mixed 12-request trace token-for-token identically to
    the single-process ShardedServeEngine on the same 4x2 logical mesh,
    fp AND int8 (plus a chunked-prefill case), and the coordinator's
    per-host accounting shows both processes' replicas admitting."""
    with tempfile.TemporaryDirectory() as td:
        ref_path = os.path.join(td, "ref.json")
        ref = _run(_REF, [ref_path], devices=8)
        assert ref.returncode == 0, ref.stderr[-3000:]

        mh_path = os.path.join(td, "mh.json")
        procs, outs = _spawn_fleet(_MULTI, [mh_path], n_procs=2, devices=4)
        for p, (so, se) in zip(procs, outs):
            assert p.returncode == 0, (so[-1500:], se[-3000:])

        with open(ref_path) as f:
            want = json.load(f)
        with open(mh_path) as f:
            got = json.load(f)

    for name in ("fp", "int8", "chunked", "paged", "paged_tight",
                 "nstep", "nstep_tight"):
        assert got[name] == want[name], (
            name, [i for i, (a, b) in enumerate(zip(got[name], want[name]))
                   if a != b])
    # coordinator accounting: admission spread across BOTH hosts' replicas,
    # every pool drained, and the compile counts stay bucket-bounded
    hs = got["host_stats"]["fp"]
    assert set(hs) == {"0", "1"}
    assert all(h["replicas"] == 2 and h["slots"] == 4 for h in hs.values())
    assert all(h["admits"] >= 1 and h["occupied"] == 0 for h in hs.values())
    assert sum(h["admits"] for h in hs.values()) == 12
    st = got["stats"]["fp"]
    assert st["decode_compiles"] == 1
    assert st["prefill_compiles"] <= 3
    assert min(st["replica_admits"]) >= 1
    # the tight paged pool actually preempted (and still matched the
    # single-process engine token for token above)
    assert got["stats"]["paged_tight"]["preemptions"] > 0
    # N-step blocks: one fused program, and the preempt-and-requeue path
    # stays token-exact at N=4 too (compared against the N=1 ref above)
    assert got["stats"]["nstep"]["decode_compiles"] == 1
    assert got["stats"]["nstep_tight"]["preemptions"] > 0


def test_multihost_engine_degenerate_single_process():
    """The same engine class on ONE process (no jax.distributed) is the
    sharded engine plus in-program sampling: token parity on a 2x2 mesh,
    coordinator role trivially held, worker entrypoints refused."""
    code = """
        import jax
        import numpy as np
        from repro.configs import reduced_config
        from repro.launch.mesh import make_serve_mesh
        from repro.models import build_model
        from repro.serve import MultiHostServeEngine, Request, ShardedServeEngine

        cfg = reduced_config("stablelm-1.6b")
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        lens = [3, 7, 11, 16, 5, 9]

        def run(cls):
            eng = cls(cfg, params, mesh=make_serve_mesh(2, 2),
                      slots_per_replica=2, max_len=48, buckets=(8, 16))
            rng = np.random.default_rng(0)
            reqs = [Request(uid=i,
                            prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                            max_new=4) for i, L in enumerate(lens)]
            eng.run(reqs)
            return eng, [tuple(r.generated) for r in reqs]

        ref, want = run(ShardedServeEngine)
        eng, got = run(MultiHostServeEngine)
        assert got == want, (got, want)
        assert eng.is_coordinator and eng.n_processes == 1
        assert eng.host_replicas == {0: [0, 1]}
        try:
            eng.serve_worker()
            raise SystemExit("serve_worker must refuse on the coordinator")
        except AssertionError:
            pass
        eng.stop_workers()            # no-op with no workers
        print("OK")
    """
    out = _run(code, [], devices=8)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_process_replicas_single_process_layout():
    """All data rows of a process-local mesh belong to process 0."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    n = jax.device_count()
    mesh = Mesh(np.asarray(jax.devices()).reshape(n, 1), ("data", "model"))
    assert process_replicas(mesh) == {jax.process_index(): list(range(n))}


# ------------------------------------------------- PR-7: ingress front door
# Three phases over one 2-process fleet, each vs a single-process
# ShardedServeEngine reference on the SAME 4x2 logical mesh:
#   a) vision extras ride the command stream (shape-tagged float32
#      bitcast over the int32 exchange) token-exactly,
#   b) worker-side submit_remote() traffic reaches the coordinator via
#      queue counts on the header exchange + CMD_INGRESS pulls, and the
#      worker mirrors the finished tokens without any backhaul,
#   c) the streaming service over the multi-host coordinator: cancel and
#      deadline evict ONLY their own request, peers bit-exact.

_V7_COMMON = """
    import json
    import sys

    def requests(cfg, lens, max_new, seed=0, uids=None):
        rng = np.random.default_rng(seed)
        return [Request(uid=(i if uids is None else uids[i]),
                        prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                        max_new=max_new) for i, L in enumerate(lens)]

    def ingress_prompt(cfg, i):
        rng = np.random.default_rng(100 + i)
        return rng.integers(0, cfg.vocab, 4 + 3 * i).astype(np.int32)

    VIS_LENS, VIS_NEW = [3, 5, 9, 12], 4
    ING_LENS, ING_NEW = [4, 7, 10], 5
    SVC_LENS, SVC_NEW = [3, 9, 12, 5], 12
    KW = dict(max_len=64, buckets=(8, 16, 32))
"""

_V7_REF = _V7_COMMON + """
    import jax
    import numpy as np
    from repro.configs import reduced_config
    from repro.launch.mesh import make_serve_mesh
    from repro.models import build_model
    from repro.serve import Request, ShardedServeEngine

    mesh = make_serve_mesh(4, 2)
    out = {}

    cfg_v = reduced_config("phi-3-vision-4.2b")
    params_v = build_model(cfg_v).init(jax.random.PRNGKey(0))
    extras = {"patches": (0.01 * np.random.default_rng(7).standard_normal(
        (1, cfg_v.frontend_tokens, cfg_v.d_model))).astype(np.float32)}
    eng = ShardedServeEngine(cfg_v, params_v, mesh=mesh,
                             slots_per_replica=2, **KW)
    reqs = requests(cfg_v, VIS_LENS, VIS_NEW)
    eng.run(reqs, extras=extras)
    out["extras"] = {str(r.uid): list(map(int, r.generated)) for r in reqs}

    cfg = reduced_config("stablelm-1.6b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = ShardedServeEngine(cfg, params, mesh=mesh,
                             slots_per_replica=2, **KW)
    reqs = [Request(uid=(1 << 20) | (i + 1), prompt=ingress_prompt(cfg, i),
                    max_new=ING_NEW) for i in range(len(ING_LENS))]
    eng.run(reqs)
    out["ingress"] = {str(r.uid): list(map(int, r.generated)) for r in reqs}

    eng = ShardedServeEngine(cfg, params, mesh=mesh,
                             slots_per_replica=2, **KW)
    reqs = requests(cfg, SVC_LENS, SVC_NEW)
    eng.run(reqs)
    out["svc"] = {str(r.uid): list(map(int, r.generated)) for r in reqs}

    with open(sys.argv[1], "w") as f:
        json.dump(out, f)
    print("REF OK")
"""

_V7_MULTI = _V7_COMMON + """
    proc, port, out_path = int(sys.argv[1]), sys.argv[2], sys.argv[3]
    import time
    import jax
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(f"127.0.0.1:{port}", num_processes=2,
                               process_id=proc)
    import numpy as np
    from repro.configs import reduced_config
    from repro.launch.mesh import make_serve_mesh
    from repro.models import build_model
    from repro.serve import (MultiHostServeEngine, ProtocolError, Request,
                             ServeService)

    mesh = make_serve_mesh(4, 2)
    out = {}

    # ---- phase a: vision extras over the command stream
    cfg_v = reduced_config("phi-3-vision-4.2b")
    params_v = build_model(cfg_v).init(jax.random.PRNGKey(0))
    eng = MultiHostServeEngine(cfg_v, params_v, mesh=mesh,
                               slots_per_replica=2, **KW)
    if proc == 0:
        extras = {"patches": (0.01 * np.random.default_rng(7)
                              .standard_normal((1, cfg_v.frontend_tokens,
                                                cfg_v.d_model))
                              ).astype(np.float32)}
        reqs = requests(cfg_v, VIS_LENS, VIS_NEW)
        eng.run(reqs, extras=extras)
        eng.stop_workers()
        out["extras"] = {str(r.uid): list(map(int, r.generated))
                         for r in reqs}
        try:                       # unknown key: typed, BEFORE any command
            eng._validate_extras(3, {"bogus": np.zeros((1, 2), np.float32)})
            out["bad_extra_typed"] = False
        except ProtocolError:
            out["bad_extra_typed"] = True
    else:
        eng.serve_worker()

    # ---- phase b: worker-side ingress
    cfg = reduced_config("stablelm-1.6b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = MultiHostServeEngine(cfg, params, mesh=mesh,
                               slots_per_replica=2, **KW)
    if proc == 0:
        got = []
        while len(got) < len(ING_LENS):
            got.extend(eng.poll_ingress())
        eng.run(got)
        eng.stop_workers()
        out["ingress"] = {str(r.uid): list(map(int, r.generated))
                          for r in got}
        out["remote_ingress_stat"] = eng.stats["remote_ingress"]
    else:
        uids = [eng.submit_remote(ingress_prompt(cfg, i), max_new=ING_NEW)
                for i in range(len(ING_LENS))]
        eng.serve_worker()
        out["worker_uids"] = uids
        out["worker_mirror"] = {str(u): list(map(int, eng.remote_tokens(u)))
                                for u in uids}
        out["worker_done"] = all(eng.remote_done(u) for u in uids)

    # ---- phase c: streaming service over the multi-host coordinator
    eng = MultiHostServeEngine(cfg, params, mesh=mesh,
                               slots_per_replica=2, **KW)
    if proc == 0:
        eng._clock = lambda: float(eng._round)     # deadlines in rounds
        svc = ServeService(eng, max_pending=8).start()
        prompts = [r.prompt for r in requests(cfg, SVC_LENS, SVC_NEW)]
        streams = [svc.submit(p, max_new=SVC_NEW,
                              deadline_s=(4.0 if i == 2 else None))
                   for i, p in enumerate(prompts)]
        got1 = []
        while len(got1) < 2:                       # cancel uid 1 mid-flight
            got1.extend(streams[1].drain()[0])
            time.sleep(0.005)
        svc.cancel(1, reason="client gone")
        res = {s.uid: s.result(timeout=600) for s in streams}
        svc.stop()
        eng.stop_workers()
        out["svc"] = {str(u): [list(map(int, t)), fin, err]
                      for u, (t, fin, err) in res.items()}
        out["svc_early1"] = list(map(int, got1))
        out["svc_stats"] = {"cancelled": eng.stats["cancelled"],
                            "deadline_expired": eng.stats["deadline_expired"],
                            "free": eng._free_total(), "slots": eng.slots}
    else:
        eng.serve_worker()

    suffix = "" if proc == 0 else ".worker"
    with open(out_path + suffix, "w") as f:
        json.dump(out, f)
    print("PROC", proc, "OK")
"""


def test_multihost_ingress_extras_and_service_eviction():
    with tempfile.TemporaryDirectory() as td:
        ref_path = os.path.join(td, "ref.json")
        ref = _run(_V7_REF, [ref_path], devices=8)
        assert ref.returncode == 0, ref.stderr[-3000:]
        mh_path = os.path.join(td, "mh.json")
        procs, outs = _spawn_fleet(_V7_MULTI, [mh_path], n_procs=2,
                                   devices=4)
        for p, (so, se) in zip(procs, outs):
            assert p.returncode == 0, (so[-2000:], se[-3000:])
        with open(ref_path) as f:
            want = json.load(f)
        with open(mh_path) as f:
            got = json.load(f)
        with open(mh_path + ".worker") as f:
            wrk = json.load(f)

    # a) extras: token-exact across the fleet, bad key typed-refused
    assert got["extras"] == want["extras"]
    assert got["bad_extra_typed"] is True

    # b) ingress: worker submits scheduled by the coordinator match the
    # reference AND the worker's local mirror - uids fleet-namespaced
    assert wrk["worker_uids"] == [(1 << 20) | (i + 1) for i in range(3)]
    assert got["ingress"] == want["ingress"]
    assert wrk["worker_mirror"] == want["ingress"]
    assert wrk["worker_done"] is True
    assert got["remote_ingress_stat"] == 3

    # c) service: cancel (uid 1) + deadline (uid 2) evict alone; peers
    # (0, 3) bit-exact vs the single-process reference run
    svc = got["svc"]
    for uid in ("0", "3"):
        toks, fin, err = svc[uid]
        assert fin == "complete" and toks == want["svc"][uid], uid
    toks1, fin1, err1 = svc["1"]
    all1 = got["svc_early1"] + toks1
    assert fin1 == "cancel" and err1 == "client gone"
    assert all1 == want["svc"]["1"][:len(all1)] and len(all1) < 12
    toks2, fin2, err2 = svc["2"]
    assert fin2 == "deadline" and len(toks2) < 12
    assert toks2 == want["svc"]["2"][:len(toks2)]
    st = got["svc_stats"]
    assert st["cancelled"] == 1 and st["deadline_expired"] == 1
    assert st["free"] == st["slots"]


# ----------------------------------------------- PR-9: fleet telemetry
# The launcher's --trace-out on a 2-process fleet: the coordinator writes
# ONE merged Chrome-trace JSON with a process row per jax process (worker
# launch timings ride the command-header timing slots), and the registry
# carries per-process fleet launch histograms.

_TRACE_FLEET = """
    import json
    import sys

    proc, port = int(sys.argv[1]), sys.argv[2]
    trace_path, metrics_path = sys.argv[3], sys.argv[4]

    import repro.launch.serve as launcher

    # dump the coordinator's registry at exit time, alongside the normal
    # report (the engine is launcher-internal; the wrap is the test's tap)
    _report = launcher.report_telemetry
    def report(eng, args):
        _report(eng, args)
        with open(metrics_path, "w") as f:
            f.write(eng.tel.metrics.render())
    launcher.report_telemetry = report

    launcher.main(["--reduced", "--mesh", "4x2", "--num-processes", "2",
                   "--process-id", str(proc),
                   "--coordinator", f"127.0.0.1:{port}",
                   "--requests", "6", "--max-new", "4", "--prompt-len", "12",
                   "--buckets", "8,16", "--max-len", "64",
                   "--trace-out", trace_path])
    print("PROC", proc, "OK")
"""


def test_multihost_trace_out_merges_both_processes():
    """--trace-out on a 2-process fleet: one Perfetto-loadable trace with
    spans attributed to BOTH pids (worker launches reconstructed from the
    header timing slots), fleet launch histograms labeled by process, and
    the drain printout reporting latency percentiles."""
    with tempfile.TemporaryDirectory() as td:
        trace_path = os.path.join(td, "trace.json")
        metrics_path = os.path.join(td, "metrics.prom")
        procs, outs = _spawn_fleet(_TRACE_FLEET,
                                   [trace_path, metrics_path],
                                   n_procs=2, devices=4)
        for p, (so, se) in zip(procs, outs):
            assert p.returncode == 0, (so[-2000:], se[-3000:])
        with open(trace_path) as f:
            trace = json.load(f)
        with open(metrics_path) as f:
            metrics = f.read()

    # the drain printout: histogram summaries + the trace-write notice
    so0 = outs[0][0]
    assert "ttft: n=6 p50=" in so0 and "p99=" in so0
    assert "per-token: n=" in so0
    assert "queue wait: n=6" in so0
    assert f"spans -> {trace_path}" in so0

    # Chrome-trace schema: X spans from both pids, M rows naming both
    # process tracks, every span numerically timestamped
    evs = trace["traceEvents"]
    spans = [e for e in evs if e["ph"] == "X"]
    assert {e["pid"] for e in spans} == {0, 1}
    for e in spans:
        assert isinstance(e["ts"], (int, float))
        assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
    named = {e["pid"]: e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert "coordinator" in named[0] and named[1] == "jax process 1"
    names0 = {e["name"] for e in spans if e["pid"] == 0}
    assert {"plan:prefill", "launch:prefill", "plan:decode",
            "launch:decode"} <= names0
    # worker spans: reconstructed launches only, kind-attributed, tagged
    # with the source process
    worker = [e for e in spans if e["pid"] == 1]
    assert worker and all(e["name"].startswith("launch:") for e in worker)
    assert all(e["args"]["process"] == 1 for e in worker)
    assert trace["otherData"]["dropped_spans"] == 0

    # fleet aggregation: the registry carries per-process launch
    # histograms fed from the header timing slots
    assert 'serve_launch_seconds_bucket{kind="decode"' in metrics
    assert ('serve_launch_seconds_count{kind="decode",process="1"}'
            in metrics)
    assert "serve_ttft_seconds_count 6" in metrics
