"""Kernel-launch census lint: fail CI if a pallas_call count regresses.

The PDQ execution contract is a LAUNCH BUDGET, not just numerics: the
quantized GQA block must trace to a pinned number of ``pallas_call``s
per mode, because every extra launch is a lost fusion (a standalone PDQ
prologue, an unfused attend, a split QKV triple) that quietly multiplies
serving cost long before any parity test notices.  The pins live in
scattered jaxpr tests too (tests/test_hlo_and_linops.py), but those run
in the tier-1 jobs; this tool runs in the LINT job so a census
regression fails in minutes, with the table printed, before any heavy
suite spins up.

Pinned table (DESIGN.md "Decode fast path" documents the breakdown):

  decode_fp      7   prologue+matmul for the QKV triple and for wo,
                     flash-decode attend, fused SwiGLU MLP triple
                     (gate/up epilogue computes silu(g)*u AND w_down's
                     prologue)
  decode_int8kv  7   the int8-KV attend's output stage emits wo's PDQ
                     prologue (decode_attend_i8kv_fused_p), so wo costs
                     one W8A8 matmul launch
  prefill        7   same budget at S>1: the fusions are mode-agnostic
  lin_quantized  2   one PDQ prologue + one W8A8 matmul per quantized
                     projection outside the fused blocks

Run from the repo root: ``python tools/check_census.py``.  Exits
non-zero on any mismatch - HIGHER means a lost fusion; LOWER means a
new fusion landed and the table (and the jaxpr tests) must be re-pinned
in the same change.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.attention import AttnDims, gqa_apply, gqa_init, init_cache
from repro.models.layers import mlp_apply, mlp_init, rms_norm
from repro.models.linops import lin, quantize_param_tree, quantize_weight

PINS = {
    "decode_fp": 7,
    "decode_int8kv": 7,
    "prefill": 7,
    "lin_quantized": 2,
}


def count_pallas_calls(jaxpr) -> int:
    """Recursively count pallas_call eqns in a (Closed)Jaxpr."""
    if hasattr(jaxpr, "jaxpr"):              # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    n += count_pallas_calls(sub)
    return n


def _block_setup(quant_kv: str):
    dims = AttnDims(d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
                    quant_kv=quant_kv)
    key = jax.random.PRNGKey(0)
    params = {"attn": gqa_init(key, dims, jnp.float32),
              "attn_norm": jnp.zeros((256,)),
              "ffn_norm": jnp.zeros((256,)),
              "ffn": mlp_init(jax.random.fold_in(key, 1), 256, 512,
                              jnp.float32)}
    return dims, quantize_param_tree(params), init_cache(dims, 8, 64,
                                                         jnp.float32)


def block_census(quant_kv: str, mode: str) -> int:
    """Trace one full quantized GQA block (attn norm -> QKV -> attend ->
    wo, ffn norm -> gate/up -> down) under kernel impl; count launches."""
    dims, qp, cache = _block_setup(quant_kv)

    def block(p, h, cache, positions, seq_lens):
        a, cache = gqa_apply(p["attn"], dims, rms_norm(h, p["attn_norm"]),
                             positions, mode=mode, cache=cache,
                             seq_lens=seq_lens)
        h = h + a
        return h + mlp_apply(p["ffn"], rms_norm(h, p["ffn_norm"])), cache

    S = 1 if mode == "decode" else 16
    h = jnp.ones((8, S, 256))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (8, S))
    seq_lens = jnp.full((8,), S, jnp.int32)
    ops.set_impl("kernel")
    try:
        if mode == "decode":
            jaxpr = jax.make_jaxpr(
                lambda p, h, c, pos: block(p, h, c, pos, None))(
                    qp, h, cache, pos)
        else:
            jaxpr = jax.make_jaxpr(block)(qp, h, cache, pos, seq_lens)
    finally:
        ops.set_impl("auto")
    return count_pallas_calls(jaxpr)


def lin_census() -> int:
    """One quantized projection outside the fused blocks."""
    w = quantize_weight(0.1 * jax.random.normal(jax.random.PRNGKey(1),
                                                (256, 128)))
    x = jnp.ones((8, 256))
    ops.set_impl("kernel")
    try:
        jaxpr = jax.make_jaxpr(lambda x: lin(x, w))(x)
    finally:
        ops.set_impl("auto")
    return count_pallas_calls(jaxpr)


def main() -> int:
    got = {
        "decode_fp": block_census("none", "decode"),
        "decode_int8kv": block_census("dynamic", "decode"),
        "prefill": block_census("none", "prefill"),
        "lin_quantized": lin_census(),
    }
    failed = False
    for name, pin in PINS.items():
        mark = "ok" if got[name] == pin else "REGRESSED"
        failed |= got[name] != pin
        print(f"census: {name:14s} {got[name]:2d} pallas_calls "
              f"(pinned {pin}) {mark}")
    if failed:
        print("census: FAIL - a pallas_call count moved off the pinned "
              "table. Higher = a lost fusion (fix it); lower = a new "
              "fusion (re-pin this table AND the jaxpr tests in "
              "tests/test_hlo_and_linops.py in the same change).")
        return 1
    print("census: all launch budgets hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
