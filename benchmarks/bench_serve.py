"""Bucketed batched prefill vs. per-request prefill ingest timing.

bucketed : ServeEngine's admission scheduler - prompts right-padded to a
           static bucket set, ONE multi-slot prefill_many per same-bucket
           group, one fused cache_scatter into the pooled cache; at most
           len(buckets) prefill executables per engine lifetime.
legacy   : the pre-PR-3 path - one batch-of-1 prefill per request at the
           EXACT prompt length, so XLA compiles a fresh executable per
           distinct length and the PDQ pipeline runs at batch 1.

Each cell serves a mixed-length workload end to end (max_new=1 completes
at prefill, so the wall-clock is pure ingest) on a FRESH engine, compile
time included -
recompiles per prompt length are precisely the serving cost the bucket
design removes, so they belong in the measurement.  ``speedup`` is
ingest-throughput bucketed/legacy (prompt tokens per second).

Writes ``BENCH_serve.json`` next to this file; ``--quick`` runs the CI
smoke cells only and ``--compare <baseline.json>`` fails on a >25% geomean
speedup regression (see _compare.py).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _compare import compare

from repro.configs import reduced_config
from repro.serve import Request, ServeEngine

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "BENCH_serve.json")
ARCH = "stablelm-1.6b"


def _workload(cfg, requests: int, max_prompt: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(2, max_prompt + 1, requests)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                    max_new=1) for i, L in enumerate(lens)], int(lens.sum())


def bench_cell(cfg, params, requests: int, slots: int, max_prompt: int) -> dict:
    buckets = (8, 16, 32, 64)
    out = {"requests": requests, "slots": slots, "max_prompt": max_prompt}
    for tag, batched in (("bucketed", True), ("legacy", False)):
        reqs, prompt_tokens = _workload(cfg, requests, max_prompt)
        eng = ServeEngine(cfg, params, slots=slots,
                          max_len=max(buckets) + 8, buckets=buckets,
                          batch_prefill=batched)
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        out[f"{tag}_s"] = dt
        out[f"{tag}_tok_s"] = prompt_tokens / dt
        out[f"{tag}_prefill_compiles"] = eng.stats["prefill_compiles"]
    # _compare.py convention: 'speedup' is the dimensionless trajectory pin
    out["speedup"] = out["legacy_s"] / out["bucketed_s"]
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small cells / CI smoke")
    ap.add_argument("--out", default=OUT)
    ap.add_argument("--compare", default=None, metavar="BASELINE.json",
                    help="fail on >25%% speedup regression vs this baseline")
    args = ap.parse_args()

    cfg = reduced_config(ARCH)
    from repro.models import build_model
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    # (requests, slots, max_prompt); quick cells ride in the full sweep so
    # CI smoke runs intersect the committed baseline (see --compare)
    quick_spec = [(12, 4, 32), (8, 4, 16)]
    if args.quick:
        cells_spec = quick_spec
    else:
        cells_spec = list(dict.fromkeys(
            quick_spec + [(24, 4, 32), (24, 8, 64), (48, 8, 64)]))

    cells = []
    for requests, slots, max_prompt in cells_spec:
        cell = bench_cell(cfg, params, requests, slots, max_prompt)
        cells.append(cell)
        print(f"requests={requests:3d} slots={slots} max_prompt={max_prompt:3d}  "
              f"bucketed {cell['bucketed_s']:6.2f}s "
              f"({cell['bucketed_prefill_compiles']} compiles)  "
              f"legacy {cell['legacy_s']:6.2f}s "
              f"({cell['legacy_prefill_compiles']} compiles)  "
              f"x{cell['speedup']:.2f}")

    out = {
        "meta": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "arch": ARCH,
            "jax": jax.__version__,
            "quick": bool(args.quick),
        },
        "cells": cells,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    if args.compare:
        sys.exit(compare(out, args.compare,
                         keys=("requests", "slots", "max_prompt")))


if __name__ == "__main__":
    main()
