"""Bucketed batched prefill vs. per-request prefill ingest timing, plus
the mesh-distributed data-parallel ingest scaling sweep.

Single-device mode (default, BENCH_serve.json):

bucketed : ServeEngine's admission scheduler - prompts right-padded to a
           static bucket set, ONE multi-slot prefill_many per same-bucket
           group, one fused cache_scatter into the pooled cache; at most
           len(buckets) prefill executables per engine lifetime.
legacy   : the pre-PR-3 path - one batch-of-1 prefill per request at the
           EXACT prompt length, so XLA compiles a fresh executable per
           distinct length and the PDQ pipeline runs at batch 1.

Each cell serves a mixed-length workload end to end (max_new=1 completes
at prefill, so the wall-clock is pure ingest) on a FRESH engine, compile
time included -
recompiles per prompt length are precisely the serving cost the bucket
design removes, so they belong in the measurement.  ``speedup`` is
ingest-throughput bucketed/legacy (prompt tokens per second).

Mesh mode (``--mesh DxM``, BENCH_serve_sharded.json): ShardedServeEngine
ingest throughput at data=1 vs data=D (model axis and per-replica batch
fixed), STEADY-STATE - each engine is warmed on a small workload first so
the measurement isolates the data-parallel scaling, not compile time.
``speedup`` is the tok/s ratio data=D over data=1.  On CPU the required
virtual devices are forced automatically (env set before jax imports).

Multi-process mode (``--mesh DxM --multiproc N``,
BENCH_serve_multihost.json): the SAME logical mesh served by the
single-process ShardedServeEngine (measured inline) vs the
``jax.distributed`` MultiHostServeEngine over N spawned processes.  The
GATED ``speedup`` is the per-round ingest-capacity ratio multihost /
single-process: the coordinator protocol must reproduce the
single-process schedule exactly (same admits per round), so the
deterministic expectation is 1.0 and any routing/protocol regression
(idle replicas, extra rounds) fails the gate.  Wall-clock tok/s for both
engines is recorded informationally - on a 2-core CI host all processes
share the cores, so the wall ratio measures coordination overhead plus
core contention, not replica concurrency.

Paged mode (``--paged``, BENCH_serve_paged.json): max concurrent users
at a FIXED persistent-pool byte budget.  The slot-row engine reserves a
full max_len row per slot, so its concurrency is slots = pool_bytes /
row_bytes; the paged engine spends the SAME byte budget on a page pool
and admits until the pages (not the rows) run out, so short-lived
requests pack many more concurrent users into the budget.  Each cell
serves a short-request workload through both engines (the paged pool is
sized DOWN to fit inside the slot-row engine's measured pool bytes,
asserted) and the GATED ``speedup`` is the ratio of peak concurrently
admitted users paged / slot-row - a deterministic scheduler quantity, fp
and int8-KV cells both.

Writes the JSON next to this file; ``--quick`` runs the CI smoke cells
only and ``--compare <baseline.json>`` fails on a >25% geomean speedup
regression (see _compare.py).
"""
from __future__ import annotations

import os
import sys


from repro.launch.mesh import bootstrap_mesh_env

bootstrap_mesh_env(sys.argv)

import argparse
import json
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _compare import compare

from repro.configs import reduced_config
from repro.launch.mesh import make_serve_mesh, parse_mesh
from repro.serve import Request, ServeConfig, build_engine

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "BENCH_serve.json")
OUT_SHARDED = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "BENCH_serve_sharded.json")
OUT_MULTIHOST = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_serve_multihost.json")
OUT_PAGED = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_serve_paged.json")
ARCH = "stablelm-1.6b"
MULTIPROC_TIMEOUT = 1200       # hard cap on the spawned process pair (s)


def _workload(cfg, requests: int, max_prompt: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(2, max_prompt + 1, requests)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                    max_new=1) for i, L in enumerate(lens)], int(lens.sum())


def bench_cell(cfg, params, requests: int, slots: int, max_prompt: int) -> dict:
    buckets = (8, 16, 32, 64)
    out = {"requests": requests, "slots": slots, "max_prompt": max_prompt}
    for tag, batched in (("bucketed", True), ("legacy", False)):
        reqs, prompt_tokens = _workload(cfg, requests, max_prompt)
        eng = build_engine(ServeConfig(
            slots=slots, max_len=max(buckets) + 8, buckets=buckets,
            batch_prefill=batched), cfg=cfg, params=params)
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        out[f"{tag}_s"] = dt
        out[f"{tag}_tok_s"] = prompt_tokens / dt
        out[f"{tag}_prefill_compiles"] = eng.stats["prefill_compiles"]
    # _compare.py convention: 'speedup' is the dimensionless trajectory pin
    out["speedup"] = out["legacy_s"] / out["bucketed_s"]
    return out


def bench_telemetry_cell(cfg, params, requests: int, slots: int,
                         max_prompt: int,
                         trace_out: str | None = None) -> dict:
    """Telemetry-overhead A/B: the identical mixed prefill+decode workload
    through an instrumented engine (telemetry=True; span capture too when
    ``--trace-out`` asks for the sample trace) and a bare one
    (telemetry=False, the ServeConfig A/B switch).

    The GATED ``speedup`` is per-round token capacity instrumented/bare:
    tokens landed (prompt + decoded) per device launch.  The hook points
    observe timings but never touch admission, sampling or launch
    shapes, so the deterministic expectation is exactly 1.0 - the cell
    hard-asserts the <=2% overhead budget from DESIGN.md
    "Observability", and a telemetry change that alters the schedule (an
    extra host sync, a blocking collection) fails here rather than in
    production.  Wall tok/s both ways is recorded informationally: the
    2-core CI hosts' wall clock is far noisier than 2%.
    """
    buckets = (8, 16, 32)
    out = {"requests": requests, "slots": slots, "max_prompt": max_prompt}
    tokens_served: dict[str, list] = {}
    for tag, instrumented in (("on", True), ("off", False)):
        rng = np.random.default_rng(3)
        lens = rng.integers(2, max_prompt + 1, requests)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                        max_new=8) for i, L in enumerate(lens)]
        eng = build_engine(ServeConfig(
            slots=slots, max_len=max(buckets) + 16, buckets=buckets,
            telemetry=instrumented,
            trace=instrumented and trace_out is not None),
            cfg=cfg, params=params)
        warm = [Request(uid=1000 + i, prompt=p, max_new=2) for i, p in
                enumerate(r.prompt for r in reqs[:slots])]
        eng.run(warm)                   # compile prefill AND decode
        base_rounds = (eng.stats["prefill_batches"]
                       + eng.stats["decode_steps"])
        base_tokens = (eng.stats["prefill_tokens"]
                       + eng.stats["decode_tokens"])
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        tokens_served[tag] = [list(map(int, r.generated)) for r in reqs]
        rounds = (eng.stats["prefill_batches"] + eng.stats["decode_steps"]
                  - base_rounds)
        tokens = (eng.stats["prefill_tokens"] + eng.stats["decode_tokens"]
                  - base_tokens)
        out[f"{tag}_tok_s"] = sum(len(t) for t in tokens_served[tag]) / dt
        out[f"{tag}_rounds"] = rounds
        out[f"{tag}_tokens_per_round"] = tokens / rounds
        if instrumented and trace_out:
            eng.tel.tracer.write(trace_out)
            print(f"wrote sample trace ({len(eng.tel.tracer.events())} "
                  f"spans) -> {trace_out}")
    assert tokens_served["on"] == tokens_served["off"], \
        "telemetry changed the served tokens"
    out["speedup"] = out["on_tokens_per_round"] / out["off_tokens_per_round"]
    assert 0.98 <= out["speedup"] <= 1.02, \
        f"telemetry overhead gate: capacity ratio {out['speedup']} " \
        f"outside [0.98, 1.02]"
    return out


def _mesh_workload(cfg, requests: int, lo: int, hi: int, seed: int = 0):
    """Uniform-bucket prompts (lo, hi]: one prefill executable per engine."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(lo + 1, hi + 1, requests)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                    max_new=1) for i, L in enumerate(lens)], int(lens.sum())


def bench_mesh_cell(cfg, params, *, data_hi: int, model: int, spr: int,
                    max_prompt: int, requests: int) -> dict:
    """Data-parallel ingest scaling at fixed model size and fixed
    per-replica pool shape (``spr`` slots each): the same
    ``requests``-request workload through a data=1 and a data=data_hi
    engine.

    The GATED quantity (``speedup``) is per-round ingest capacity, read
    from ``engine.stats``: real prompt tokens landed per admission round
    (= per SPMD prefill launch + cache scatter).  It is what the
    coordinator design controls - one round must fill every replica's
    free slots, so capacity scales ~data x; a routing/assignment
    regression (replicas left idle, extra rounds) shows up immediately,
    and the measure is deterministic, which a CI gate needs.

    Wall-clock tok/s for both engines is RECORDED alongside
    (``d*_tok_s``) but not gated: on the 2-core CI hosts this tree
    targets, all virtual devices share the same two cores, so the wall
    ratio measures host core saturation (observed anywhere between ~1x
    and ~2.5x run-to-run), not replica concurrency.  On hardware with >=
    ``data`` cores/chips the wall ratio tracks the capacity ratio.
    """
    out = {"requests": requests, "spr": spr, "max_prompt": max_prompt,
           "model": model, "data_hi": data_hi}
    per_round = {}
    for data in (1, data_hi):
        eng = build_engine(ServeConfig(
            mesh=make_serve_mesh(data, model), slots_per_replica=spr,
            max_len=max_prompt + 32, buckets=(max_prompt,)),
            cfg=cfg, params=params)
        cell = _ingest_cell(eng, cfg, lo=max_prompt // 2, hi=max_prompt,
                            requests=requests)
        tag = f"d{data}"
        out[f"{tag}_tok_s"] = cell["tok_s"]
        out[f"{tag}_rounds"] = cell["rounds"]
        per_round[data] = cell["tokens_per_round"]
        out[f"{tag}_tokens_per_round"] = per_round[data]
    out["speedup"] = per_round[data_hi] / per_round[1]
    return out


def run_mesh_sweep(args, cfg, params) -> dict:
    data, model = parse_mesh(args.mesh)
    # (spr, max_prompt, requests); the quick cell rides in the full sweep
    # so CI smoke runs intersect the committed baseline
    quick_spec = [(8, 256, 64)]
    cells_spec = quick_spec if args.quick else list(dict.fromkeys(
        quick_spec + [(4, 256, 64), (8, 128, 64)]))
    cells = []
    for spr, max_prompt, requests in cells_spec:
        cell = bench_mesh_cell(cfg, params, data_hi=data, model=model,
                               spr=spr, max_prompt=max_prompt,
                               requests=requests)
        cells.append(cell)
        print(f"spr={spr} max_prompt={max_prompt:3d} model={model} "
              f"requests={requests:3d}  "
              f"d1 {cell['d1_tok_s']:8.0f} tok/s ({cell['d1_rounds']} rounds)"
              f"  d{data} {cell[f'd{data}_tok_s']:8.0f} tok/s "
              f"({cell[f'd{data}_rounds']} rounds)  "
              f"capacity x{cell['speedup']:.2f}")
    return {"cells": cells,
            "keys": ("requests", "spr", "max_prompt", "model", "data_hi")}


def _multiproc_cells(quick: bool):
    """(spr, max_prompt, requests); the quick cell rides in the full sweep
    so CI smoke runs intersect the committed baseline."""
    quick_spec = [(4, 64, 24)]
    return quick_spec if quick else list(dict.fromkeys(
        quick_spec + [(2, 64, 24), (4, 32, 24)]))


def _ingest_cell(eng, cfg, *, lo: int, hi: int, requests: int) -> dict:
    """Steady-state ingest through an already-built engine: warm run to
    compile, then one measured run; reports tokens landed per admission
    round (the deterministic scheduler quantity) and wall tok/s."""
    n_slots = eng.slots
    warm, _ = _mesh_workload(cfg, n_slots, lo, hi, seed=7)
    eng.run(warm)
    base_batches = eng.stats["prefill_batches"]
    base_tokens = eng.stats["prefill_tokens"]
    reqs, prompt_tokens = _mesh_workload(cfg, requests, lo, hi)
    t0 = time.perf_counter()
    eng.run(reqs)
    jax.block_until_ready(eng.caches)
    dt = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    rounds = eng.stats["prefill_batches"] - base_batches
    tokens = eng.stats["prefill_tokens"] - base_tokens
    return {"tok_s": prompt_tokens / dt, "rounds": rounds,
            "tokens_per_round": tokens / rounds}


def _tree_bytes(tree) -> int:
    return sum(np.dtype(x.dtype).itemsize * int(np.prod(x.shape))
               for x in jax.tree_util.tree_leaves(tree))


def _peak_users(eng, reqs) -> tuple[int, float]:
    """Drain ``reqs`` through the engine round by round (the run() loop,
    instrumented): peak concurrently active requests + wall seconds."""
    eng.pending.extend(reqs)
    peak = 0
    t0 = time.perf_counter()
    while eng.pending or any(r is not None for r in eng.active):
        eng._admit(None)
        peak = max(peak, sum(r is not None for r in eng.active))
        eng.step()
    dt = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    return peak, dt


def bench_paged_cell(cfg, params, *, requests: int, max_len: int,
                     page_size: int, kv: str) -> dict:
    """Max concurrent users at a fixed persistent-pool byte budget.

    The budget is the slot-row engine's measured pool bytes (4 slots x
    max_len rows).  The paged engine gets the same budget spent on pages:
    its pool_pages is sized so its persistent pool fits INSIDE the
    budget (asserted), with scheduler rows (slots) no longer tied to
    row reservations.  The workload is short requests (one page of live
    context each), so concurrency is limited by reserved bytes on the
    slot-row engine and by actual usage on the paged one.
    """
    from repro.models import build_model

    slot_slots = 4
    buckets = (8, 16, 32)
    ref = build_engine(ServeConfig(slots=slot_slots, max_len=max_len,
                                   buckets=buckets), cfg=cfg, params=params)
    budget = _tree_bytes(ref.caches)

    # the paged pool's bytes are affine in pool_pages (page leaves scale
    # with pages, flat leaves with slots): probe two shapes to solve for
    # the largest pool_pages fitting the budget
    paged_slots = requests
    mem_len = 8 if cfg.family == "encdec" else 0
    po = build_model(cfg).paged_cache(paged_slots, max_len, mem_len,
                                     page_size)
    bytes_at = lambda p: _tree_bytes(jax.eval_shape(lambda: po.init(p)))
    per_page = bytes_at(3) - bytes_at(2)
    base = bytes_at(2) - 2 * per_page
    pool_pages = int((budget - base) // per_page)
    assert pool_pages >= 2, "budget too small for a page pool"

    eng = build_engine(ServeConfig(
        slots=paged_slots, max_len=max_len, buckets=buckets, paged=True,
        page_size=page_size, pool_pages=pool_pages), cfg=cfg, params=params)
    paged_bytes = _tree_bytes(eng.caches)
    assert paged_bytes <= budget, (paged_bytes, budget)

    def workload(seed=0):
        rng = np.random.default_rng(seed)
        lens = rng.integers(8, page_size - 8, requests)
        return [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                        max_new=4) for i, L in enumerate(lens)]

    slot_peak, slot_s = _peak_users(ref, workload())
    paged_peak, paged_s = _peak_users(eng, workload())
    return {"requests": requests, "max_len": max_len,
            "page_size": page_size, "kv": kv,
            "pool_bytes": budget, "paged_pool_bytes": paged_bytes,
            "pool_pages": pool_pages,
            "slotrow_peak_users": slot_peak, "paged_peak_users": paged_peak,
            "slotrow_s": slot_s, "paged_s": paged_s,
            # deterministic scheduler quantity: concurrently admitted
            # users at the same persistent-pool byte budget
            "speedup": paged_peak / slot_peak}


def run_paged_sweep(args) -> dict:
    """fp + int8-KV cells (int8 halves the per-token KV bytes, so the
    budget buys twice the rows on BOTH engines - the gated ratio pins
    that paging keeps its packing advantage in the quantized layout)."""
    import dataclasses

    from repro.models import build_model

    # (requests, max_len, page_size); quick == full: cells are seconds
    cells_spec = [(24, 256, 32)]
    cells = []
    for kv in ("fp", "int8"):
        cfg = reduced_config(ARCH)
        if kv == "int8":
            cfg = dataclasses.replace(cfg, quant_kv="dynamic")
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        for requests, max_len, page_size in cells_spec:
            cell = bench_paged_cell(cfg, params, requests=requests,
                                    max_len=max_len, page_size=page_size,
                                    kv=kv)
            cells.append(cell)
            print(f"kv={kv:4s} requests={requests:3d} max_len={max_len} "
                  f"page={page_size}  pool {cell['pool_bytes']/1e6:6.1f}MB  "
                  f"slot-row {cell['slotrow_peak_users']:2d} users  "
                  f"paged {cell['paged_peak_users']:2d} users "
                  f"({cell['pool_pages']} pages)  "
                  f"x{cell['speedup']:.2f}")
    return {"cells": cells,
            "keys": ("requests", "max_len", "page_size", "kv")}


def run_multiproc_child(args, cfg, params) -> None:
    """One jax.distributed process of the --multiproc sweep (spawned by the
    parent with --process-id).  The coordinator (process 0) measures every
    cell and writes the partial JSON the parent merges."""
    from repro.launch.mesh import make_serve_mesh, parse_mesh

    data, model = parse_mesh(args.mesh)
    out = []
    for spr, max_prompt, requests in _multiproc_cells(args.quick):
        eng = build_engine(ServeConfig(
            mesh=make_serve_mesh(data, model), slots_per_replica=spr,
            max_len=max_prompt + 32, buckets=(max_prompt,),
            multihost=True), cfg=cfg, params=params)
        if jax.process_index() == 0:
            cell = _ingest_cell(eng, cfg, lo=max_prompt // 2, hi=max_prompt,
                                requests=requests)
            eng.stop_workers()
            out.append(cell)
        else:
            eng.serve_worker()
    if jax.process_index() == 0:
        with open(args.multiproc_out, "w") as f:
            json.dump(out, f)


def run_multiproc_sweep(args, cfg, params) -> dict:
    """Parent: measure the single-process ShardedServeEngine inline, spawn
    the N-process pair to measure MultiHostServeEngine on the same logical
    mesh, and gate the per-round capacity ratio."""
    import subprocess
    import sys as _sys
    import tempfile

    from repro.launch.mesh import (make_serve_mesh, parse_mesh,
                                   pick_coordinator,
                                   strip_forced_device_count)

    data, model = parse_mesh(args.mesh)
    singles = []
    for spr, max_prompt, requests in _multiproc_cells(args.quick):
        eng = build_engine(ServeConfig(
            mesh=make_serve_mesh(data, model), slots_per_replica=spr,
            max_len=max_prompt + 32, buckets=(max_prompt,)),
            cfg=cfg, params=params)
        singles.append(_ingest_cell(eng, cfg, lo=max_prompt // 2,
                                    hi=max_prompt, requests=requests))

    env = dict(os.environ)
    env["XLA_FLAGS"] = strip_forced_device_count(env.get("XLA_FLAGS", ""))
    with tempfile.TemporaryDirectory() as td:
        mp_out = os.path.join(td, "mp.json")
        child_argv = [_sys.executable, os.path.abspath(__file__),
                      "--mesh", args.mesh, "--multiproc", str(args.multiproc),
                      # --num-processes sizes the child's forced device
                      # count in bootstrap_mesh_env (D*M // N per process)
                      "--num-processes", str(args.multiproc),
                      "--coordinator", pick_coordinator(args.coordinator),
                      "--multiproc-out", mp_out]
        if args.quick:
            child_argv.append("--quick")
        procs = [subprocess.Popen(child_argv + ["--process-id", str(i)],
                                  env=env)
                 for i in range(args.multiproc)]
        try:
            for p in procs:
                p.wait(timeout=MULTIPROC_TIMEOUT)
        finally:
            for p in procs:
                p.kill()
        for i, p in enumerate(procs):
            if p.returncode != 0:
                raise RuntimeError(f"multiproc bench process {i} exited "
                                   f"{p.returncode}")
        with open(mp_out) as f:
            multis = json.load(f)

    cells = []
    for (spr, max_prompt, requests), sp, mp in zip(
            _multiproc_cells(args.quick), singles, multis):
        cell = {"requests": requests, "spr": spr, "max_prompt": max_prompt,
                "nprocs": args.multiproc,
                "sp_tok_s": sp["tok_s"],
                "sp_tokens_per_round": sp["tokens_per_round"],
                "mp_tok_s": mp["tok_s"],
                "mp_rounds": mp["rounds"],
                "mp_tokens_per_round": mp["tokens_per_round"],
                # the coordinator protocol must reproduce the single-process
                # schedule exactly: capacity ratio 1.0, deterministic
                "speedup": mp["tokens_per_round"] / sp["tokens_per_round"]}
        cells.append(cell)
        print(f"spr={spr} max_prompt={max_prompt:3d} requests={requests:3d}  "
              f"single-proc {cell['sp_tok_s']:8.0f} tok/s  "
              f"{args.multiproc}-proc {cell['mp_tok_s']:8.0f} tok/s "
              f"({cell['mp_rounds']} rounds)  "
              f"capacity x{cell['speedup']:.2f}")
    return {"cells": cells,
            "keys": ("requests", "spr", "max_prompt", "nprocs")}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small cells / CI smoke")
    ap.add_argument("--out", default=None)
    ap.add_argument("--compare", default=None, metavar="BASELINE.json",
                    help="fail on >25%% speedup regression vs this baseline")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="data-parallel ingest scaling sweep on a DxM mesh "
                         "(ShardedServeEngine; data=1 vs data=D)")
    ap.add_argument("--multiproc", type=int, default=0, metavar="N",
                    help="with --mesh: compare the single-process sharded "
                         "engine vs MultiHostServeEngine over N "
                         "jax.distributed processes")
    ap.add_argument("--paged", action="store_true",
                    help="max-concurrent-users sweep at a fixed "
                         "persistent-pool byte budget: paged KV pool vs "
                         "slot-row, fp and int8 KV")
    ap.add_argument("--num-processes", type=int, default=None,
                    help=argparse.SUPPRESS)   # accepted for env bootstrap symmetry
    ap.add_argument("--process-id", type=int, default=None,
                    help=argparse.SUPPRESS)   # child mode (set by the parent)
    ap.add_argument("--coordinator", default=None,
                    help="jax.distributed coordinator for --multiproc "
                         "(default: a free local port)")
    ap.add_argument("--multiproc-out", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a sample Perfetto trace from the "
                         "instrumented engine of the telemetry A/B cell "
                         "(single-device sweep only)")
    args = ap.parse_args()

    if args.process_id is not None:
        # --multiproc child: join the jax.distributed job BEFORE any
        # device query, then follow the coordinator
        if not args.coordinator:
            raise SystemExit("a --process-id child needs an explicit "
                             "--coordinator HOST:PORT")
        from repro.launch.mesh import init_distributed
        init_distributed(args.coordinator, args.multiproc, args.process_id)

    cfg = reduced_config(ARCH)
    from repro.models import build_model
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    if args.process_id is not None:
        run_multiproc_child(args, cfg, params)
        return

    if args.paged:
        sweep = run_paged_sweep(args)
        out = {
            "meta": {
                "backend": jax.default_backend(),
                "device": str(jax.devices()[0]),
                "arch": ARCH,
                "jax": jax.__version__,
                "quick": bool(args.quick),
            },
            "cells": sweep["cells"],
        }
        out_path = args.out or OUT_PAGED
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
            f.write("\n")
        print(f"wrote {out_path}")
        if args.compare:
            sys.exit(compare(out, args.compare, keys=sweep["keys"]))
        return

    if args.mesh and args.multiproc:
        sweep = run_multiproc_sweep(args, cfg, params)
        out = {
            "meta": {
                "backend": jax.default_backend(),
                "device": str(jax.devices()[0]),
                "arch": ARCH,
                "jax": jax.__version__,
                "mesh": args.mesh,
                "nprocs": args.multiproc,
                "quick": bool(args.quick),
            },
            "cells": sweep["cells"],
        }
        out_path = args.out or OUT_MULTIHOST
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
            f.write("\n")
        print(f"wrote {out_path}")
        if args.compare:
            sys.exit(compare(out, args.compare, keys=sweep["keys"]))
        return

    if args.mesh:
        sweep = run_mesh_sweep(args, cfg, params)
        out = {
            "meta": {
                "backend": jax.default_backend(),
                "device": str(jax.devices()[0]),
                "arch": ARCH,
                "jax": jax.__version__,
                "mesh": args.mesh,
                "quick": bool(args.quick),
            },
            "cells": sweep["cells"],
        }
        out_path = args.out or OUT_SHARDED
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
            f.write("\n")
        print(f"wrote {out_path}")
        if args.compare:
            sys.exit(compare(out, args.compare, keys=sweep["keys"]))
        return

    # (requests, slots, max_prompt); quick cells ride in the full sweep so
    # CI smoke runs intersect the committed baseline (see --compare)
    quick_spec = [(12, 4, 32), (8, 4, 16)]
    if args.quick:
        cells_spec = quick_spec
    else:
        cells_spec = list(dict.fromkeys(
            quick_spec + [(24, 4, 32), (24, 8, 64), (48, 8, 64)]))

    cells = []
    for requests, slots, max_prompt in cells_spec:
        cell = bench_cell(cfg, params, requests, slots, max_prompt)
        cells.append(cell)
        print(f"requests={requests:3d} slots={slots} max_prompt={max_prompt:3d}  "
              f"bucketed {cell['bucketed_s']:6.2f}s "
              f"({cell['bucketed_prefill_compiles']} compiles)  "
              f"legacy {cell['legacy_s']:6.2f}s "
              f"({cell['legacy_prefill_compiles']} compiles)  "
              f"x{cell['speedup']:.2f}")

    # telemetry-overhead A/B (distinct cell key; quick AND full, so the
    # <=2% gate runs on every CI smoke)
    cell = bench_telemetry_cell(cfg, params, 16, 4, 32,
                                trace_out=args.trace_out)
    cells.append(cell)
    print(f"telemetry A/B requests= 16 slots=4 max_prompt= 32  "
          f"on {cell['on_tok_s']:7.0f} tok/s  "
          f"off {cell['off_tok_s']:7.0f} tok/s  "
          f"capacity x{cell['speedup']:.2f} (gate [0.98, 1.02])")

    out = {
        "meta": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "arch": ARCH,
            "jax": jax.__version__,
            "quick": bool(args.quick),
        },
        "cells": cells,
    }
    out_path = args.out or OUT
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")
    if args.compare:
        sys.exit(compare(out, args.compare,
                         keys=("requests", "slots", "max_prompt")))


if __name__ == "__main__":
    main()
