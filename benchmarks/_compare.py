"""Baseline comparison for the BENCH_*.json perf trajectory.

``compare(current, baseline_path, keys)`` matches cells between the
current run and a committed baseline on the given shape keys and fails
(returns non-zero) when the GEOMEAN *speedup ratio* over the matched
cells regressed by more than ``threshold`` (default 25%).  Speedup
(fused/unfused wall-time ratio) is dimensionless, so the check is
meaningful across hosts of different absolute speed, and the geomean
absorbs the per-cell timer noise of small smoke shapes while still
catching a systemic regression (losing the fusion shifts every cell at
once).  Per-cell ratios are printed informationally.  Runs on different
backends (e.g. a TPU baseline checked from a CPU CI host) are skipped
with a note rather than failed.
"""
from __future__ import annotations

import json
import math


def compare(current: dict, baseline_path: str, keys: tuple[str, ...],
            threshold: float = 0.25) -> int:
    """Return 0 if the matched-cell geomean speedup is within threshold of
    the baseline's, else 1.

    A baseline that cannot be read as the expected shape (corrupt JSON,
    missing 'cells'/'speedup' fields) FAILS the gate with a message rather
    than crashing: a silently unparseable committed baseline would
    otherwise disable the regression check it exists for.
    """
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
        base_by_key = {tuple(c[k] for k in keys): c
                       for c in baseline["cells"]}
        for c in baseline["cells"]:
            # the gate takes log(speedup) on the raw value: anything
            # non-numeric (JSON strings) or <= 0 must fail HERE, with the
            # message, not crash at the math below
            if (isinstance(c["speedup"], bool)
                    or not isinstance(c["speedup"], (int, float))
                    or not c["speedup"] > 0):
                raise ValueError(f"cell speedup {c['speedup']!r} is not a "
                                 "positive number")
    except (OSError, ValueError, KeyError, TypeError) as e:
        print(f"compare: FAIL - baseline {baseline_path} is unreadable or "
              f"malformed ({type(e).__name__}: {e}); regenerate and commit it")
        return 1
    cur_backend = current.get("meta", {}).get("backend")
    base_backend = baseline.get("meta", {}).get("backend")
    if cur_backend != base_backend:
        print(f"compare: SKIP - backend mismatch (current={cur_backend}, "
              f"baseline={base_backend})")
        return 0
    log_cur, log_base = 0.0, 0.0
    matched = 0
    for cell in current["cells"]:
        key = tuple(cell[k] for k in keys)
        base = base_by_key.get(key)
        if base is None:
            continue
        matched += 1
        log_cur += math.log(cell["speedup"])
        log_base += math.log(base["speedup"])
        print(f"compare: cell {dict(zip(keys, key))}  speedup "
              f"{cell['speedup']:.2f}x vs baseline {base['speedup']:.2f}x")
    if matched == 0:
        print(f"compare: WARNING - no cells of {baseline_path} match this "
              f"sweep; nothing checked")
        return 0
    geo_cur = math.exp(log_cur / matched)
    geo_base = math.exp(log_base / matched)
    ok = geo_cur >= geo_base * (1.0 - threshold)
    print(f"compare: geomean speedup {geo_cur:.2f}x vs baseline "
          f"{geo_base:.2f}x over {matched} cells -> "
          f"{'ok' if ok else f'REGRESSED more than {threshold:.0%}'} "
          f"({baseline_path})")
    return 0 if ok else 1
