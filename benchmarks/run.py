"""Benchmark harness - one entry per paper table/figure + the roofline.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick]

Emits ``name,us_per_call,derived`` CSV lines per benchmark as the summary,
after each section's human-readable output.  Artifacts (json/md) land in
benchmarks/artifacts/.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

CSV: list[tuple[str, float, str]] = []


def _csv(name: str, us: float, derived: str):
    CSV.append((name, us, derived))


def bench_kernels():
    """Microbench the PDQ kernel surfaces (CPU ref-path timings; the Pallas
    kernels themselves are TPU-target, validated in interpret mode by tests)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (512, 2048))
    xq = jax.random.randint(key, (512, 2048), -128, 128, jnp.int32).astype(jnp.int8)
    wq = jax.random.randint(key, (2048, 2048), -128, 128, jnp.int32).astype(jnp.int8)

    def timeit(fn, *a, reps=5):
        fn(*a)
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(*a)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / reps * 1e6

    t = timeit(jax.jit(lambda v: ops.act_stats(v)), x)
    _csv("kernel.act_stats_512x2048", t, "fused s1+s2 single pass")
    t = timeit(jax.jit(lambda a, b: ops.w8a8_matmul(a, b, 0.01, 0, 0.01)), xq, wq)
    _csv("kernel.w8a8_512x2048x2048", t, "int8 matmul + dequant epilogue")
    t = timeit(jax.jit(lambda v: ops.quantize(v, 0.05, 0)), x)
    _csv("kernel.quantize_512x2048", t, "affine int8 quantize")


def bench_paper_tables(quick: bool):
    import paper_tables
    res = paper_tables.run_tables(n_eval=128 if quick else 384)
    print(paper_tables.render(res))
    import json
    from _cnn_common import ART
    with open(os.path.join(ART, "paper_tables.json"), "w") as f:
        json.dump(res, f, indent=1)
    for domain in ("in_domain", "ood"):
        for task, row in res[domain].items():
            gap_pdq = row["fp32"] - row["ours_C"]
            gap_static = row["fp32"] - row["static_C"]
            _csv(f"table.{domain}.{task}", 0.0,
                 f"fp32={row['fp32']:.4f} ours_C={row['ours_C']:.4f} "
                 f"dyn_C={row['dynamic_C']:.4f} static_C={row['static_C']:.4f} "
                 f"pdq_gap={gap_pdq:.4f} static_gap={gap_static:.4f}")


def bench_fig3():
    import fig3_latency
    res = fig3_latency.measure()
    import json
    from _cnn_common import ART
    with open(os.path.join(ART, "fig3_latency.json"), "w") as f:
        json.dump(res, f, indent=1)
    a = res["vs_cin"]
    slope = (a[-1]["est_us"] - a[0]["est_us"]) / (a[-1]["cin"] - a[0]["cin"])
    _csv("fig3.est_vs_cin", a[-1]["est_us"], f"linear slope ~{slope:.2f}us/ch")
    b = res["vs_cout"]
    _csv("fig3.est_vs_cout", b[-1]["est_us"],
         f"constant: {b[0]['est_us']:.1f} -> {b[-1]['est_us']:.1f}us")
    g = res["vs_gamma"]
    _csv("fig3.est_vs_gamma", g[-1]["est_us"],
         f"gamma 1->8 time {g[0]['est_us']:.1f}->{g[-1]['est_us']:.1f}us "
         f"positions /{g[0]['positions'] // g[-1]['positions']}")


def bench_fig4():
    import fig4_stride
    rows = fig4_stride.run()
    for r in rows:
        _csv(f"fig4.gamma{r['gamma']}.{r['granularity']}", 0.0,
             f"in={r['in_domain']:.4f} ood={r['ood']:.4f}")


def bench_fig5():
    import fig5_calibsize
    rows = fig5_calibsize.run()
    for r in rows:
        _csv(f"fig5.S{r['n_calib']}.{r['granularity']}", 0.0,
             f"acc={r['acc_mean']:.4f}+-{r['acc_std']:.4f}")


def bench_roofline():
    import roofline
    rows = roofline.full_table()
    md = roofline.render_markdown(rows)
    with open(os.path.join(roofline.OUT, "roofline.md"), "w") as f:
        f.write(md)
    import json
    with open(os.path.join(roofline.OUT, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)
    ok = [r for r in rows if r.get("status") == "ok"]
    for r in ok:
        _csv(f"roofline.{r['arch']}.{r['shape']}", r["bound_s"] * 1e6,
             f"dom={r['dominant']} frac={r.get('roofline_frac', 0):.3f}")
    if not ok:
        _csv("roofline", 0.0, "no dry-run artifacts yet - run repro.launch.dryrun")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: kernels,tables,fig3,fig4,fig5,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    if want("kernels"):
        bench_kernels()
    if want("tables"):
        bench_paper_tables(args.quick)
    if want("fig3"):
        bench_fig3()
    if want("fig4") and not args.quick:
        bench_fig4()
    if want("fig5") and not args.quick:
        bench_fig5()
    if want("roofline"):
        bench_roofline()

    print("\nname,us_per_call,derived")
    for name, us, derived in CSV:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
