"""Shared wall-clock timing helper for the BENCH_* harnesses."""
from __future__ import annotations

import time

import jax


def median_time(fn, x, iters: int) -> float:
    """Median wall-clock seconds per call, after compile + warmup."""
    y = fn(x)
    jax.block_until_ready(y)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
