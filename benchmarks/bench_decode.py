"""Per-step decode latency: the N-step fused decode block vs single-step.

Each cell serves the same decode-heavy workload (4 slots in lockstep,
max_new=33: one prefill token + 32 decode tokens per request) through
two engines on the same warm params:

n1     : ``decode_steps=1`` - one host dispatch, one (slots, 1) backhaul
         and one scheduler round per decoded token (the pre-fast-path
         engine).
fused  : ``decode_steps=N`` - N decode steps run inside one ``lax.scan``
         per dispatch, cache state staying on device; the host sees one
         (slots, N) token block per round.

Both engines are warmed first (prefill AND decode compiled), so the
timed window isolates steady-state decode.  Wall-clock per-step latency
(``*_step_ms``) and token throughput are RECORDED informationally - on
the 2-core CI hosts the wall clock mostly measures host Python + XLA
CPU overlap, which is exactly what the fused block amortizes, but it is
too noisy to gate.

The GATED ``speedup`` is host dispatches per decoded token, n1/fused -
a deterministic scheduler quantity read from ``engine.stats``
(``decode_steps`` counts dispatches, ``decode_tokens`` consumed
tokens).  With every row running full blocks it is EXACTLY N, asserted
per cell; a scheduler regression that splits blocks (lost budget math,
early flushes) fails the cell before the geomean gate even runs.  Every
cell also asserts token-for-token parity between the two engines - the
fast path is not allowed to buy its dispatch reduction with a single
changed token.

Cells: N in {4, 16} x {fp, int8} KV x {slot-row, paged} layout.
Writes BENCH_decode.json next to this file; ``--quick`` runs the N=4
cells only and ``--compare <baseline.json>`` fails on a >25% geomean
regression (see _compare.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _compare import compare

from repro.configs import reduced_config
from repro.models import build_model
from repro.serve import Request, ServeConfig, build_engine

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "BENCH_decode.json")
ARCH = "stablelm-1.6b"
SLOTS = 4
MAX_NEW = 33            # 1 prefill token + 32 decode tokens per request


def _workload(cfg, seed: int = 0, uid0: int = 0):
    rng = np.random.default_rng(seed)
    return [Request(uid=uid0 + i,
                    prompt=rng.integers(0, cfg.vocab, 7).astype(np.int32),
                    max_new=MAX_NEW) for i in range(SLOTS)]


def _serve(cfg, params, n: int, paged: bool) -> dict:
    """One warmed engine at decode_steps=n: tokens, dispatch stats, wall."""
    eng = build_engine(ServeConfig(
        slots=SLOTS, max_len=64, buckets=(8,), temperature=0.9,
        decode_steps=n, paged=paged, page_size=16),
        cfg=cfg, params=params)
    eng.run(_workload(cfg, seed=9, uid0=1000))     # compile prefill + decode
    base_steps = eng.stats["decode_steps"]
    base_tokens = eng.stats["decode_tokens"]
    reqs = _workload(cfg)
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    assert eng.stats["decode_compiles"] == 1
    dispatches = eng.stats["decode_steps"] - base_steps
    tokens = eng.stats["decode_tokens"] - base_tokens
    # lockstep rows running full blocks: the accounting is deterministic
    assert tokens == SLOTS * (MAX_NEW - 1), (tokens, n)
    assert dispatches == (MAX_NEW - 1) // n, (dispatches, n)
    return {"tokens": {r.uid: list(map(int, r.generated)) for r in reqs},
            "dispatches": dispatches, "decode_tokens": tokens,
            "wall_s": dt, "steps": (MAX_NEW - 1)}


def bench_cell(cfg, params, *, n: int, kv: str, layout: str) -> dict:
    paged = layout == "paged"
    n1 = _serve(cfg, params, 1, paged)
    fused = _serve(cfg, params, n, paged)
    assert fused["tokens"] == n1["tokens"], \
        f"N={n} {kv}/{layout}: fused decode changed the served tokens"
    out = {"n": n, "kv": kv, "layout": layout,
           "decode_tokens": fused["decode_tokens"]}
    for tag, r in (("n1", n1), ("fused", fused)):
        out[f"{tag}_dispatches"] = r["dispatches"]
        out[f"{tag}_dispatch_per_tok"] = r["dispatches"] / r["decode_tokens"]
        out[f"{tag}_step_ms"] = 1e3 * r["wall_s"] / r["steps"]
        out[f"{tag}_tok_s"] = r["decode_tokens"] / r["wall_s"]
    # deterministic gate: host-dispatch reduction per decoded token
    out["speedup"] = out["n1_dispatch_per_tok"] / out["fused_dispatch_per_tok"]
    assert out["speedup"] == n, (out["speedup"], n)
    out["wall_speedup"] = n1["wall_s"] / fused["wall_s"]   # informational
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="N=4 cells only / CI smoke")
    ap.add_argument("--out", default=None)
    ap.add_argument("--compare", default=None, metavar="BASELINE.json",
                    help="fail on >25%% speedup regression vs this baseline")
    args = ap.parse_args()

    cells = []
    for kv in ("fp", "int8"):
        cfg = reduced_config(ARCH)
        if kv == "int8":
            cfg = dataclasses.replace(cfg, quant_kv="dynamic")
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        for layout in ("slotrow", "paged"):
            # quick cells ride in the full sweep so CI smoke runs
            # intersect the committed baseline (see --compare)
            for n in ((4,) if args.quick else (4, 16)):
                cell = bench_cell(cfg, params, n=n, kv=kv, layout=layout)
                cells.append(cell)
                print(f"kv={kv:4s} layout={layout:7s} N={n:2d}  "
                      f"n1 {cell['n1_step_ms']:6.2f} ms/step  "
                      f"fused {cell['fused_step_ms']:6.2f} ms/step "
                      f"(wall x{cell['wall_speedup']:.2f})  "
                      f"dispatch/tok {cell['n1_dispatch_per_tok']:.3f} -> "
                      f"{cell['fused_dispatch_per_tok']:.3f}  "
                      f"x{cell['speedup']:.0f}")

    out = {
        "meta": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "arch": ARCH,
            "jax": jax.__version__,
            "quick": bool(args.quick),
        },
        "cells": cells,
    }
    out_path = args.out or OUT
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")
    if args.compare:
        sys.exit(compare(out, args.compare, keys=("n", "kv", "layout")))


if __name__ == "__main__":
    main()
