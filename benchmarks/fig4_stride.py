"""Paper Fig. 4: impact of the sampling stride gamma on accuracy,
per-tensor (T) and per-channel (C), in-domain and OOD."""
from __future__ import annotations

import json
import os

import numpy as np

from repro.data.corruptions import corrupt_batch

from _cnn_common import ART, accuracy, calibrate_task, eval_data, get_trained

GAMMAS = (1, 2, 4, 8)
TASK = "cls_resnet"


def run() -> list[dict]:
    cfg, params = get_trained(TASK)
    imgs, labels = eval_data(TASK, 384)
    imgs_ood = corrupt_batch(imgs, np.random.default_rng(1), max_severity=3)
    rows = []
    for gamma in GAMMAS:
        for pc in (False, True):
            qstate = calibrate_task(TASK, params, per_channel=pc, gamma=gamma)
            rows.append({
                "gamma": gamma, "granularity": "C" if pc else "T",
                "in_domain": accuracy(TASK, params, imgs, labels, "pdq", pc,
                                      qstate, gamma),
                "ood": accuracy(TASK, params, imgs_ood, labels, "pdq", pc,
                                qstate, gamma),
            })
    return rows


def main():
    rows = run()
    with open(os.path.join(ART, "fig4_stride.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print("\n## Fig 4: gamma sweep (PDQ accuracy)")
    for r in rows:
        print(f"  gamma={r['gamma']:2d} {r['granularity']}  "
              f"in={r['in_domain']:.4f}  ood={r['ood']:.4f}")


if __name__ == "__main__":
    main()
