"""Shared helpers for the paper-track benchmarks: train-once model cache,
calibration, and quantized evaluation."""
from __future__ import annotations

import os
import pickle

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import run_calibration, spec_for_mode
from repro.models.cnn import (CNNConfig, cnn_apply, make_gratings,
                              train_cnn)

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")
os.makedirs(ART, exist_ok=True)

TASKS = {
    # paper Table-1 rows -> our proxies (same protocol, synthetic data);
    # 16 classes + heavy noise keep fp32 off the ceiling so quantization
    # gaps are visible.
    "cls_resnet": CNNConfig(arch="mini_resnet", width=24, res=20, n_classes=16),
    "cls_mobilenet": CNNConfig(arch="mini_mobilenet", width=24, res=20, n_classes=16),
    "seg_unet": CNNConfig(arch="mini_seg", width=24, res=20, n_classes=16),
}
TRAIN_STEPS = {"cls_resnet": 250, "cls_mobilenet": 250, "seg_unet": 200}


def get_trained(task: str):
    cfg = TASKS[task]
    path = os.path.join(ART, f"cnn_{task}.pkl")
    if os.path.exists(path):
        with open(path, "rb") as f:
            return cfg, pickle.load(f)
    params = train_cnn(cfg, steps=TRAIN_STEPS[task], batch=32,
                       segmentation=task.startswith("seg"))
    params = jax.device_get(params)
    with open(path, "wb") as f:
        pickle.dump(params, f)
    return cfg, params


def eval_data(task: str, n: int = 512, seed: int = 77):
    cfg = TASKS[task]
    imgs, labels = make_gratings(seed, n, res=cfg.res, n_classes=cfg.n_classes,
                                 noise=0.45)
    if task.startswith("seg"):
        from repro.models.cnn import seg_labels
        labels = seg_labels(labels, cfg.res, cfg.n_classes)
    return imgs, labels


def calib_data(task: str, n: int = 16, seed: int = 5):
    cfg = TASKS[task]
    imgs, _ = make_gratings(seed, n, res=cfg.res, n_classes=cfg.n_classes,
                            noise=0.45)
    return [jnp.asarray(imgs[i: i + 8]) for i in range(0, n, 8)]


def apply_fn_for(cfg: CNNConfig):
    def apply_fn(params, batch, *, spec, qstate, tape=None):
        return cnn_apply(params, batch, cfg=cfg, spec=spec, qstate=qstate,
                         tape=tape)
    return apply_fn


def calibrate_task(task: str, params, per_channel: bool, gamma: int = 1,
                   n_calib: int = 16, seed: int = 5):
    cfg = TASKS[task]
    spec = spec_for_mode("pdq", per_channel=per_channel, gamma=gamma)
    return run_calibration(apply_fn_for(cfg), params,
                           calib_data(task, n_calib, seed), spec)


def accuracy(task: str, params, imgs, labels, mode: str, per_channel: bool,
             qstate=None, gamma: int = 1, batch: int = 128) -> float:
    cfg = TASKS[task]
    spec = spec_for_mode(mode, per_channel=per_channel, gamma=gamma)
    fn = jax.jit(lambda p, x, q: cnn_apply(p, x, cfg=cfg, spec=spec, qstate=q))
    correct = total = 0
    for i in range(0, len(imgs), batch):
        xb = jnp.asarray(imgs[i: i + batch])
        yb = labels[i: i + batch]
        logits = fn(params, xb, qstate if qstate is not None else {})
        pred = np.asarray(jnp.argmax(logits, -1))
        correct += (pred == yb).sum()
        total += yb.size
    return correct / total
