"""Paper Fig. 5: impact of the calibration-set size #S (3 seeds each,
as in the paper, to wash out sample-selection luck)."""
from __future__ import annotations

import json
import os

import numpy as np

from _cnn_common import ART, accuracy, calibrate_task, eval_data, get_trained

SIZES = (16, 32, 64, 128)
TASK = "cls_resnet"
GAMMA = 4            # the paper picks the best stride (gamma=4) for this study


def run() -> list[dict]:
    cfg, params = get_trained(TASK)
    imgs, labels = eval_data(TASK, 384)
    rows = []
    for n in SIZES:
        for pc in (False, True):
            accs = []
            for seed in (5, 6, 7):
                qstate = calibrate_task(TASK, params, per_channel=pc,
                                        gamma=GAMMA, n_calib=n, seed=seed)
                accs.append(accuracy(TASK, params, imgs, labels, "pdq", pc,
                                     qstate, GAMMA))
            rows.append({"n_calib": n, "granularity": "C" if pc else "T",
                         "acc_mean": float(np.mean(accs)),
                         "acc_std": float(np.std(accs))})
    return rows


def main():
    rows = run()
    with open(os.path.join(ART, "fig5_calibsize.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print("\n## Fig 5: calibration-set size sweep (PDQ, gamma=4)")
    for r in rows:
        print(f"  #S={r['n_calib']:4d} {r['granularity']}  "
              f"acc={r['acc_mean']:.4f} +- {r['acc_std']:.4f}")


if __name__ == "__main__":
    main()
