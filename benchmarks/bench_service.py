"""Serving front-door overload bench: the streaming service under a
deterministic 3x-capacity open-loop storm, vs batch ``run()``.

Each cell drives a ``ServeService`` over a fresh ``ServeEngine`` with a
burst-injection fault plan: ``per_round`` requests hit the admission
queue at the top of every scheduler round for ``rounds`` rounds - about
3x the engine's slot capacity, so the bounded queue sheds most of the
offered load.  Because bursts are keyed on the scheduler round (never
wall-clock), the shed/accept split and the full schedule replay exactly.

The GATED ``speedup`` is the round-capacity ratio

    (accepted tokens / service rounds) / (same requests / batch rounds)

where the denominator re-runs exactly the accepted request set through
batch ``engine.run()`` on a fresh engine.  Both schedules are
round-deterministic, so the ratio is timer-noise-free: it measures how
much per-round capacity the continuous-admission loop loses to ingress
handling (watermark checks, cancel scans, deadline sweeps) relative to
the batch scheduler on identical work.  A regression here means the
front door started costing rounds, not just microseconds.

Recorded informationally per cell (wall-clock, varies by host):
``accepted_tok_s`` (end-to-end accepted-token throughput),
``ttft_p50_ms``/``ttft_p99_ms`` (submit -> first token, from the
TokenStream timestamps of a streamed follow-up wave against the warm
service), and ``shed_rate`` (fraction of offered requests refused at the
watermark - deterministic, so drift flags an admission change even
before the gate trips).

``--quick`` runs the CI smoke cell only; ``--compare <baseline.json>``
fails on a >25% geomean regression (see _compare.py).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _compare import compare

from repro.configs import reduced_config
from repro.distributed.fault import FaultPlan
from repro.serve import Request, ServeConfig, ServeService, build_engine

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "BENCH_service.json")
ARCH = "stablelm-1.6b"


def _engine(cfg, params, slots, fault=None):
    return build_engine(ServeConfig(slots=slots, max_len=64, buckets=(8,),
                                    fault=fault), cfg=cfg, params=params)


def _wait(pred, timeout=900.0):
    deadline = time.monotonic() + timeout
    while not pred():
        if time.monotonic() > deadline:
            raise TimeoutError("bench condition not reached")
        time.sleep(0.01)


def bench_cell(cfg, params, *, slots: int, watermark: int, rounds: int,
               per_round: int, max_new: int) -> dict:
    out = {"slots": slots, "watermark": watermark, "rounds": rounds,
           "per_round": per_round}

    # --- overload soak: deterministic burst storm through the service
    burst = {r: [[3 + (r + i) % 6, max_new] for i in range(per_round)]
             for r in range(rounds)}
    plan = FaultPlan(burst_rounds=burst)
    eng = _engine(cfg, params, slots, fault=plan.injector())
    svc = ServeService(eng, max_pending=watermark).start()
    offered = rounds * per_round
    t0 = time.perf_counter()
    # every offered request terminal (monotonic counters: no transient
    # window mid queue-to-slot handoff, unlike polling pending/active)
    _wait(lambda: eng.stats["shed"] + eng.stats["completed"] == offered)
    wall = time.perf_counter() - t0
    svc.stop()
    accepted = list(eng.finished)
    acc_tokens = sum(len(r.generated) for r in accepted)
    assert eng.stats["shed"] + eng.stats["completed"] == offered
    out["offered"] = offered
    out["accepted"] = len(accepted)
    out["shed_rate"] = eng.stats["shed"] / offered
    out["service_rounds"] = eng._round
    out["accepted_tok_s"] = acc_tokens / wall
    svc_per_round = acc_tokens / eng._round

    # --- batch reference: the SAME accepted set through run()
    ref = _engine(cfg, params, slots)
    copies = [Request(uid=r.uid, prompt=np.asarray(r.prompt),
                      max_new=r.max_new) for r in accepted]
    ref.run(copies)
    assert all(c.done and c.error is None for c in copies)
    assert ([tuple(c.generated) for c in copies]
            == [tuple(r.generated) for r in accepted]), \
        "service streams diverged from batch run()"
    out["batch_rounds"] = ref._round
    batch_per_round = acc_tokens / ref._round
    # gated: per-round capacity kept by the continuous-admission loop
    out["speedup"] = svc_per_round / batch_per_round

    # --- TTFT wave: streamed submits against the warm service
    eng2 = _engine(cfg, params, slots)
    svc2 = ServeService(eng2, max_pending=watermark).start()
    rng = np.random.default_rng(1)
    streams = []
    for i in range(2 * slots):
        streams.append(svc2.submit(
            rng.integers(0, cfg.vocab, 4 + i % 5).astype(np.int32),
            max_new=max_new))
    for s in streams:
        s.result(timeout=900)
    svc2.stop()
    ttft = sorted(1e3 * (s.first_token_at - s.submitted_at)
                  for s in streams if s.first_token_at is not None)
    out["ttft_p50_ms"] = ttft[len(ttft) // 2]
    out["ttft_p99_ms"] = ttft[min(len(ttft) - 1,
                                  int(0.99 * (len(ttft) - 1)))]
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke cell only")
    ap.add_argument("--out", default=None)
    ap.add_argument("--compare", default=None, metavar="BASELINE.json",
                    help="fail on >25%% speedup regression vs this baseline")
    args = ap.parse_args()

    cfg = reduced_config(ARCH)
    from repro.models import build_model
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    cells = [dict(slots=2, watermark=4, rounds=40, per_round=6, max_new=4)]
    if not args.quick:
        cells += [dict(slots=4, watermark=8, rounds=60, per_round=12,
                       max_new=4),
                  dict(slots=4, watermark=16, rounds=60, per_round=12,
                       max_new=8)]

    results = []
    for c in cells:
        cell = bench_cell(cfg, params, **c)
        print(f"slots={cell['slots']} watermark={cell['watermark']} "
              f"rounds={cell['rounds']}x{cell['per_round']}: "
              f"shed={cell['shed_rate']:.2f} "
              f"speedup={cell['speedup']:.3f} "
              f"acc={cell['accepted_tok_s']:.1f} tok/s "
              f"ttft p50={cell['ttft_p50_ms']:.1f}ms "
              f"p99={cell['ttft_p99_ms']:.1f}ms")
        results.append(cell)

    out = {
        "meta": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "arch": ARCH,
            "jax": jax.__version__,
            "quick": bool(args.quick),
        },
        "cells": results,
    }
    out_path = args.out or OUT
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}")
    if args.compare:
        sys.exit(compare(out, args.compare,
                         keys=("slots", "watermark", "rounds", "per_round")))


if __name__ == "__main__":
    main()
