"""Paper Tables 1 & 2: static / dynamic / PDQ x per-tensor / per-channel,
in-domain and out-of-domain (corruption suite), on trained Mini-CNNs.

Also reports surrogate fidelity (predicted vs empirical pre-activation
moments) - the paper's core modelling assumption, verified directly.
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core import spec_for_mode
from repro.core.policy import as_observe
from repro.data.corruptions import corrupt_batch

from _cnn_common import (ART, TASKS, accuracy, apply_fn_for, calibrate_task,
                         eval_data, get_trained)

MODES = ("ours", "dynamic", "static")
_MODE_KEY = {"ours": "pdq", "dynamic": "dynamic", "static": "static"}


def run_tables(n_eval: int = 384) -> dict:
    results: dict = {"in_domain": {}, "ood": {}, "surrogate": {}}
    rng = np.random.default_rng(0)
    for task in TASKS:
        cfg, params = get_trained(task)
        imgs, labels = eval_data(task, n_eval)
        imgs_ood = corrupt_batch(imgs, rng, max_severity=3)
        qstates = {pc: calibrate_task(task, params, per_channel=pc)
                   for pc in (False, True)}

        for domain, data in (("in_domain", imgs), ("ood", imgs_ood)):
            row = {"fp32": accuracy(task, params, data, labels, "none", False)}
            for mode in MODES:
                for pc in (False, True):
                    key = f"{mode}_{'C' if pc else 'T'}"
                    row[key] = accuracy(task, params, data, labels,
                                        _MODE_KEY[mode], pc, qstates[pc])
            results[domain][task] = row

        # surrogate fidelity: correlation of predicted vs empirical moments
        from repro.core.surrogate import empirical_moments
        tape = {}
        spec = as_observe(spec_for_mode("pdq", per_channel=True))
        apply_fn_for(cfg)(params, jnp.asarray(imgs[:64]), spec=spec,
                          qstate={}, tape=tape)
        mcorr, scorr = [], []
        for name, rec in tape.items():
            if rec.get("moments") is None:
                continue
            emp = empirical_moments(rec["y"], per_channel=True)
            pm = np.asarray(rec["moments"].mean).ravel()
            em = np.asarray(emp.mean).ravel()
            ps = np.asarray(rec["moments"].std).ravel()
            es = np.asarray(emp.std).ravel()
            if np.std(em) > 1e-6 and np.std(pm) > 1e-6:
                mcorr.append(float(np.corrcoef(pm, em)[0, 1]))
            if np.std(es) > 1e-6 and np.std(ps) > 1e-6:
                scorr.append(float(np.corrcoef(ps, es)[0, 1]))
        results["surrogate"][task] = {
            "mean_corr": float(np.mean(mcorr)) if mcorr else None,
            "std_corr": float(np.mean(scorr)) if scorr else None,
            "n_layers": len(tape),
        }
    return results


def render(results: dict) -> str:
    out = []
    for domain, title in (("in_domain", "Table 1 (In-Domain proxy)"),
                          ("ood", "Table 2 (Out-of-Domain proxy)")):
        out.append(f"\n## {title}\n")
        out.append("| task | FP32 | ours T | ours C | dyn T | dyn C | "
                   "static T | static C |\n|---|---|---|---|---|---|---|---|\n")
        for task, row in results[domain].items():
            out.append(
                f"| {task} | {row['fp32']:.4f} | {row['ours_T']:.4f} | "
                f"{row['ours_C']:.4f} | {row['dynamic_T']:.4f} | "
                f"{row['dynamic_C']:.4f} | {row['static_T']:.4f} | "
                f"{row['static_C']:.4f} |\n")
    out.append("\n## Surrogate fidelity (per-channel, trained nets)\n")
    for task, rec in results["surrogate"].items():
        out.append(f"- {task}: mean-corr {rec['mean_corr']:.3f}, "
                   f"std-corr {rec['std_corr']:.3f} over {rec['n_layers']} layers\n")
    return "".join(out)


def main():
    results = run_tables()
    with open(os.path.join(ART, "paper_tables.json"), "w") as f:
        json.dump(results, f, indent=1)
    print(render(results))


if __name__ == "__main__":
    main()
