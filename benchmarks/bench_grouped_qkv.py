"""Grouped vs per-projection PDQ QKV timing at serving shapes.

grouped  : ops.pdq_dense_grouped over a group_quantize_weights record of a
           GQA Q/K/V triple - ONE prologue (x read once) + ONE wide W8A8
           matmul with the per-(row, segment) interval epilogue.
per_proj : three independent ops.pdq_dense calls on the same input - the
           PR-1 fused path dispatched once per projection (3 prologue
           reads of x, 3 skinny matmuls).

Shapes mirror a GQA decode step: K = d_model, N_q = d_model,
N_k = N_v = d_model / 4 (4:1 GQA), B in {8, 64, 256}, d_model in
{2048, 4096}.  Writes ``BENCH_grouped_qkv.json`` next to this file (the
stable path the perf trajectory tracks); ``--quick`` shrinks the sweep
for CI smoke and ``--compare <baseline.json>`` fails on a >25% speedup
regression against the committed JSON (see _compare.py).

Dispatch follows ``ops.set_impl`` 'auto': real Pallas kernels on TPU, the
jnp oracle elsewhere - the JSON records which path ran.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _compare import compare
from _timing import median_time

from repro.kernels import ops
from repro.models.linops import group_quantize_weights, quantize_weight

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "BENCH_grouped_qkv.json")


def bench_cell(B: int, d_model: int, iters: int) -> dict:
    key = jax.random.PRNGKey(B + d_model)
    n_kv = max(d_model // 4, 128)
    sizes = (d_model, n_kv, n_kv)           # Q, K, V extents (4:1 GQA)
    ws = [0.05 * jax.random.normal(jax.random.fold_in(key, i), (d_model, n))
          for i, n in enumerate(sizes)]
    grec = group_quantize_weights(ws)
    recs = [quantize_weight(w) for w in ws]
    x = jax.random.normal(jax.random.fold_in(key, 9), (B, d_model))

    grouped = jax.jit(lambda t: ops.pdq_dense_grouped(t, grec, out="fp"))
    per_proj = jax.jit(lambda t: tuple(ops.pdq_dense(t, r, out="fp")
                                       for r in recs))
    t_grouped = median_time(grouped, x, iters)
    t_per_proj = median_time(per_proj, x, iters)
    return {"B": B, "d_model": d_model, "sizes": list(sizes),
            "grouped_ms": t_grouped * 1e3, "per_proj_ms": t_per_proj * 1e3,
            "speedup": t_per_proj / t_grouped}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / few iters (CI smoke)")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--out", default=OUT)
    ap.add_argument("--compare", default=None, metavar="BASELINE.json",
                    help="fail on >25%% speedup regression vs this baseline")
    args = ap.parse_args()

    # ms-scale 2048 cells anchor the smoke comparison - the sub-ms cells
    # alone are within timer noise of a shared CI runner
    quick_cells = [(8, 512), (64, 1024), (8, 2048), (64, 2048)]
    if args.quick:
        cells_spec, iters = quick_cells, args.iters or 9
    else:
        # the quick cells ride along so CI smoke runs intersect the
        # committed baseline (see --compare)
        full = [(b, d) for d in (2048, 4096) for b in (8, 64, 256)]
        cells_spec = list(dict.fromkeys(quick_cells + full))
        iters = args.iters or 9

    cells = []
    for b, d in cells_spec:
        cell = bench_cell(b, d, iters)
        cells.append(cell)
        print(f"B={b:4d} d_model={d:5d}  grouped {cell['grouped_ms']:9.3f} ms  "
              f"per-proj {cell['per_proj_ms']:9.3f} ms  "
              f"x{cell['speedup']:.2f}")

    out = {
        "meta": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "impl": "kernel" if jax.default_backend() == "tpu" else "ref",
            "jax": jax.__version__,
            "iters": iters,
            "quick": bool(args.quick),
        },
        "cells": cells,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    if args.compare:
        sys.exit(compare(out, args.compare, keys=("B", "d_model")))


if __name__ == "__main__":
    main()
