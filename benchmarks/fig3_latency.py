"""Paper Fig. 3: estimation cost scaling.

(a) vs input channels  - linear   (estimation touches each input once)
(b) vs output channels - constant (moments are output-shape independent)
(c) vs sampling stride - quadratic decrease (gamma^-2 positions sampled)

Measured as jitted CPU wall time of the moment estimate vs the conv itself,
plus the analytic op-count model from Sec. 4.2.  The absolute numbers are
CPU-host values (the paper's are STM32); the *scaling shapes* are the claim
being reproduced.
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.core import surrogate, weight_stats

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")


def _time(fn, *args, reps: int = 20) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def measure() -> dict:
    res: dict = {"vs_cin": [], "vs_cout": [], "vs_gamma": []}
    key = jax.random.PRNGKey(0)

    def conv_fn(x, k):
        import jax.lax as lax
        dn = lax.conv_dimension_numbers(x.shape, k.shape, ("NHWC", "HWIO", "NHWC"))
        return lax.conv_general_dilated(x, k, (1, 1), "SAME",
                                        dimension_numbers=dn)

    def est_fn(x, k, gamma=1):
        ws = weight_stats(k, reduce_axes=(0, 1, 2), per_channel=False)
        return surrogate.conv_moments(x, ws, (3, 3), (1, 1), "SAME", False,
                                      gamma)

    # (a) input channels, C_out = 3 (paper setup)
    for cin in (4, 8, 16, 32, 64):
        x = jax.random.normal(key, (1, 32, 32, cin))
        k = jax.random.normal(key, (3, 3, cin, 3)) * 0.1
        res["vs_cin"].append({"cin": cin,
                              "conv_us": _time(jax.jit(conv_fn), x, k),
                              "est_us": _time(jax.jit(est_fn), x, k)})
    # (b) output channels, C_in = 3
    for cout in (4, 8, 16, 32, 64):
        x = jax.random.normal(key, (1, 32, 32, 3))
        k = jax.random.normal(key, (3, 3, 3, cout)) * 0.1
        res["vs_cout"].append({"cout": cout,
                               "conv_us": _time(jax.jit(conv_fn), x, k),
                               "est_us": _time(jax.jit(est_fn), x, k)})
    # (c) sampling stride
    x = jax.random.normal(key, (1, 32, 32, 3))
    k = jax.random.normal(key, (3, 3, 3, 16)) * 0.1
    for gamma in (1, 2, 4, 8):
        fn = jax.jit(lambda xx, kk, g=gamma: est_fn(xx, kk, g))
        n_pos = (32 // gamma) ** 2
        res["vs_gamma"].append({"gamma": gamma, "est_us": _time(fn, x, k),
                                "positions": n_pos})
    return res


def main():
    res = measure()
    with open(os.path.join(ART, "fig3_latency.json"), "w") as f:
        json.dump(res, f, indent=1)
    print("\n## Fig 3a: estimation cost vs input channels (expect ~linear)")
    for r in res["vs_cin"]:
        print(f"  cin={r['cin']:3d}  est={r['est_us']:8.1f}us  conv={r['conv_us']:8.1f}us")
    print("## Fig 3b: estimation cost vs output channels (expect ~constant)")
    for r in res["vs_cout"]:
        print(f"  cout={r['cout']:3d}  est={r['est_us']:8.1f}us  conv={r['conv_us']:8.1f}us")
    print("## Fig 3c: estimation cost vs gamma (positions fall as gamma^-2)")
    for r in res["vs_gamma"]:
        print(f"  gamma={r['gamma']:2d}  est={r['est_us']:8.1f}us  positions={r['positions']}")


if __name__ == "__main__":
    main()
