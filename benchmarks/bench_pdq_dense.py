"""Fused vs. unfused PDQ dense timing at serving shapes.

fused   : ops.pdq_dense - ONE prologue kernel (x read once) + ONE W8A8
          matmul with the fp-out interval epilogue.
unfused : the pre-fusion serving path - separate amax / quantize /
          act_stats passes over x, requant matmul, jnp dequant.

Writes ``BENCH_pdq_dense.json`` (fused/unfused wall-clock per cell plus
environment metadata) next to this file so subsequent PRs have a perf
trajectory to defend.  Shapes: M in {8, 64, 256} x K=N in {2048, 4096,
8192} plus the CI smoke cells; ``--quick`` shrinks the sweep to the smoke
cells only, and ``--compare <baseline.json>`` fails on a >25% speedup
regression against the committed JSON (see _compare.py).

Dispatch follows ``ops.set_impl`` 'auto': real Pallas kernels on TPU, the
jnp oracle elsewhere (interpret-mode Pallas is a correctness tool, not a
timing target) - the JSON records which path ran.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _compare import compare
from _timing import median_time

from repro.kernels import ops
from repro.models.linops import quantize_weight

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "BENCH_pdq_dense.json")


def bench_cell(M: int, K: int, N: int, iters: int) -> dict:
    key = jax.random.PRNGKey(M + K + N)
    w = 0.05 * jax.random.normal(key, (K, N))
    rec = quantize_weight(w)
    x = jax.random.normal(jax.random.fold_in(key, 1), (M, K))

    fused = jax.jit(lambda t: ops.pdq_dense(t, rec, out="fp"))
    unfused = jax.jit(lambda t: ops.pdq_dense_unfused(t, rec)[0])
    t_fused = median_time(fused, x, iters)
    t_unfused = median_time(unfused, x, iters)
    return {"M": M, "K": K, "N": N,
            "fused_ms": t_fused * 1e3, "unfused_ms": t_unfused * 1e3,
            "speedup": t_unfused / t_fused}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / few iters (CI smoke)")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--out", default=OUT)
    ap.add_argument("--compare", default=None, metavar="BASELINE.json",
                    help="fail on >25%% speedup regression vs this baseline")
    args = ap.parse_args()

    # ms-scale 2048 cells anchor the smoke comparison - the sub-ms cells
    # alone are within timer noise of a shared CI runner
    quick_spec = ([(m, kn) for kn in (512, 1024) for m in (8, 64)]
                  + [(8, 2048), (64, 2048)])
    if args.quick:
        cells_spec, iters = quick_spec, args.iters or 9
    else:
        # the quick cells ride along so CI smoke runs intersect the
        # committed baseline (see --compare)
        full = [(m, kn) for kn in (2048, 4096, 8192) for m in (8, 64, 256)]
        cells_spec = list(dict.fromkeys(quick_spec + full))
        iters = args.iters or 9

    cells = []
    for m, kn in cells_spec:
        cell = bench_cell(m, kn, kn, iters)
        cells.append(cell)
        print(f"M={m:4d} K=N={kn:5d}  fused {cell['fused_ms']:9.3f} ms  "
              f"unfused {cell['unfused_ms']:9.3f} ms  "
              f"x{cell['speedup']:.2f}")

    out = {
        "meta": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "impl": "kernel" if jax.default_backend() == "tpu" else "ref",
            "jax": jax.__version__,
            "iters": iters,
            "quick": bool(args.quick),
        },
        "cells": cells,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    if args.compare:
        sys.exit(compare(out, args.compare, keys=("M", "K", "N")))


if __name__ == "__main__":
    main()
