"""Roofline table from the dry-run artifacts (deliverable g).

Terms per (arch x shape), single-pod mesh, TPU v5e constants:
  compute    = scaled_dot_flops / 197e12            [s/chip]
  memory     = traffic_proxy    / 819e9             [s/chip]
               traffic_proxy = argument + output + 2 * temp bytes
               (decode/prefill: every argument byte - params + cache - is
               read once per step; temp counted twice for write+read)
  collective = scaled_collective_bytes / 50e9       [s/chip]

dominant = argmax; MODEL_FLOPS from the analytic model (model_flops.py);
ratio = MODEL_FLOPS / (chips * scaled_dot_flops): the useful fraction of
compiled compute (catches remat + masked-attention waste).
roofline_frac = model_compute_time / max(term): the score headline.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts",
                   "dryrun")
OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")


def load_cells(mesh: str = "pod16x16"):
    cells = []
    for path in sorted(glob.glob(os.path.join(ART, f"*__{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def terms_for(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    mem = rec.get("memory", {})
    traffic = (mem.get("argument_bytes", 0) + mem.get("output_bytes", 0)
               + 2 * mem.get("temp_bytes", 0))
    compute = rec["scaled_dot_flops"] / PEAK_FLOPS
    memory = traffic / HBM_BW
    coll = rec.get("scaled_collective_total", 0.0) / ICI_BW
    dom = max(("compute", compute), ("memory", memory), ("collective", coll),
              key=lambda kv: kv[1])
    return {"compute_s": compute, "memory_s": memory, "collective_s": coll,
            "dominant": dom[0], "bound_s": dom[1], "traffic_bytes": traffic}


def full_table(mesh: str = "pod16x16", with_model: bool = True):
    rows = []
    model_cache: dict[str, dict] = {}
    if with_model:
        from repro.configs import get_config
        from repro.launch.model_flops import model_flops
    for rec in load_cells(mesh):
        t = terms_for(rec)
        row = {"arch": rec["arch"], "shape": rec["shape"],
               "status": rec.get("status")}
        if t is None:
            row["reason"] = rec.get("reason", rec.get("error", ""))[:90]
            rows.append(row)
            continue
        row.update(t)
        if with_model:
            key = f"{rec['arch']}|{rec['shape']}"
            if key not in model_cache:
                model_cache[key] = model_flops(get_config(rec["arch"]),
                                               rec["shape"])
            mf = model_cache[key]
            chips = rec["mesh_info"]["n_devices"]
            hlo_total = rec["scaled_dot_flops"] * chips
            row["model_flops"] = mf["total"]
            row["flops_ratio"] = mf["total"] / max(hlo_total, 1.0)
            model_time = mf["total"] / chips / PEAK_FLOPS
            row["roofline_frac"] = model_time / max(t["bound_s"], 1e-30)
        rows.append(row)
    return rows


def render_markdown(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL_FLOPs | HLO/model | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | skipped: "
                       f"{r.get('reason','')[:60]} | - | - | - |\n")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | |\n")
            continue
        inv = 1.0 / r["flops_ratio"] if r.get("flops_ratio") else float("nan")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
            f"{r.get('model_flops', 0):.3e} | {inv:.2f}x | "
            f"{r.get('roofline_frac', 0):.3f} |\n")
    return "".join(out)


def main():
    rows = full_table()
    md = render_markdown(rows)
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "roofline.md"), "w") as f:
        f.write(md)
    with open(os.path.join(OUT, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print(md)
    ok = [r for r in rows if r.get("status") == "ok"]
    if ok:
        import statistics
        fr = [r["roofline_frac"] for r in ok if "roofline_frac" in r]
        print(f"# {len(ok)} cells ok; median roofline fraction "
              f"{statistics.median(fr):.3f}")


if __name__ == "__main__":
    main()
