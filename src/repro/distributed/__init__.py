from . import collectives, fault, sharding
