"""Compressed / overlapped collective primitives (beyond-paper).

``compressed_psum``: int8 gradient all-reduce with PDQ-style predicted
scales + error feedback.  The payload over the ICI links drops 4x vs fp32
(collective roofline term / 4).  Used under shard_map over the DP axes.

Scheme (ring-friendly reduce-scatter + all-gather decomposition):
  1. residual-corrected gradient g' = g + e (error feedback carry)
  2. per-chunk symmetric int8 quantization; the scale is *predicted* from
     the chunk's second moment (PDQ surrogate: E|g| ~ sigma * sqrt(2/pi))
     rather than a second amax pass - one pass over the data, like the
     paper's estimator;
  3. psum of int8 payloads decoded per hop (here: psum of dequantized
     values is emulated as int32 psum of codes x shared scale, which is
     exactly what a switch/ICI offload implementation would do);
  4. e' = g' - dequant(quant(g')) kept locally for the next step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_CHUNK = 1024


def _predicted_scale(g: jax.Array) -> jax.Array:
    """PDQ-flavored scale: predicted from moments, not from a minmax scan.
    For near-Gaussian gradient chunks, max|g| ~ k * sigma; k=4 covers
    ~99.994% mass, the rest clips (absorbed by error feedback)."""
    sigma = jnp.sqrt(jnp.mean(jnp.square(g), axis=-1, keepdims=True) + 1e-20)
    return jnp.maximum(4.0 * sigma / 127.0, 1e-12)


def quantize_grad(g: jax.Array):
    """g: any shape -> (codes int8 (n,_CHUNK), scale (n,1), meta)."""
    n = g.size
    pad = (-n) % _CHUNK
    flat = jnp.pad(g.reshape(-1).astype(jnp.float32), (0, pad))
    chunks = flat.reshape(-1, _CHUNK)
    scale = _predicted_scale(chunks)
    codes = jnp.clip(jnp.round(chunks / scale), -127, 127).astype(jnp.int8)
    return codes, scale, (g.shape, n)


def dequantize_grad(codes, scale, meta):
    shape, n = meta
    flat = (codes.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape)


def compressed_psum(g: jax.Array, axis_name, error: jax.Array | None = None):
    """int8 all-reduce with error feedback; call under shard_map/pmap.

    Returns (g_reduced, new_error).  ``error`` has g's shape (or None).
    """
    g32 = g.astype(jnp.float32)
    if error is not None:
        g32 = g32 + error
    codes, scale, meta = quantize_grad(g32)
    decoded = dequantize_grad(codes, scale, meta)
    new_error = g32 - decoded
    # int32 code psum with a shared (max over shards) scale - what the wire
    # carries is int8 codes + one scale per chunk.
    shared_scale = jax.lax.pmax(scale, axis_name)
    rescaled = jnp.round(codes.astype(jnp.float32) * (scale / shared_scale))
    summed = jax.lax.psum(rescaled.astype(jnp.int32), axis_name)
    out = dequantize_grad(summed, shared_scale, meta)
    return out.astype(g.dtype), new_error.astype(g.dtype)


def psum_overlap_hint(x: jax.Array, axis_name):
    """Plain psum; kept as an explicit site so XLA's latency-hiding scheduler
    can overlap it with the surrounding compute (async collectives)."""
    return jax.lax.psum(x, axis_name)
