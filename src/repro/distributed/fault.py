"""Fault handling at fleet scale: elastic resharding, failure bookkeeping,
deadline watchdogs, scheduler snapshots, and a deterministic
fault-injection harness.

On a real cluster the control plane (borg/k8s) replaces failed hosts; the
framework's job is to (a) checkpoint in a mesh-agnostic layout, (b) restore
onto whatever mesh the restarted job gets, (c) flag stragglers so the
scheduler can drain them, and (d) convert hangs (a dead peer inside a gloo
collective blocks FOREVER) into visible, typed failures fast enough that
the control plane can act.  This module implements (b)-(d) plus the
serving-side pieces:

  * ``DeadlineWatchdog`` - a context manager arming a timer around any
    blocking launch/collective; on expiry it runs a callback (default:
    print a typed ABORT line and ``os._exit(EXIT_DEADLINE)``) because a
    thread blocked inside a C++ collective cannot be interrupted from
    Python.
  * ``save_snapshot`` / ``load_snapshot`` - the scheduler's pure-numpy
    drain record (serve/core.SchedulerCore.snapshot) to/from an .npz, so
    a preempted coordinator can requeue in-flight work after an elastic
    restart (possibly onto a different mesh; params travel through
    ``reshard_state``).
  * ``FaultPlan`` / ``FaultInjector`` - deterministic fault injection
    threaded through the serving engines behind no-op-by-default hooks:
    kill a process at a protocol step, hang a collective, corrupt a
    command header, NaN a request's logits block, inject virtual
    straggler delay, or preempt the coordinator at a round.  Everything
    keys off round/sequence COUNTERS, never wall-clock, so CI replays are
    exact.
"""
from __future__ import annotations

import dataclasses
import io
import os
import sys
import threading
import time
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

# typed process exit codes: the launcher / test harness reads these to tell
# an injected kill from a watchdog abort from an ordinary crash
EXIT_DEADLINE = 87     # DeadlineWatchdog expired (hung collective / dead peer)
EXIT_KILLED = 41       # FaultPlan kill_* injection


def reshard_state(state: Any, target_mesh: Mesh, spec_tree: Any) -> Any:
    """Elastic scaling: lay a (restored, host-local numpy) state out onto a
    NEW mesh - the device count may differ from the mesh that wrote the
    checkpoint.  Sharding specs are logical (axis names), so they transfer."""
    def place(x, spec):
        return jax.device_put(np.asarray(x), NamedSharding(target_mesh, spec))

    return jax.tree.map(place, state, spec_tree,
                        is_leaf=lambda x: isinstance(x, (np.ndarray, jax.Array)))


@dataclasses.dataclass
class StragglerWatchdog:
    """EMA step-time tracker: flags steps (hosts) slower than factor x EMA.

    On a fleet, per-host step times arrive via the coordination service;
    here the serving loop feeds its own round timings (serve/core.py
    observes every decode launch; tests inject synthetic delays through
    ``FaultPlan.delay_rounds``)."""
    factor: float = 3.0
    ema: float | None = None
    flagged: int = 0
    history: list = dataclasses.field(default_factory=list)

    def observe(self, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        self.ema = dt if self.ema is None else 0.9 * self.ema + 0.1 * dt
        self.flagged += int(slow)
        self.history.append((dt, slow))
        return slow


@dataclasses.dataclass
class FailureLog:
    events: list = dataclasses.field(default_factory=list)

    def record(self, step: int, kind: str, detail: str = ""):
        self.events.append({"t": time.time(), "step": step, "kind": kind,
                            "detail": detail})

    def count(self, kind: str | None = None) -> int:
        return len([e for e in self.events if kind is None or e["kind"] == kind])


# ---------------------------------------------------------------------------
# Deadline watchdogs
# ---------------------------------------------------------------------------


def _default_deadline_abort(reason: str, seconds: float) -> None:
    sys.stderr.write(
        f"FATAL ABORT_DEADLINE: {reason} exceeded its {seconds:g}s deadline "
        f"(hung collective or dead peer); exiting {EXIT_DEADLINE}\n")
    sys.stderr.flush()
    os._exit(EXIT_DEADLINE)


class DeadlineWatchdog:
    """Arm a timer around a blocking launch; fire ``on_timeout`` on expiry.

    A Python thread blocked inside a gloo/XLA collective cannot be
    interrupted, so the only way to bound a hung rendezvous is a SIDE
    thread that declares the process dead: the default handler prints a
    typed ``ABORT_DEADLINE`` line and ``os._exit``s with ``EXIT_DEADLINE``
    so the launcher (launch/serve.py) tears the fleet down and reports
    which process timed out.  A custom ``on_timeout(reason, seconds)`` can
    first dump the scheduler snapshot (the coordinator does: host-side
    scheduler state is consistent between result applications, so the
    drain record is valid even while the main thread is stuck in a
    collective).

    ``seconds=None`` disarms (context manager becomes a no-op)."""

    def __init__(self, seconds: float | None, *, reason: str = "collective",
                 on_timeout=None):
        self.seconds = seconds
        self.reason = reason
        self.on_timeout = on_timeout or _default_deadline_abort
        self._timer: threading.Timer | None = None
        self.fired = False

    def _fire(self):
        self.fired = True
        self.on_timeout(self.reason, self.seconds)

    def __enter__(self):
        if self.seconds is not None:
            self._timer = threading.Timer(self.seconds, self._fire)
            self._timer.daemon = True
            self._timer.start()
        return self

    def __exit__(self, *exc):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        return False


# ---------------------------------------------------------------------------
# Scheduler snapshots (drain-and-requeue records)
# ---------------------------------------------------------------------------
#
# A snapshot is a plain dict of numpy arrays / python scalars (built by
# serve/core.SchedulerCore.snapshot): request records for finished,
# in-flight and pending work plus the scheduler's counters.  In-flight
# requests are requeued and REGENERATED deterministically on resume
# (sampling keys derive from (uid, step), so token n of a request is the
# same computation whether or not the run was interrupted) - that is what
# makes a killed-and-resumed run token-for-token equal to an uninterrupted
# one without shipping cache pages.


def save_snapshot(path: str, snap: dict) -> None:
    """Write a scheduler snapshot atomically (tmp + rename: a watchdog
    firing mid-write must not leave a truncated record for the resume)."""
    buf = io.BytesIO()
    np.save(buf, np.asarray(snap, dtype=object), allow_pickle=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(buf.getvalue())
    os.replace(tmp, path)


def load_snapshot(path: str) -> dict:
    snap = np.load(path, allow_pickle=True).item()
    assert isinstance(snap, dict) and "version" in snap, (
        f"{path} is not a scheduler snapshot")
    return snap


# ---------------------------------------------------------------------------
# Deterministic fault injection
# ---------------------------------------------------------------------------


class FaultInjector:
    """No-op hook set threaded through the serving engines.

    The engines call these at fixed points; the default implementation
    does nothing, so production runs pay one virtual call per launch.
    Deterministic subclasses (see ``FaultPlan.injector``) key off the
    scheduler round / protocol sequence counters."""

    engine = None

    def bind(self, engine) -> None:
        """Called once by the engine at construction."""
        self.engine = engine

    def on_round(self, rnd: int) -> None:
        """Start of each scheduler round (serve/core.run loop)."""

    def on_exec(self, kind: str, rnd: int) -> None:
        """Immediately before a device launch ('prefill'/'chunked'/'decode').
        Raising here is treated as a launch failure (request isolation)."""

    def exec_delay(self, kind: str, rnd: int) -> float:
        """Virtual extra seconds added to the observed launch time (feeds
        the straggler watchdog deterministically)."""
        return 0.0

    def poison_rows(self, kind: str, plan) -> list[int]:
        """Batch rows whose logits should be overwritten with NaN before
        sampling (single-process engines only; models a corrupted kernel
        epilogue)."""
        return []

    def on_broadcast(self, seq: int, header: np.ndarray) -> np.ndarray:
        """Multi-host: before contributing to the command-header exchange.
        May sleep (hung collective), exit (process kill), or return a
        mutated header (corruption).  Called on every process; gate on
        ``self.engine.process_id``."""
        return header

    # ---- ingress faults (serve/service.ServeService hook points) ----

    def ingress_burst(self, rnd: int) -> list:
        """Extra ``(prompt, max_new)`` pairs the service submits at the top
        of round ``rnd`` - a deterministic client stampede for overload
        tests.  Submissions past the admission watermark are shed (counted
        in stats) exactly like external ones."""
        return []

    def drop_stream(self, uid: int, n_tokens: int) -> bool:
        """Return True to sever request ``uid``'s client after it has
        received ``n_tokens`` tokens (models a mid-stream disconnect; the
        service turns it into ``cancel(uid, kind='disconnect')``)."""
        return False

    def stream_cap(self, uid: int) -> int | None:
        """Override the per-stream token-buffer bound for ``uid`` (models a
        stalled SSE reader: a tiny cap overflows after a few tokens and the
        service cancels with ``kind='slow_consumer'``).  None = default."""
        return None


@dataclasses.dataclass
class FaultPlan:
    """Declarative, deterministic fault schedule for one serving run.

    All triggers are counters (scheduler round, protocol command seq),
    never wall-clock.  JSON-serializable (``dataclasses.asdict``) so
    subprocess test fixtures can ship one over argv.
    """
    # NaN a request's logits block: every launch of ``nan_kind`` whose
    # batch carries ``nan_uid`` gets that row's logits poisoned.
    nan_uid: int | None = None
    nan_kind: str = "any"             # 'prefill' | 'decode' | 'any'
    # raise RuntimeError right before a launch of this kind at this round
    raise_kind: str | None = None
    raise_round: int = 0
    # virtual straggler delays: {round: extra_seconds} added to launch
    # timings (never actually slept); ``delay_kind`` scopes them to one
    # launch kind ('prefill' | 'chunked' | 'decode') so prefill- and
    # decode-straggler EMAs can be exercised independently
    delay_rounds: dict = dataclasses.field(default_factory=dict)
    delay_kind: str = "any"
    # coordinator preemption (SIGTERM stand-in): request a drain at round N
    preempt_at_round: int | None = None
    # multi-host process faults, gated on (process id, command seq):
    kill_process: int | None = None   # os._exit(EXIT_KILLED) before seq
    kill_at_seq: int = 0
    hang_process: int | None = None   # sleep(hang_seconds) before seq
    hang_at_seq: int = 0
    hang_seconds: float = 3600.0
    corrupt_header_at_seq: int | None = None   # coordinator ships opcode 99
    # ingress faults (service front door):
    # {round: [[prompt_len, max_new], ...]} - deterministic client burst
    # submitted at the top of that round (prompts are derived from the
    # round number, so replays are exact)
    burst_rounds: dict = dataclasses.field(default_factory=dict)
    disconnect_uid: int | None = None  # sever this client mid-stream ...
    disconnect_after: int = 1          # ... once it has this many tokens
    stall_uid: int | None = None       # stalled-reader stream: tiny buffer
    stall_cap: int = 4

    def injector(self) -> "PlanInjector":
        return PlanInjector(self)


class PlanInjector(FaultInjector):
    """Executes a ``FaultPlan`` at the engine hook points."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan

    def on_round(self, rnd: int) -> None:
        p = self.plan
        if p.preempt_at_round is not None and rnd >= p.preempt_at_round:
            self.engine.request_drain()

    def on_exec(self, kind: str, rnd: int) -> None:
        p = self.plan
        if p.raise_kind == kind and rnd >= p.raise_round:
            p.raise_kind = None       # one-shot: later launches succeed
            raise RuntimeError(f"injected {kind} launch fault at round {rnd}")

    def exec_delay(self, kind: str, rnd: int) -> float:
        if self.plan.delay_kind not in (kind, "any"):
            return 0.0
        return float(self.plan.delay_rounds.get(rnd, 0.0))

    def poison_rows(self, kind: str, plan) -> list[int]:
        p = self.plan
        if p.nan_uid is None or p.nan_kind not in (kind, "any"):
            return []
        uids, steps = plan.row_uids, plan.row_steps
        live = getattr(plan, "live", None)
        return [i for i, u in enumerate(uids)
                if int(u) == p.nan_uid and (live is None or i in live)
                and (steps[i] >= 0)]

    def on_broadcast(self, seq: int, header: np.ndarray) -> np.ndarray:
        p, eng = self.plan, self.engine
        pid = getattr(eng, "process_id", 0)
        if p.kill_process == pid and seq >= p.kill_at_seq:
            sys.stderr.write(f"FAULT-INJECTION: killing process {pid} at "
                             f"command seq {seq}\n")
            sys.stderr.flush()
            os._exit(EXIT_KILLED)
        if p.hang_process == pid and seq >= p.hang_at_seq:
            sys.stderr.write(f"FAULT-INJECTION: hanging process {pid} at "
                             f"command seq {seq}\n")
            sys.stderr.flush()
            time.sleep(p.hang_seconds)
        if (p.corrupt_header_at_seq is not None and pid == 0
                and seq >= p.corrupt_header_at_seq):
            p.corrupt_header_at_seq = None    # one-shot
            header = np.array(header)
            header[0] = 99                    # not a real opcode
        return header

    def ingress_burst(self, rnd: int) -> list:
        spec = self.plan.burst_rounds.pop(rnd, None) if self.plan.burst_rounds \
            else None
        if not spec:
            return []
        vocab = int(getattr(self.engine.cfg, "vocab", 256))
        out = []
        for i, (plen, max_new) in enumerate(spec):
            rng = np.random.default_rng(1000 * rnd + i)
            prompt = rng.integers(0, vocab, size=int(plen)).astype(np.int32)
            out.append((prompt, int(max_new)))
        return out

    def drop_stream(self, uid: int, n_tokens: int) -> bool:
        p = self.plan
        return p.disconnect_uid == uid and n_tokens >= p.disconnect_after

    def stream_cap(self, uid: int) -> int | None:
        p = self.plan
        return p.stall_cap if p.stall_uid == uid else None
