"""Fault handling at fleet scale: elastic resharding + failure bookkeeping.

On a real cluster the control plane (borg/k8s) replaces failed hosts; the
framework's job is to (a) checkpoint in a mesh-agnostic layout, (b) restore
onto whatever mesh the restarted job gets, and (c) flag stragglers so the
scheduler can drain them.  This module implements (b) and the bookkeeping
for (c); (a) is checkpoint/io.py's full-logical-array layout.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding


def reshard_state(state: Any, target_mesh: Mesh, spec_tree: Any) -> Any:
    """Elastic scaling: lay a (restored, host-local numpy) state out onto a
    NEW mesh - the device count may differ from the mesh that wrote the
    checkpoint.  Sharding specs are logical (axis names), so they transfer."""
    def place(x, spec):
        return jax.device_put(np.asarray(x), NamedSharding(target_mesh, spec))

    return jax.tree.map(place, state, spec_tree,
                        is_leaf=lambda x: isinstance(x, (np.ndarray, jax.Array)))


@dataclasses.dataclass
class StragglerWatchdog:
    """EMA step-time tracker: flags steps (hosts) slower than factor x EMA.

    On a fleet, per-host step times arrive via the coordination service;
    here the single-process loop feeds its own timings (tests inject
    synthetic delays)."""
    factor: float = 3.0
    ema: float | None = None
    flagged: int = 0
    history: list = dataclasses.field(default_factory=list)

    def observe(self, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        self.ema = dt if self.ema is None else 0.9 * self.ema + 0.1 * dt
        self.flagged += int(slow)
        self.history.append((dt, slow))
        return slow


@dataclasses.dataclass
class FailureLog:
    events: list = dataclasses.field(default_factory=list)

    def record(self, step: int, kind: str, detail: str = ""):
        self.events.append({"t": time.time(), "step": step, "kind": kind,
                            "detail": detail})

    def count(self, kind: str | None = None) -> int:
        return len([e for e in self.events if kind is None or e["kind"] == kind])
