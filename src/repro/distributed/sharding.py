"""Sharding rules: param-name conventions -> PartitionSpecs.

Strategy (single pod mesh ('data','model'); multi-pod adds a leading 'pod'
axis used as pure DP for params):

  * TP: the "wide" dim of every projection shards over 'model' (heads, ffn,
    vocab, experts).
  * FSDP/ZeRO-3: the other matrix dim shards over 'data'; optimizer states
    inherit the param specs.
  * EP: expert-stacked (E, ., .) tensors shard E over 'model'.
  * Vectors (norms, biases, A_log...) replicate.
  * lax.scan block stacking / int8-weight records add leading dims: rules
    are right-aligned (extra leading dims replicate).

Activation/batch/cache shardings:
  * batch dims shard over ('pod','data') when divisible;
  * decode caches shard batch over DP and heads over 'model';
  * long-context (batch 1) caches shard the *sequence* dim over 'data'
    (context parallelism) and heads over 'model'.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import tree_flatten_with_path, tree_unflatten

# (regex on the param path, right-aligned spec entries)
_RULES: list[tuple[str, tuple]] = [
    (r"embed/embedding$", ("model", "data")),
    # expert stacks (E, d_in, d_out): EP over model + FSDP over data
    (r"we_gate$", ("model", "data", None)),
    (r"we_up$", ("model", "data", None)),
    (r"we_down$", ("model", None, "data")),
    (r"router$", (None, None)),
    # column-parallel (d_model -> wide); grouped records live under
    # <sibling>/group/ and keep the column-parallel layout (the N axis is
    # the segment concatenation, every segment padded to 128 lanes)
    (r"(wq|wk|wv|wq_b|w_gate|w_up|in_proj)(/group)?(/q)?$", ("data", "model")),
    # row-parallel (wide -> d_model)
    (r"(wo|w_down|out_proj|wk_b|wv_b)(/q)?$", ("model", "data")),
    # low-rank down-projections: small output, shard input dim only
    (r"(wq_a|wkv_a)(/group)?(/q)?$", ("data", None)),
    # quantized-record auxiliaries: per-output-channel vectors
    (r"(wq|wk|wv|wq_b|w_gate|w_up|in_proj)(/group)?/scale$", ("model",)),
    (r"(wq|wk|wv|wq_b|w_gate|w_up|in_proj)(/group)?/colsum$", (None, "model")),
    (r"(wo|w_down|out_proj|wk_b|wv_b)/scale$", ("data",)),
    (r"(wo|w_down|out_proj|wk_b|wv_b)/colsum$", (None, "data")),
    (r"conv_w$", (None, "model")),
    (r"conv_b$", ("model",)),
]


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in entry]))
    return int(mesh.shape[entry])


def spec_for_param(path: str, leaf, mesh=None) -> P:
    ndim = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    if ndim == 0:
        return P()
    for pat, entries in _RULES:
        if re.search(pat, path):
            entries = tuple(entries)
            if len(entries) > ndim:       # e.g. scalar 'scale' on tiny layers
                entries = entries[-ndim:]
            pad = (None,) * (ndim - len(entries))
            full = list(pad + entries)
            if mesh is not None:
                # drop axes the dim doesn't divide (e.g. vocab 50280 % 16)
                for i, e in enumerate(full):
                    if e is not None and leaf.shape[i] % _axis_size(mesh, e) != 0:
                        full[i] = None
            return P(*full)
    return P(*((None,) * ndim))           # vectors & unknowns replicate


def param_specs(params, mesh=None) -> Any:
    leaves, treedef = tree_flatten_with_path(params)
    specs = [spec_for_param(_path_str(p), v, mesh) for p, v in leaves]
    return tree_unflatten(jax.tree.structure(params), specs)


def named(mesh: Mesh, tree_of_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


def serve_pool_specs(caches) -> Any:
    """shard_map PartitionSpecs for a ServeEngine cache pool: the slot axis
    shards over 'data' (one contiguous block of slots per data-parallel
    replica), everything else stays replica-local.

    Head/tail leaves carry slots on axis 0; lax.scan-stacked block leaves
    on axis 1 (the same layout contract as ``models/api.cache_slice``).
    Heads/features are NOT sharded here: inside the shard_map body each
    replica runs the single-device program on its slot block, and the
    'model' axis splits the PDQ/fp projection columns instead
    (kernels/ops.tp_shard), which keeps the quantized epilogue math exact.
    """
    def head(c):
        return P(*(("data",) + (None,) * (c.ndim - 1)))

    def block(c):
        return P(None, "data", *((None,) * (c.ndim - 2)))

    return {"head": jax.tree.map(head, caches["head"]),
            "tail": jax.tree.map(head, caches["tail"]),
            "blocks": jax.tree.map(block, caches["blocks"])}


def pool_shardings(mesh: Mesh, caches) -> Any:
    """NamedSharding tree for a ServeEngine cache pool on ``mesh``: the
    ``serve_pool_specs`` PartitionSpecs bound to concrete devices (what
    ``jax.jit`` out_shardings / ``jax.device_put`` want)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        serve_pool_specs(caches),
                        is_leaf=lambda x: isinstance(x, P))


def process_replicas(mesh: Mesh) -> dict[int, list[int]]:
    """Which data-parallel replicas each process hosts.

    Replica r's cache-slot block is the r-th shard of the 'data' axis, so
    its addressable shards live on the devices of mesh row r - the row's
    process owns that replica's slot state.  Returns {process_index:
    [replica, ...]} in replica order.  The serve meshes built by
    ``launch/mesh.py`` lay processes out contiguously along 'data', so
    each row is process-local; if a row ever spanned processes (exotic
    topology) it is attributed to its first device's process.
    """
    devs = np.moveaxis(np.asarray(mesh.devices),
                       tuple(mesh.axis_names).index("data"), 0)
    out: dict[int, list[int]] = {}
    for r in range(devs.shape[0]):
        out.setdefault(devs[r].flat[0].process_index, []).append(r)
    return out


def make_global(mesh: Mesh, spec: P, x) -> jax.Array:
    """Build a global jax.Array on ``mesh`` from a host array that every
    process holds IDENTICALLY (multi-controller jax rejects plain numpy
    args with non-trivial shardings; each process donates the shards its
    local devices address)."""
    sh = NamedSharding(mesh, spec)
    x = np.asarray(x)
    return jax.make_array_from_callback(x.shape, sh, lambda idx: x[idx])


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_spec(mesh: Mesh, batch_tree, seq_over_model: bool = False) -> Any:
    """Shard every batch leaf's leading (batch) dim over the DP axes; with
    seq_over_model, also shard dim 1 (sequence) over 'model' (SP prefill)."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    model_size = mesh.shape["model"]

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        entries = [None] * leaf.ndim
        if leaf.shape[0] % dp_size == 0:
            entries[0] = dp
        if (seq_over_model and leaf.ndim >= 2
                and leaf.shape[1] % model_size == 0):
            entries[1] = "model"
        return P(*entries)

    return jax.tree.map(one, batch_tree)


def cache_spec(mesh: Mesh, caches, batch: int,
               seq_over_model: bool = False) -> Any:
    """Decode caches: DP on batch when divisible, else context-parallel on
    the sequence dim; KV-head / state dims over 'model' when divisible."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    model_size = mesh.shape["model"]
    data_size = mesh.shape["data"]

    # core rank of each cache leaf (batch-leading); scan-stacked block caches
    # carry extra leading dims which replicate (right-aligned rules).
    core_rank = {"k": 4, "v": 4, "k_scale": 3, "v_scale": 3, "ckv": 3,
                 "krope": 3, "state": 4, "conv": 3, "cross_k": 4,
                 "cross_v": 4, "pos": 2, "len": 1}

    leaves, _ = tree_flatten_with_path(caches)
    specs = []
    for path, leaf in leaves:
        name = _path_str(path).split("/")[-1]
        nd = leaf.ndim
        if nd == 0 or name not in core_rank:
            specs.append(P(*((None,) * nd)))
            continue
        core = core_rank[name]
        lead = nd - core
        shape = leaf.shape[lead:]
        e: list = [None] * core
        batch_sharded = shape[0] % dp_size == 0 and shape[0] > 1
        if batch_sharded:
            e[0] = dp
        seq_ok = (not batch_sharded) and core >= 2 and shape[1] % data_size == 0
        sp_ok = seq_over_model and core >= 2 and shape[1] % model_size == 0
        if name in ("k", "v", "k_scale", "v_scale"):
            # int8 KV caches (and their scales, which only exist quantized)
            # are stored in kernel layout (B, Hkv, S[, Dh]); fp caches stay
            # logical (B, S, Hkv[, Dh]).  See models/attention.init_cache.
            kernel_layout = (name in ("k_scale", "v_scale")
                             or leaf.dtype == jnp.int8)
            head_ax, seq_ax = (1, 2) if kernel_layout else (2, 1)
            seq_ok = (not batch_sharded) and shape[seq_ax] % data_size == 0
            sp_ok = seq_over_model and shape[seq_ax] % model_size == 0
            if sp_ok:
                e[seq_ax] = "model"           # sequence-parallel prefill
            elif shape[head_ax] % model_size == 0:
                e[head_ax] = "model"
            if seq_ok:
                e[seq_ax] = "data"            # context parallel (long_500k)
        elif name in ("ckv", "krope"):
            if sp_ok:
                e[1] = "model"
            elif seq_ok:
                e[1] = "data"
        elif name == "state":
            if shape[1] % model_size == 0:
                e[1] = "model"
        elif name == "conv":
            if shape[2] % model_size == 0:
                e[2] = "model"
        elif name in ("cross_k", "cross_v"):
            if shape[2] % model_size == 0:
                e[2] = "model"
        elif name == "pos":
            if sp_ok:
                e[1] = "model"
            elif seq_ok:
                e[1] = "data"
        specs.append(P(*(((None,) * lead) + tuple(e))))
    return tree_unflatten(jax.tree.structure(caches), specs)
