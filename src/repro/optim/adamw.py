"""Optimizers, built from scratch (no optax in this environment).

AdamW with optionally int8-quantized moment states: the PDQ idea applied to
optimizer memory - per-block symmetric scales are *predicted* from running
amax rather than re-scanned, and the second moment uses a log-domain int8
code.  The int8 states cut optimizer HBM from 8 to 2 bytes/param, which is
what lets the 480B Arctic config fit a single v5e pod (DESIGN.md Sec. 5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

# int8-state block size along the LAST axis. Chosen so blocking never
# crosses a shard boundary (last dims and their per-device slices are
# multiples of 64 across the model zoo): quantization stays a purely LOCAL
# reshape. (A flat (rows, 256) layout would force an f32 all-gather of the
# whole gradient on every step - measured 7.7e12 B/device on arctic-480b;
# see EXPERIMENTS.md Perf iteration 2.)
_BLOCK = 64


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quant_state: bool = False       # int8 m/v (for very large models)


class _Upd(NamedTuple):
    """Per-leaf update result; a distinct type so tree unzipping never
    confuses it with user pytree tuples (e.g. empty () containers)."""
    p: Any
    m: Any
    v: Any
    ms: Any
    vs: Any


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    m_scale: Any = None             # only for quant_state
    v_scale: Any = None


def _blocks(x: jax.Array):
    """(..., D) -> (..., G, _BLOCK): last-axis blocking, padding the last
    axis only (a local op under any sharding of the leading dims)."""
    if x.ndim == 0:
        x = x.reshape(1)
    D = x.shape[-1]
    pad = (-D) % _BLOCK
    if pad:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, widths)
    return x.reshape(*x.shape[:-1], -1, _BLOCK), pad


def _q8(x: jax.Array):
    """Per-block symmetric int8 encode -> (codes, scales)."""
    b, _ = _blocks(x)
    amax = jnp.maximum(jnp.max(jnp.abs(b), axis=-1, keepdims=True), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(b / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    full = (q.astype(jnp.float32) * scale)
    full = full.reshape(*full.shape[:-2], -1)     # unblock last axis
    if shape == ():
        return full.reshape(-1)[0]
    D = shape[-1]
    if full.shape[-1] != D:
        full = full[..., :D]
    return full.reshape(shape)


def init(params, cfg: AdamWConfig) -> OptState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    if not cfg.quant_state:
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=jax.tree.map(zeros32, params),
                        v=jax.tree.map(zeros32, params))

    def zq(p):
        q, s = _q8(jnp.zeros(p.shape, jnp.float32))
        return q

    def zs(p):
        q, s = _q8(jnp.zeros(p.shape, jnp.float32))
        return s

    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zq, params), v=jax.tree.map(zq, params),
                    m_scale=jax.tree.map(zs, params),
                    v_scale=jax.tree.map(zs, params))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _is_matrix(p) -> bool:
    return p.ndim >= 2


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig,
                  lr_scale: jax.Array | float = 1.0):
    """Returns (new_params, new_state).  Gradients are fp32-cast, globally
    clipped; weight decay applies to matrix params only (standard)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v, ms=None, vs=None):
        g = g.astype(jnp.float32) * clip
        if cfg.quant_state:
            m_f = _dq8(m, ms, p.shape)
            v_f = _dq8(v, vs, p.shape)
        else:
            m_f, v_f = m, v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * jnp.square(g)
        mhat = m_f / b1c
        vhat = v_f / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _is_matrix(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if cfg.quant_state:
            mq, mss = _q8(m_f)
            vq, vss = _q8(v_f)
            return _Upd(new_p, mq, vq, mss, vss)
        return _Upd(new_p, m_f, v_f, None, None)

    is_upd = lambda x: isinstance(x, _Upd)
    pick = lambda i: (lambda t: t[i])
    if cfg.quant_state:
        out = jax.tree.map(upd, params, grads, state.m, state.v,
                           state.m_scale, state.v_scale, is_leaf=is_upd)
        return (jax.tree.map(pick(0), out, is_leaf=is_upd),
                OptState(step,
                         jax.tree.map(pick(1), out, is_leaf=is_upd),
                         jax.tree.map(pick(2), out, is_leaf=is_upd),
                         jax.tree.map(pick(3), out, is_leaf=is_upd),
                         jax.tree.map(pick(4), out, is_leaf=is_upd)))

    out = jax.tree.map(upd, params, grads, state.m, state.v, is_leaf=is_upd)
    return (jax.tree.map(pick(0), out, is_leaf=is_upd),
            OptState(step,
                     jax.tree.map(pick(1), out, is_leaf=is_upd),
                     jax.tree.map(pick(2), out, is_leaf=is_upd), None, None))
