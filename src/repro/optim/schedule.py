"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int = 100, total: int = 10_000,
                  min_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos


def constant(step, *, value: float = 1.0):
    return jnp.full((), value, jnp.float32)
