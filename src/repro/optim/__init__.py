from . import adamw, schedule
from .adamw import AdamWConfig, OptState
