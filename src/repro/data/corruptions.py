"""The paper's domain-shift corruption suite (Sec. 5.2), severity 1..5.

Operates on NHWC float images in [0, 1].  'combination' applies several
corruptions in one pass, as in the paper.  Implemented in numpy so the
evaluation pipeline can corrupt batches outside jit.
"""
from __future__ import annotations

import numpy as np

SEVERITY = {1: 0.2, 2: 0.4, 3: 0.6, 4: 0.8, 5: 1.0}


def white_noise(x, s, rng):
    return np.clip(x + rng.normal(0, 0.08 * SEVERITY[s], x.shape), 0, 1)


def blur(x, s, rng):
    k = 1 + 2 * s  # box blur size
    pad = k // 2
    xp = np.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)), mode="edge")
    out = np.zeros_like(x)
    for i in range(k):
        for j in range(k):
            out += xp[:, i: i + x.shape[1], j: j + x.shape[2]]
    return out / (k * k)


def pixelate(x, s, rng):
    f = 1 + s
    h, w = x.shape[1], x.shape[2]
    small = x[:, ::f, ::f]
    return np.repeat(np.repeat(small, f, axis=1), f, axis=2)[:, :h, :w]


def quantize_img(x, s, rng):
    levels = max(2, 32 >> s)
    return np.round(x * (levels - 1)) / (levels - 1)


def color_shift(x, s, rng):
    shift = rng.uniform(-0.25, 0.25, size=(1, 1, 1, x.shape[-1])) * SEVERITY[s]
    return np.clip(x + shift, 0, 1)


def brightness(x, s, rng):
    return np.clip(x + 0.3 * SEVERITY[s] * rng.choice([-1.0, 1.0]), 0, 1)


def contrast(x, s, rng):
    c = 1.0 - 0.7 * SEVERITY[s]
    mean = x.mean(axis=(1, 2, 3), keepdims=True)
    return np.clip((x - mean) * c + mean, 0, 1)


CORRUPTIONS = {
    "white_noise": white_noise,
    "blur": blur,
    "pixelate": pixelate,
    "quantize": quantize_img,
    "color_shift": color_shift,
    "brightness": brightness,
    "contrast": contrast,
}


def combination(x, s, rng):
    names = rng.choice(list(CORRUPTIONS), size=2, replace=False)
    for n in names:
        x = CORRUPTIONS[n](x, s, rng)
    return x


def corrupt_batch(x: np.ndarray, rng: np.random.Generator,
                  max_severity: int = 5) -> np.ndarray:
    """Paper protocol: uniformly sample an augmentation + severity PER IMAGE."""
    names = list(CORRUPTIONS) + ["combination"]
    out = np.empty_like(x, dtype=np.float32)
    for i in range(x.shape[0]):
        name = names[rng.integers(len(names))]
        s = int(rng.integers(1, max_severity + 1))
        fn = combination if name == "combination" else CORRUPTIONS[name]
        out[i] = fn(x[i: i + 1].astype(np.float64), s, rng)[0]
    return out
