from . import corruptions, pipeline
from .pipeline import DataConfig, Prefetcher, make_source
