"""Deterministic, shardable data pipeline.

Sources:
  * SyntheticLM  - procedural token streams (zipf-ish unigram + markov
    structure so models actually have something to learn); fully
    deterministic in (seed, step, shard), which makes restarts exact.
  * FileTokens   - memory-mapped .bin token files (uint16/uint32) with the
    same deterministic sharded indexing.

Each host pulls only its shard (``shard_id``/``num_shards``), so the global
batch is assembled by the runtime's device layout rather than by shipping
data - the standard multi-host JAX pattern.  A background prefetch thread
keeps ``prefetch`` batches ready.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch: int                   # per-shard batch
    seed: int = 0
    shard_id: int = 0
    num_shards: int = 1
    kind: str = "synthetic"      # 'synthetic' | 'file'
    path: str | None = None
    prefetch: int = 2


class SyntheticLM:
    """Markov-flavored synthetic LM data; learnable and deterministic."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # fixed random transition structure: each token prefers a small set
        self._next = rng.integers(0, v, size=(v, 4), dtype=np.int64)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * cfg.num_shards + cfg.shard_id)
        B, S = cfg.batch, cfg.seq_len
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = rng.choice(cfg.vocab, size=B, p=self._unigram)
        follow = rng.random((B, S)) < 0.75
        choice = rng.integers(0, 4, size=(B, S))
        fresh = rng.choice(cfg.vocab, size=(B, S), p=self._unigram)
        for t in range(S):
            nxt = self._next[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, fresh[:, t])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class FileTokens:
    """Flat binary token file, deterministic strided sharded windows."""

    def __init__(self, cfg: DataConfig, dtype=np.uint16):
        assert cfg.path is not None
        self.cfg = cfg
        self._data = np.memmap(cfg.path, dtype=dtype, mode="r")
        self._n_windows = (len(self._data) - 1) // cfg.seq_len

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed + step)
        idx = rng.integers(0, self._n_windows,
                           size=(cfg.num_shards, cfg.batch))[cfg.shard_id]
        S = cfg.seq_len
        rows = np.stack([self._data[i * S: i * S + S + 1] for i in idx])
        rows = rows.astype(np.int32) % cfg.vocab
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def make_source(cfg: DataConfig):
    return FileTokens(cfg) if cfg.kind == "file" else SyntheticLM(cfg)


class Prefetcher:
    """Background thread that stays ``cfg.prefetch`` steps ahead; restart-
    exact because batches are a pure function of the step index."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.source = make_source(cfg)
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict[str, np.ndarray]]]:
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
