"""Paged KV-cache pool: the pure-numpy allocator half.

The serving cache used to be a pool of whole-sequence slot rows: admitting
a request cost ``max_len`` tokens of cache no matter how short it was, and
the pool's concurrency ceiling was exactly ``slots`` rows.  This module
breaks that pool into fixed-size PAGES of ``page_size`` token positions
with an indirection table per request:

  * ``PagePool`` is the per-replica allocator - alloc/free lists, per-page
    refcounts, and per-uid page tables (the logical->physical indirection
    the device programs consume).  It is pure numpy/python bookkeeping: the
    scheduler core (serve/core.py) drives it at plan time and ships the
    resulting tables/maps INSIDE the existing PrefillPlan/ChunkedPlan/
    DecodePlan arrays, so the device side never adds a host round-trip and
    the multi-host coordinator broadcasts them like any other plan payload.
  * ``PrefixStore`` implements copy-on-write prefix sharing: full pages of
    a landed prompt are registered under their token-prefix key, and a
    later request whose prompt starts with the same tokens attaches those
    pages read-only (refcount + 1) instead of landing duplicates.  Only
    FULL pages strictly below every participant's write frontier are ever
    shared, so shared pages are immutable by construction; the allocator's
    ``ensure_writable`` (the COW arm) enforces that invariant before every
    decode write and copies a page out if a sharing policy ever aliases a
    frontier page.
  * ``SpillRecord`` carries a preempted request's page contents (plus its
    flat per-slot leaves) in host memory, so re-admission restores the
    cache instead of regenerating - the warm-resume path.

Page 0 of every pool is the DUMP page: it is never allocated and never
read (unallocated page-table entries are -1, which the device gather maps
turn into zero rows - bit-exactly the never-written region of a slot-row
cache).  Free slots still run the batched decode step on garbage rows
(scheduler invariant since PR 3); their write-back lands on page 0, which
nothing ever reads.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

DUMP_PAGE = 0


class PageError(RuntimeError):
    """Page allocation failed: the pool is out of free pages.  The
    scheduler catches this and either defers admission or preempts the
    youngest live request (serve/core.py)."""


def pages_for(tokens: int, page: int) -> int:
    """Pages needed to hold token positions [0, tokens)."""
    return -(-int(tokens) // page)


class PagePool:
    """Refcounted fixed-size-page allocator for ONE replica's cache pool.

    Physical page ids index the leading page axis of every paged cache
    leaf on the owning replica (replica-LOCAL ids, the same convention the
    scheduler's ``src_map`` scratch rows use).  A uid's table is its pages
    in logical order: entry j backs token positions [j*page, (j+1)*page).
    """

    def __init__(self, n_pages: int, pages_per_seq: int, page: int):
        assert n_pages >= pages_per_seq + 1, (
            f"pool of {n_pages} pages cannot hold one full sequence of "
            f"{pages_per_seq} pages plus the dump page")
        self.n_pages = int(n_pages)
        self.n_pp = int(pages_per_seq)
        self.page = int(page)
        self.refs = np.zeros((n_pages,), np.int32)
        self.refs[DUMP_PAGE] = 1                 # never allocated, never freed
        # LIFO free list: hot pages recycle first (better locality, and the
        # hypothesis suite exercises reuse-after-free aggressively)
        self._free: list[int] = list(range(n_pages - 1, 0, -1))
        self._tables: dict[int, list[int]] = {}
        # freed-page callback (the PrefixStore drops its entries there)
        self.on_free = None
        # COW callback: fired with (uid, src, dst) whenever ensure_writable
        # breaks a shared frontier page (the engine wires a telemetry
        # counter + structured event here; None = uninstrumented)
        self.on_cow = None
        self.stats = {"page_allocs": 0, "page_frees": 0, "cow_copies": 0}

    # ------------------------------------------------------------- accounting
    def free_pages(self) -> int:
        return len(self._free)

    def used_pages(self) -> int:
        return self.n_pages - 1 - len(self._free)

    def holds(self, uid: int) -> bool:
        return uid in self._tables

    def pages(self, uid: int) -> list[int]:
        return list(self._tables[uid])

    def n_owned(self, uid: int) -> int:
        return len(self._tables.get(uid, ()))

    # ------------------------------------------------------------- allocation
    def attach(self, uid: int) -> None:
        """Open an (empty) page table for a request being placed."""
        assert uid not in self._tables, f"uid {uid} already holds pages"
        self._tables[uid] = []

    def share(self, uid: int, page_ids: list[int]) -> None:
        """Attach already-populated pages read-only (prefix sharing): each
        gains a reference and extends the uid's table in logical order.
        Caller guarantees the pages sit strictly below the uid's write
        frontier (full pages of a common prompt prefix)."""
        tab = self._tables[uid]
        assert not tab, "shared prefix pages must come first in the table"
        for p in page_ids:
            assert 0 < p < self.n_pages and self.refs[p] > 0, p
            self.refs[p] += 1
            tab.append(int(p))

    def alloc(self, uid: int, k: int) -> list[int]:
        """Append k fresh (refcount-1) pages to the uid's table; raises
        ``PageError`` without side effects if the pool cannot supply k."""
        if k > len(self._free):
            raise PageError(
                f"uid {uid} needs {k} pages, pool has {len(self._free)} free "
                f"({self.used_pages()}/{self.n_pages - 1} in use)")
        tab = self._tables[uid]
        got = [self._free.pop() for _ in range(k)]
        for p in got:
            assert self.refs[p] == 0, (p, self.refs[p])
            self.refs[p] = 1
        tab.extend(got)
        self.stats["page_allocs"] += k
        return got

    def ensure_writable(self, uid: int, j: int) -> tuple[int, int] | None:
        """Copy-on-write arm: page j of the uid's table is about to be
        WRITTEN (the decode frontier).  If it is shared (refcount > 1),
        allocate a fresh page, swap it into the table, drop the old
        reference, and return ``(src, dst)`` so the engine can issue the
        device page copy.  Returns None when the page is already exclusive
        - the common case: full-prefix sharing never aliases a frontier
        page, so this arm is the invariant keeper a future fork/parallel-
        sampling policy would lean on."""
        tab = self._tables[uid]
        src = tab[j]
        if self.refs[src] == 1:
            return None
        dst = self.alloc_one_detached()
        self.refs[src] -= 1
        tab[j] = dst
        self.stats["cow_copies"] += 1
        if self.on_cow is not None:
            self.on_cow(uid, src, dst)
        return src, dst

    def alloc_one_detached(self) -> int:
        """One fresh refcount-1 page NOT appended to any table (COW swap)."""
        if not self._free:
            raise PageError("pool exhausted during copy-on-write")
        p = self._free.pop()
        assert self.refs[p] == 0
        self.refs[p] = 1
        self.stats["page_allocs"] += 1
        return p

    def release(self, uid: int) -> list[int]:
        """Drop the uid's table; pages reaching refcount 0 return to the
        free list (and fire ``on_free`` so the prefix store forgets them).
        Unknown uids are a no-op - every slot-release path funnels here."""
        tab = self._tables.pop(uid, None)
        if tab is None:
            return []
        freed: list[int] = []
        for p in tab:
            self.refs[p] -= 1
            assert self.refs[p] >= 0, p
            if self.refs[p] == 0:
                self._free.append(p)
                freed.append(p)
                if self.on_free is not None:
                    self.on_free(p)
        self.stats["page_frees"] += len(freed)
        return freed

    # ------------------------------------------------------------ device maps
    def table_row(self, uid: int | None) -> np.ndarray:
        """(n_pp,) int32 page-table row: allocated pages in logical order,
        -1 beyond (the device gather turns -1 into zero rows, matching the
        never-written region of a slot-row cache bit-exactly)."""
        row = np.full((self.n_pp,), -1, np.int32)
        if uid is not None and uid in self._tables:
            tab = self._tables[uid]
            row[:len(tab)] = tab
        return row

    def check(self) -> None:
        """Allocator invariants (the hypothesis suite calls this after
        every operation): refcounts equal table membership counts, free
        pages are unreferenced, nothing leaks, no double-free, and no two
        uids alias a writable (refcount-1) page."""
        counts = np.zeros_like(self.refs)
        counts[DUMP_PAGE] = 1
        for tab in self._tables.values():
            for p in tab:
                counts[p] += 1
        assert (counts == self.refs).all(), (counts, self.refs)
        free = set(self._free)
        assert len(free) == len(self._free), "double-free: duplicate free page"
        assert DUMP_PAGE not in free
        for p in free:
            assert self.refs[p] == 0, f"free page {p} still referenced"
        used = {p for tab in self._tables.values() for p in tab}
        assert not (used & free), "page both allocated and free"
        assert len(used) + len(free) + 1 == self.n_pages or \
            len(used | free) + 1 == self.n_pages


class PrefixStore:
    """Token-prefix -> page-ids index for copy-on-write prefix sharing.

    ``register`` records every FULL-page prefix of a landed prompt; a later
    ``lookup`` returns the longest registered prefix of its prompt.  Pages
    leave the store the moment the allocator frees them (``PagePool.on_free``
    wiring), so a hit can always be attached with ``PagePool.share``.
    Entries alias live pages only - the store never owns a reference.
    """

    def __init__(self, page: int):
        self.page = int(page)
        self._by_key: dict[bytes, tuple[int, ...]] = {}
        self._by_page: dict[int, set[bytes]] = {}
        self.stats = {"prefix_hits": 0, "prefix_shared_pages": 0,
                      "prefix_entries": 0}

    @staticmethod
    def _key(prompt: np.ndarray, tokens: int) -> bytes:
        return np.ascontiguousarray(prompt[:tokens], np.int32).tobytes()

    def lookup(self, prompt: np.ndarray) -> tuple[int, list[int]]:
        """Longest shareable prefix of ``prompt``: returns (k, pages) where
        the k returned pages hold prompt tokens [0, k*page).  Only full
        pages strictly inside the prompt are candidates, so the caller's
        own landing (its partial last page, its decode frontier) never
        touches a shared page."""
        P = self.page
        prompt = np.asarray(prompt)
        for k in range(len(prompt) // P, 0, -1):
            ids = self._by_key.get(self._key(prompt, k * P))
            if ids is not None:
                self.stats["prefix_hits"] += 1
                self.stats["prefix_shared_pages"] += k
                return k, list(ids)
        return 0, []

    def register(self, prompt: np.ndarray, page_ids: list[int]) -> None:
        """Record the prompt's full pages (page_ids[:len(prompt)//page])
        under every full-page prefix key.  First writer wins: identical
        prefixes re-registered later keep the original pages (maximal
        sharing against the oldest copy)."""
        P = self.page
        prompt = np.asarray(prompt)
        n_full = min(len(prompt) // P, len(page_ids))
        for k in range(1, n_full + 1):
            key = self._key(prompt, k * P)
            if key in self._by_key:
                continue
            ids = tuple(int(p) for p in page_ids[:k])
            self._by_key[key] = ids
            for p in ids:
                self._by_page.setdefault(p, set()).add(key)
            self.stats["prefix_entries"] += 1

    def drop_page(self, page: int) -> None:
        """A physical page was freed: forget every prefix that used it
        (wired as ``PagePool.on_free``)."""
        for key in self._by_page.pop(page, ()):
            ids = self._by_key.pop(key, None)
            if ids is None:
                continue
            self.stats["prefix_entries"] -= 1
            for p in ids:
                if p != page and p in self._by_page:
                    self._by_page[p].discard(key)
                    if not self._by_page[p]:
                        del self._by_page[p]


@dataclasses.dataclass
class SpillRecord:
    """Host-memory copy of a preempted request's cache state: one
    cache-shaped numpy tree holding the paged leaves' page contents
    (padded to n_pp pages so the restore program compiles once) AND the
    flat per-slot leaves (one row each), plus the scheduler state needed
    to reactivate without re-prefilling (warm resume)."""
    uid: int
    n_pages: int                     # pages actually held (rest is padding)
    length: int                      # self.lengths[slot] at preemption
    last_token: int
    data: Any                        # PagedCacheOps.capture() tree
