"""Multi-process serving: ``MultiHostServeEngine`` over a ``jax.distributed``
mesh, with a coordinator protocol.

Topology.  N OS processes each own a slice of the global device set;
``launch/mesh.py`` lays them out contiguously along the 'data' axis of the
('data', 'model') serve mesh, so every data-parallel replica's cache-slot
block is addressable by exactly one process (``distributed/sharding.
process_replicas``).  All processes execute the SAME SPMD launch sequence
- multi-controller jax requires it - but scheduling is NOT replicated:

  * **coordinator (process 0)** runs the scheduler core (serve/core.py)
    as a host-side singleton: the pending queue, bucket grouping and
    least-loaded replica routing live only there, exactly as on one
    process.  Each device launch it decides is announced to the workers
    as a COMMAND: a fixed-shape int32 header (opcode + bucket length)
    followed by the plan's numpy payload, both shipped by a one-to-all
    psum broadcast that blocks on every local shard (see ``_broadcast``).
  * **workers (process > 0)** run ``serve_worker()``: receive a command,
    execute the identical launch, repeat until CMD_STOP.  They hold no
    scheduler state - just the global cache pool (of which they
    physically store their replicas' shards) and the in-flight chunked
    sub-pool.

Collective fast path.  The single-process engines sample on the host,
which forces a device->host gather of the (slots, vocab) logits; across
processes that gather is not even addressable.  Here sampling runs
IN-PROGRAM: argmax / categorical is fused after the shard_map body, and
the jit's replicated out_sharding makes XLA broadcast the (slots,) sampled
tokens to every device via an in-program all-gather - every process then
reads the full token vector from its local shard, no host-side device
gathers.  Because each replica's argmax runs over exactly the logits the
single-process engine computed (PDQ column-TP epilogue included), tokens
stay bit-exact vs ``ShardedServeEngine`` on the same logical mesh, fp and
int8.

Failure modes: a worker that dies mid-trace leaves the coordinator blocked
in a collective - the gloo/distributed-runtime timeout (or the CI job's
hard timeout) converts that into a visible failure, and the launcher
(launch/serve.py --num-processes) exits non-zero when any process dies.
A coordinator exception is propagated best-effort: ``run`` broadcasts
CMD_ABORT from a ``finally`` so workers raise instead of waiting forever
at the next header rendezvous.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (make_global, pool_shardings,
                                        process_replicas, serve_pool_specs)

from .core import ChunkedPlan, DecodePlan, PrefillPlan
from .engine import DEFAULT_BUCKETS
from .sharded import ShardedServeEngine

# coordinator -> worker opcodes (header: int32[2] = [op, bucket_len])
CMD_STOP = 0
CMD_PREFILL = 1        # payload: tokens (slots, L), seq_lens, src_map
CMD_CHUNK_FIRST = 2    # payload: tokens (slots, L), seq_lens
CMD_CHUNK_NEXT = 3     # payload: tokens (slots, L), seq_lens, start_lens
CMD_CHUNK_END = 4      # payload: src_map
CMD_DECODE = 5         # payload: tokens (slots, 1), positions (slots, 1)
CMD_ABORT = 6          # coordinator died: workers raise


class MultiHostServeEngine(ShardedServeEngine):
    """ShardedServeEngine over a multi-process ('data', 'model') mesh.

    Every process constructs the engine with IDENTICAL arguments (params
    are host-replicated: same init seed or same checkpoint).  Process 0
    then drives ``run(requests)``; every other process calls
    ``serve_worker()`` and follows the broadcast command stream.  Call
    ``stop_workers()`` on the coordinator when the engine is done so the
    workers' loops return.

    Text-only (no vision/encdec extras: their side inputs are not part of
    the command protocol yet).  Temperature sampling runs in-program from
    a per-launch key split deterministically from ``rng`` on every
    process; the stream matches the single-process engine's except under
    chunked prefill (one split per chunk launch vs one per sequence).
    """

    def __init__(self, cfg, params, *, mesh, slots_per_replica: int = 4,
                 max_len: int = 256, quantize_weights: bool = False,
                 temperature: float = 0.0, rng: jax.Array | None = None,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 chunked_prefill: bool = False):
        if cfg.frontend == "vision" or cfg.family == "encdec":
            raise NotImplementedError(
                "multi-host serving is text-only: vision/encdec extras are "
                "not part of the coordinator command protocol")
        self.n_processes = jax.process_count()
        self.process_id = jax.process_index()
        self.is_coordinator = self.process_id == 0
        data = int(mesh.shape["data"])
        if data % self.n_processes:
            # a mesh row straddling a process boundary would make the TP
            # all_gather a cross-process collective and break the
            # replica->process slot-state attribution
            raise ValueError(
                f"mesh 'data' axis ({data}) must divide over the "
                f"{self.n_processes} jax.distributed processes")
        self._chunk_sub = None
        self._stopped = False
        super().__init__(cfg, params, mesh=mesh,
                         slots_per_replica=slots_per_replica, max_len=max_len,
                         quantize_weights=quantize_weights,
                         temperature=temperature, rng=rng, buckets=buckets,
                         chunked_prefill=chunked_prefill)
        # replica -> owning process, for per-host stats and routing debug
        self.host_replicas = process_replicas(self.mesh)
        if self.n_processes > 1:
            self._build_broadcast()

    # ------------------------------------------------------- device programs
    def _init_pools(self):
        """Shape-only stand-ins: _build_jitted reads the pool tree
        structure (specs/shardings) and then allocates the real pools
        directly on the global mesh - materializing host zeros here would
        be two full pool allocations thrown away per process."""
        self.caches = jax.eval_shape(
            lambda: self.bundle.init_caches(self.slots, self.max_len,
                                            self.mem_len))
        self._prefill_pool = self.caches

    def _build_jitted(self):
        cs = serve_pool_specs(self.caches)
        dp = P("data")
        pool_sh = pool_shardings(self.mesh, self.caches)
        repl = NamedSharding(self.mesh, P())

        # long-lived global buffers.  Params: every process holds the same
        # host values; make_global donates each process's addressable
        # (replicated) shards.  Cache pools: allocated directly on the mesh
        # by a sharded-output jit - a device_put of the process-local zeros
        # cannot address the other processes' shards.
        self.params = jax.tree.map(
            lambda x: make_global(self.mesh, P(), np.asarray(x)), self.params)
        mk_pool = jax.jit(
            lambda: self.bundle.init_caches(self.slots, self.max_len,
                                            self.mem_len),
            out_shardings=pool_sh)
        self.caches = mk_pool()
        self._prefill_pool = mk_pool()

        temp = self.temperature

        def sample(logits, key):
            if temp <= 0.0:
                return jnp.argmax(logits, -1)
            return jax.random.categorical(key, logits / temp)

        def sampled(fn, in_specs):
            """shard_map(fn) (TP active inside) returning (sampled tokens,
            caches): logits stay sharded over 'data', the argmax runs per
            replica, and the replicated out_sharding broadcasts the
            (slots,) tokens to every device in-program."""
            mapped = self._sharded(fn, in_specs, (dp, cs))

            def prog(key, *args):
                logits, caches = mapped(*args)
                return sample(logits, key), caches

            return prog

        def traced(fn, counter, **jit_kw):
            stats = self.stats

            def wrapped(*args):
                if counter:
                    stats[counter] += 1      # trace-time side effect
                return fn(*args)

            return jax.jit(wrapped, **jit_kw)

        self._decode = traced(
            sampled(self.bundle.decode_step, (P(), cs, dp, dp)),
            "decode_compiles", out_shardings=(repl, pool_sh))
        self._prefill_many = traced(
            sampled(self.bundle.prefill_many, (P(), dp, cs, dp)),
            "prefill_compiles", out_shardings=(repl, pool_sh))
        self._prefill_chunk = traced(
            sampled(self.bundle.prefill_chunk, (P(), dp, cs, dp, dp)),
            "chunk_compiles", out_shardings=(repl, pool_sh))
        self._scatter = self._traced_sharded_jit(
            self.bundle.cache_scatter, None,
            in_specs=(cs, cs, dp), out_specs=cs, donate=(0,))
        self._prefill_one = None

    # --------------------------------------------------------- the protocol
    # Coordinator -> worker shipping is a psum-based one-to-all broadcast
    # (workers contribute zeros), like multihost_utils.broadcast_one_to_all
    # BUT blocked on EVERY local shard before returning.  Gloo matches
    # collective ops on a TCP device pair by posting order, and an op only
    # sequences a device that DEPENDS on it: blocking just the first local
    # shard (what np.asarray does) lets the other local devices' tail
    # collectives drain into the next program's ops and cross-pair them -
    # observed as gloo preamble-size aborts.  Every launch here therefore
    # blocks all addressable shards of anything carrying a collective
    # before the next program is dispatched.
    def _glob(self, x, spec):
        return make_global(self.mesh, spec, x)

    def _next_key(self):
        """Per-launch sampling key, split identically on every process (all
        start from the same ``rng`` and execute the same launch stream)."""
        self.rng, k = jax.random.split(self.rng)
        return self._glob(np.asarray(k), P())

    def _build_broadcast(self):
        devs = np.array(jax.devices()).reshape(self.n_processes,
                                               jax.local_device_count())
        self._bc_mesh = Mesh(devs, ("proc", "dev"))
        self._bc_jit = jax.jit(
            lambda tree: jax.tree.map(lambda x: jnp.sum(x, axis=0), tree),
            out_shardings=NamedSharding(self._bc_mesh, P()))

    def _broadcast(self, arrays: tuple) -> list[np.ndarray]:
        """Ship the coordinator's int32 arrays to every process.  All
        processes must call with equal shapes (workers pass templates)."""
        if self.n_processes == 1:
            return [np.asarray(a, np.int32) for a in arrays]

        def pre(x):
            x = np.asarray(x, np.int32)
            full = np.zeros((self.n_processes,) + x.shape, np.int32)
            if self.is_coordinator:
                full[0] = x              # workers sum in their zero rows
            return make_global(self._bc_mesh, P("proc"), full)

        out = self._bc_jit(tuple(pre(a) for a in arrays))
        jax.block_until_ready(out)       # every local shard, see above
        return [np.asarray(x.addressable_data(0)) for x in out]

    def _cmd(self, op: int, arg: int = 0) -> None:
        if not self.is_coordinator:
            # a worker that drives scheduling (submit()/run()) would
            # contribute zero rows to its own command broadcast and hang
            # or desync the fleet - fail loudly at the first command
            raise RuntimeError(
                f"process {self.process_id} is a worker: only the "
                "coordinator (process 0) issues commands; call "
                "serve_worker() here")
        self._broadcast((np.asarray([op, arg], np.int32),))

    def _recv_cmd(self) -> tuple[int, int]:
        out, = self._broadcast((np.zeros((2,), np.int32),))
        if int(out[0]) == CMD_ABORT:
            raise RuntimeError("multi-host serve coordinator aborted")
        return int(out[0]), int(out[1])

    def _send(self, arrays: list[np.ndarray]) -> None:
        self._broadcast(tuple(arrays))

    def _recv(self, shapes: list[tuple[int, ...]]) -> list[np.ndarray]:
        return self._broadcast(tuple(np.zeros(s, np.int32) for s in shapes))

    # ------------------------------------------------- shared launch bodies
    # Each _do_* runs on EVERY process with identical host arrays (the
    # coordinator's plan, either local or just received) and performs the
    # same global-mesh launch; the replicated sampled-token output is
    # locally addressable everywhere.
    def _do_prefill(self, tokens, seq_lens, src_map) -> np.ndarray:
        key = self._next_key()
        nxt, sub = self._prefill_many(
            key, self.params, {"tokens": self._glob(tokens, P("data"))},
            self._prefill_pool, self._glob(seq_lens, P("data")))
        self.caches = self._scatter(self.caches, sub,
                                    self._glob(src_map, P("data")))
        jax.block_until_ready((nxt, self.caches))
        return np.asarray(nxt)

    def _do_chunk_first(self, tokens, seq_lens) -> np.ndarray:
        key = self._next_key()
        nxt, self._chunk_sub = self._prefill_many(
            key, self.params, {"tokens": self._glob(tokens, P("data"))},
            self._prefill_pool, self._glob(seq_lens, P("data")))
        jax.block_until_ready((nxt, self._chunk_sub))
        return np.asarray(nxt)

    def _do_chunk_next(self, tokens, seq_lens, start_lens) -> np.ndarray:
        key = self._next_key()
        nxt, self._chunk_sub = self._prefill_chunk(
            key, self.params, {"tokens": self._glob(tokens, P("data"))},
            self._chunk_sub, self._glob(seq_lens, P("data")),
            self._glob(start_lens, P("data")))
        jax.block_until_ready((nxt, self._chunk_sub))
        return np.asarray(nxt)

    def _do_chunk_end(self, src_map) -> None:
        self.caches = self._scatter(self.caches, self._chunk_sub,
                                    self._glob(src_map, P("data")))
        jax.block_until_ready(self.caches)
        self._chunk_sub = None

    def _do_decode(self, tokens, positions) -> np.ndarray:
        key = self._next_key()
        nxt, self.caches = self._decode(key, self.params, self.caches,
                                        self._glob(tokens, P("data")),
                                        self._glob(positions, P("data")))
        jax.block_until_ready((nxt, self.caches))
        return np.asarray(nxt)

    # --------------------------------------------------- coordinator driver
    def _exec_prefill(self, plan: PrefillPlan, extras) -> np.ndarray:
        if extras:
            raise NotImplementedError("multi-host serving takes no extras")
        self._cmd(CMD_PREFILL, plan.bucket)
        self._send([plan.tokens, plan.seq_lens, plan.src_map])
        return self._do_prefill(plan.tokens, plan.seq_lens, plan.src_map)

    def _exec_chunked(self, plan: ChunkedPlan, extras) -> np.ndarray:
        if extras:
            raise NotImplementedError("multi-host serving takes no extras")
        b, tokens, seq_lens = plan.first
        self._cmd(CMD_CHUNK_FIRST, b)
        self._send([tokens, seq_lens])
        nxt = self._do_chunk_first(tokens, seq_lens)
        for b, tokens, seq_lens, start_lens in plan.chunks:
            self._cmd(CMD_CHUNK_NEXT, b)
            self._send([tokens, seq_lens, start_lens])
            nxt = self._do_chunk_next(tokens, seq_lens, start_lens)
        self._cmd(CMD_CHUNK_END)
        self._send([plan.src_map])
        self._do_chunk_end(plan.src_map)
        return nxt

    def _exec_decode(self, plan: DecodePlan) -> np.ndarray:
        self._cmd(CMD_DECODE)
        self._send([plan.tokens, plan.positions])
        return self._do_decode(plan.tokens, plan.positions)

    def _validate_extras(self, prompt_len: int, extras) -> None:
        # entry-point rejection, BEFORE anything queues or a plan claims
        # a slot (the _exec_* backstops would leak it); unreachable for
        # well-formed use, since __init__ refuses vision/encdec configs
        if extras:
            raise NotImplementedError("multi-host serving takes no extras")

    def run(self, requests, extras=None):
        if not self.is_coordinator:
            raise RuntimeError(
                f"process {self.process_id} is a worker: call "
                "serve_worker(), only process 0 drives run()")
        if extras:
            self._validate_extras(0, extras)   # even for an empty trace
        try:
            return super().run(requests, extras)
        except BaseException:
            # best-effort: unblock workers waiting at the next header
            # rendezvous (a worker already desynced inside a payload
            # collective is covered by the runtime/CI timeout instead).
            # The workers then EXIT, so mark the fleet stopped - a
            # `finally: stop_workers()` cleanup must not broadcast into
            # dead peers and hang on the gloo timeout.
            try:
                self._cmd(CMD_ABORT)
            except Exception:
                pass               # peer already gone: keep the original error
            finally:
                self._stopped = True
            raise

    def stop_workers(self) -> None:
        """Release the worker loops; the engine stays usable for stats."""
        if self.is_coordinator and not self._stopped:
            self._cmd(CMD_STOP)
            self._stopped = True

    # --------------------------------------------------------- worker loop
    def serve_worker(self) -> None:
        """Follow the coordinator's command stream until CMD_STOP."""
        assert not self.is_coordinator, "process 0 is the coordinator"
        S = self.slots
        while True:
            op, L = self._recv_cmd()
            if op == CMD_STOP:
                return
            if op == CMD_PREFILL:
                t, sl, m = self._recv([(S, L), (S,), (S,)])
                self._do_prefill(t, sl, m)
            elif op == CMD_CHUNK_FIRST:
                t, sl = self._recv([(S, L), (S,)])
                self._do_chunk_first(t, sl)
            elif op == CMD_CHUNK_NEXT:
                t, sl, st = self._recv([(S, L), (S,), (S,)])
                self._do_chunk_next(t, sl, st)
            elif op == CMD_CHUNK_END:
                m, = self._recv([(S,)])
                self._do_chunk_end(m)
            elif op == CMD_DECODE:
                t, p = self._recv([(S, 1), (S, 1)])
                self._do_decode(t, p)
            else:
                raise RuntimeError(f"unknown multi-host serve opcode {op}")

    # ------------------------------------------------------ per-host stats
    def host_stats(self) -> dict[int, dict[str, int]]:
        """Coordinator-side admit/occupancy totals per OWNING process,
        derived from the replica->process map (the scheduler only exists
        on process 0, so these are its authoritative counters)."""
        out: dict[int, dict[str, int]] = {}
        for proc, reps in self.host_replicas.items():
            out[proc] = {
                "replicas": len(reps),
                "admits": sum(self.stats["replica_admits"][r] for r in reps),
                "occupied": sum(self.stats["replica_occupancy"][r]
                                for r in reps),
                "slots": len(reps) * self.slots_per_replica,
            }
        return out
