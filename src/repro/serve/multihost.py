"""Multi-process serving: ``MultiHostServeEngine`` over a ``jax.distributed``
mesh, with a coordinator protocol.

Topology.  N OS processes each own a slice of the global device set;
``launch/mesh.py`` lays them out contiguously along the 'data' axis of the
('data', 'model') serve mesh, so every data-parallel replica's cache-slot
block is addressable by exactly one process (``distributed/sharding.
process_replicas``).  All processes execute the SAME SPMD launch sequence
- multi-controller jax requires it - but scheduling is NOT replicated:

  * **coordinator (process 0)** runs the scheduler core (serve/core.py)
    as a host-side singleton: the pending queue, bucket grouping and
    least-loaded replica routing live only there, exactly as on one
    process.  Each device launch it decides is announced to the workers
    as a COMMAND: a fixed-shape int32 header (opcode + bucket length)
    followed by the plan's numpy payload, both shipped by a one-to-all
    psum broadcast that blocks on every local shard (see ``_broadcast``).
  * **workers (process > 0)** run ``serve_worker()``: receive a command,
    execute the identical launch, repeat until CMD_STOP.  They hold no
    scheduler state - just the global cache pool (of which they
    physically store their replicas' shards) and the in-flight chunked
    sub-pool.

Collective fast path.  Sampling runs IN-PROGRAM per replica (inside the
shard_map body, like ``ShardedServeEngine``): a host-side sample would
force a device->host gather of the (slots, vocab) logits, which across
processes is not even addressable.  Decode additionally runs as an
N-step fused block (``engine.decode_scan``): ONE broadcast + ONE device
launch consumes up to ``decode_steps`` tokens per row, and the jit's
replicated out_sharding makes XLA broadcast the (slots, N) sampled token
block + ok flags to every device via an in-program all-gather - every
process then reads the full block from its local shard, no host-side
device gathers, and command-stream traffic per token drops to 1/N.
Because each replica samples over exactly the logits the single-process
engine computed (PDQ column-TP epilogue included), tokens stay bit-exact
vs ``ShardedServeEngine`` on the same logical mesh, fp and int8.

Failure handling (see DESIGN.md "Failure handling").  The command header
carries a monotonically increasing sequence number and a per-process ack
slot: every process CONTRIBUTES to the header exchange (coordinator: the
command; worker p: its last-completed seq in slot p), so each command
doubles as a fleet heartbeat - the coordinator verifies every worker
acked the previous command before the new one executes, and a desynced
worker is a typed ``ProtocolError`` instead of a silent hang.  Aborts are
typed: ``CMD_ABORT`` ships a reason code (exception / deadline / desync)
and workers raise ``CoordinatorAbort`` carrying it.  Every blocking
broadcast and device launch is armed with a ``DeadlineWatchdog``
(``launch_timeout=`` seconds; None disarms): a thread blocked inside a
gloo collective cannot be interrupted, so on expiry a side thread dumps
the coordinator's scheduler snapshot (if ``snapshot_path`` is set),
prints a typed ABORT_DEADLINE line and ``os._exit``s with
``fault.EXIT_DEADLINE`` - the launcher (launch/serve.py) then reports
which process timed out, and a later run resumes from the snapshot.
Exec-launch exceptions are NOT isolated per request here
(``_isolate_exec = False``): a coordinator that kept scheduling after a
failed collective would desync the fleet, so protocol errors are
fleet-fatal and recovery is drain-and-resume.
"""
from __future__ import annotations

import collections
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.fault import DeadlineWatchdog, _default_deadline_abort, \
    save_snapshot
from repro.distributed.sharding import (make_global, pool_shardings,
                                        process_replicas, serve_pool_specs)

from . import telemetry as tmod
from .core import ChunkedPlan, DecodePlan, PrefillPlan, Request
from .engine import DECODE_PAD, DEFAULT_BUCKETS, decode_scan
from .sharded import ShardedServeEngine

# coordinator -> worker opcodes.  Header: int32[4 + 3 * n_processes] =
# [op, arg, seq, n_extras, ack_0..ack_{n-1}, ing_0..ing_{n-1},
#  tim_0..tim_{n-1}] - arg is
# the bucket length (prefill/chunk), the abort reason code, or the source
# process (ingress pull); seq numbers every command; ack_p is process p's
# last-completed command seq (the heartbeat); ing_p is the length of
# process p's local ingress queue (worker-side submits awaiting pickup),
# so EVERY command exchange doubles as an ingress announcement and the
# coordinator never needs a side channel to learn about remote submits;
# tim_p is the wall time (microseconds, int32-clamped) process p spent
# executing its PREVIOUS command - the telemetry piggyback.  The
# coordinator attributes slot p to the kind of the command it issued one
# seq earlier, folds it into per-process fleet launch histograms and,
# when tracing, reconstructs a retroactive worker span (ts = arrival -
# duration on the coordinator clock - no clock sync, good enough to read
# phase overlap).  Timing costs ZERO extra collectives: it rides the
# header exchange every command already performs.
CMD_STOP = 0
CMD_PREFILL = 1        # payload: tokens (slots, L), seq_lens, src_map,
                       #          row_uids, row_steps [+ n_extras arrays,
                       #          each a shape-tag header then the values]
CMD_CHUNK_FIRST = 2    # payload: tokens (slots, L), seq_lens, row_uids,
                       #          row_steps (kept for the later chunks)
CMD_CHUNK_NEXT = 3     # payload: tokens (slots, L), seq_lens, start_lens
CMD_CHUNK_END = 4      # payload: src_map
CMD_DECODE = 5         # payload: tokens (slots, 1), positions (slots, 1),
                       #          row_uids, row_steps, n_steps (per-row
                       #          block budgets); arg = the block size N
                       #          (lockstep-verified by every worker)
CMD_ABORT = 6          # coordinator died: workers raise (arg = reason)
CMD_INGRESS = 7        # pull process arg's queued submits: count int32[1]
                       # from arg, then per request meta int32[4] =
                       # [uid, prompt_len, max_new, deadline_ms] + prompt
CMD_POLL = 8           # no-op rendezvous: harvest acks + ingress counts
                       # while the scheduler is otherwise idle
CMD_PAGE_COPY = 9      # paged pool COW copy: payload copy map
                       # (n_replicas * pool_pages,) int32, -1 = keep

# opcode -> launch kind for the header timing piggyback (commands whose
# worker-side execution is a device launch worth a histogram/span; polls,
# ingress pulls and the chunk-end scatter are protocol overhead)
_CMD_KINDS = {CMD_PREFILL: "prefill", CMD_CHUNK_FIRST: "chunked",
              CMD_CHUNK_NEXT: "chunked", CMD_DECODE: "decode",
              CMD_PAGE_COPY: "page_copy"}

# extras keys the prefill payload can carry (shape-tag header word 0);
# float32 values ride the int32 psum exchange losslessly via a bitcast
# (every non-source process contributes zeros, and zeros-sum preserves
# the source's bit pattern exactly)
_EXTRA_KEYS = {"frames": 1, "patches": 2}
_EXTRA_IDS = {v: k for k, v in _EXTRA_KEYS.items()}

# typed abort reasons (CMD_ABORT arg)
ABORT_EXC = 1          # coordinator raised while scheduling
ABORT_DEADLINE = 2     # a deadline watchdog fired fleet-side
ABORT_DESYNC = 3       # heartbeat ack mismatch: a worker fell out of step
ABORT_REASONS = {ABORT_EXC: "coordinator exception",
                 ABORT_DEADLINE: "deadline exceeded",
                 ABORT_DESYNC: "worker desynchronized"}


class ProtocolError(RuntimeError):
    """The command stream itself is corrupt (bad opcode, failed ack)."""


class CoordinatorAbort(RuntimeError):
    """Raised on workers when the coordinator broadcasts CMD_ABORT."""

    def __init__(self, reason: int):
        self.reason = int(reason)
        super().__init__(
            "multi-host serve coordinator aborted: "
            f"{ABORT_REASONS.get(self.reason, f'reason {reason}')}")


class MultiHostServeEngine(ShardedServeEngine):
    """ShardedServeEngine over a multi-process ('data', 'model') mesh.

    Every process constructs the engine with IDENTICAL arguments (params
    are host-replicated: same init seed or same checkpoint).  Process 0
    then drives ``run(requests)``; every other process calls
    ``serve_worker()`` and follows the broadcast command stream.  Call
    ``stop_workers()`` on the coordinator when the engine is done so the
    workers' loops return.

    Vision/encdec extras (patches/frames side inputs) ride the prefill
    payload as shape-tagged float32 arrays bitcast over the int32
    exchange; unsupported combinations (unknown keys, non-float dtypes,
    chunked prefill + extras) are typed ``ProtocolError``s at submit
    entry.  Temperature sampling runs in-program with
    per-request keys derived from (rng, uid, step) - the same derivation
    the single-process engines use - so sampled streams match them
    token-for-token, chunked prefill included (every process holds the
    same base ``rng`` and receives the batch uids/steps with the plan).
    """

    # a failed launch here is fleet-fatal, not per-request: the workers
    # already rendezvoused on this command, so skipping it on the
    # coordinator alone would desync every later collective.  Recovery is
    # abort + drain-and-resume instead (run()'s except path).
    _isolate_exec = False

    def __init__(self, cfg, params, *, mesh, slots_per_replica: int = 4,
                 max_len: int = 256, quantize_weights: bool = False,
                 temperature: float = 0.0, rng: jax.Array | None = None,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 chunked_prefill: bool = False, decode_steps: int = 1,
                 fault=None,
                 pdq_fallback: bool = False,
                 launch_timeout: float | None = None,
                 snapshot_path: str | None = None,
                 paged: bool = False, page_size: int = 64,
                 pool_pages: int | None = None,
                 prefix_sharing: bool = True,
                 telemetry: bool = True, trace: bool = False):
        self.n_processes = jax.process_count()
        self.process_id = jax.process_index()
        self.is_coordinator = self.process_id == 0
        data = int(mesh.shape["data"])
        if data % self.n_processes:
            # a mesh row straddling a process boundary would make the TP
            # all_gather a cross-process collective and break the
            # replica->process slot-state attribution
            raise ValueError(
                f"mesh 'data' axis ({data}) must divide over the "
                f"{self.n_processes} jax.distributed processes")
        self._chunk_sub = None
        self._chunk_us = None          # (uids, steps) held across chunk cmds
        self._chunk_track = None       # host (uids, steps) for _track_remote
        self._chunk_nxt = None         # last chunk's sampled tokens
        self._stopped = False
        self.launch_timeout = launch_timeout
        self._hdr = 4 + 3 * self.n_processes
        self._seq = 1                  # next command number (coordinator)
        self._done_seq = 0             # last completed command (workers)
        self._last_exec_us = 0         # worker: previous command exec wall
        self._prev_kind = None         # coordinator: previous command kind
        # worker-side ingress: local submits queued for coordinator pickup
        # (announced as queue counts on every header exchange)
        self._ingress_lock = threading.Lock()
        self._out_q: collections.deque = collections.deque()
        self._ingress_counts = [0] * self.n_processes
        self._remote: dict[int, dict] = {}   # uid -> {'max_new', 'tokens'}
        self._remote_seq = 1
        # every process carries its own Telemetry keyed by its jax process
        # index; the coordinator's additionally aggregates the fleet (the
        # piggybacked worker timings land there)
        tel = tmod.Telemetry(enabled=telemetry, trace=trace,
                             pid=self.process_id)
        super().__init__(cfg, params, mesh=mesh,
                         slots_per_replica=slots_per_replica, max_len=max_len,
                         quantize_weights=quantize_weights,
                         temperature=temperature, rng=rng, buckets=buckets,
                         chunked_prefill=chunked_prefill,
                         decode_steps=decode_steps, fault=fault,
                         pdq_fallback=pdq_fallback, paged=paged,
                         page_size=page_size, pool_pages=pool_pages,
                         prefix_sharing=prefix_sharing, tel=tel)
        if self.is_coordinator:
            for p in range(1, self.n_processes):
                self.tel.tracer.name_process(p, f"jax process {p}")
                self.tel.tracer.name_thread(p, tmod.TID_LAUNCH, "launch")
        self.snapshot_path = snapshot_path
        self.stats["remote_ingress"] = 0   # requests pulled from workers
        # replica -> owning process, for per-host stats and routing debug
        self.host_replicas = process_replicas(self.mesh)
        if self.n_processes > 1:
            self._build_broadcast()

    # ------------------------------------------------------- device programs
    def _init_pools(self):
        """Shape-only stand-ins: _build_jitted reads the pool tree
        structure (specs/shardings) and then allocates the real pools
        directly on the global mesh - materializing host zeros here would
        be two full pool allocations thrown away per process."""
        self._prefill_pool = jax.eval_shape(
            lambda: self.bundle.init_caches(self.slots, self.max_len,
                                            self.mem_len))
        if self.paged:
            self.caches = jax.eval_shape(
                lambda: self._paged_ops.init(
                    self.pool_pages * self.n_replicas))
        else:
            self.caches = self._prefill_pool

    def _build_jitted(self):
        cs = serve_pool_specs(self.caches)
        dp = P("data")
        pool_sh = pool_shardings(self.mesh, self.caches)
        repl = NamedSharding(self.mesh, P())

        # long-lived global buffers.  Params: every process holds the same
        # host values; make_global donates each process's addressable
        # (replicated) shards.  Cache pools: allocated directly on the mesh
        # by a sharded-output jit - a device_put of the process-local zeros
        # cannot address the other processes' shards.
        self.params = jax.tree.map(
            lambda x: make_global(self.mesh, P(), np.asarray(x)), self.params)
        # the paged pool tree has the same structure and per-leaf ranks as
        # the slot-row scratch (page axis where the slot axis was), so ONE
        # specs/shardings tree serves both
        mk_scratch = jax.jit(
            lambda: self.bundle.init_caches(self.slots, self.max_len,
                                            self.mem_len),
            out_shardings=pool_sh)
        if self.paged:
            mk_pool = jax.jit(
                lambda: self._paged_ops.init(
                    self.pool_pages * self.n_replicas),
                out_shardings=pool_sh)
        else:
            mk_pool = mk_scratch
        self.caches = mk_pool()
        self._prefill_pool = mk_scratch()

        # the base sampling key, made global once: every process constructs
        # the engine with the same rng argument, so the replicated shards
        # agree bit-for-bit
        self._rng_glob = self._glob(np.asarray(self.rng), P())

        # device programs are the ShardedServeEngine builders verbatim
        # (per-replica in-body sampling, N-step fused decode scan, TP +
        # pdq guard in the shard_map body) with one multi-process twist:
        # replicated out_shardings make XLA all-gather the (slots, N)
        # sampled-token block + ok flags to every device IN-PROGRAM, so
        # each process reads the full block off its local shard - no
        # host-side cross-process gathers, and the pdq health summary
        # rides the same sync.
        self._decode = self._traced_decode_sharded(
            decode_scan(self.bundle.decode_step, self._sample_fn(),
                        self.decode_steps, self.tel.enabled),
            in_specs=(P(), P(), cs, dp, dp, dp, dp, dp), donate=(),
            out_shardings=(repl, repl, pool_sh, repl))
        ps = ((repl, repl, pool_sh), repl)
        self._prefill_many = self._traced_sharded_jit(
            self._sampled_prefill(self.bundle.prefill_many),
            "prefill_compiles",
            in_specs=(P(), P(), dp, cs, dp, dp, dp), out_specs=(dp, dp, cs),
            tel=True, out_shardings=ps)
        self._prefill_chunk = self._traced_sharded_jit(
            self._sampled_prefill(self.bundle.prefill_chunk),
            "chunk_compiles",
            in_specs=(P(), P(), dp, cs, dp, dp, dp, dp),
            out_specs=(dp, dp, cs), tel=True, out_shardings=ps)
        self._scatter = self._traced_sharded_jit(
            self.bundle.cache_scatter, None,
            in_specs=(cs, cs, dp), out_specs=cs, donate=(0,))
        self._prefill_one = None

        if self.paged:
            # paged N-step decode (same collective fast path as _decode);
            # land/copy ride the plain sharded launches
            po = self._paged_ops
            pts = P("data", None)
            self._decode_paged = self._traced_decode_sharded(
                self._paged_decode_fn(),
                in_specs=(P(), P(), cs, pts, dp, dp, dp, dp, dp), donate=(),
                out_shardings=(repl, repl, pool_sh, repl))
            self._land = self._traced_sharded_jit(
                po.land, None, in_specs=(cs, cs, dp, dp, dp), out_specs=cs,
                donate=(0,))
            self._page_copy = self._traced_sharded_jit(
                po.copy, None, in_specs=(cs, dp), out_specs=cs, donate=(0,))

    # --------------------------------------------------------- the protocol
    # Coordinator -> worker shipping is a psum-based one-to-all broadcast
    # (workers contribute zeros), like multihost_utils.broadcast_one_to_all
    # BUT blocked on EVERY local shard before returning.  Gloo matches
    # collective ops on a TCP device pair by posting order, and an op only
    # sequences a device that DEPENDS on it: blocking just the first local
    # shard (what np.asarray does) lets the other local devices' tail
    # collectives drain into the next program's ops and cross-pair them -
    # observed as gloo preamble-size aborts.  Every launch here therefore
    # blocks all addressable shards of anything carrying a collective
    # before the next program is dispatched.
    def _glob(self, x, spec):
        return make_global(self.mesh, spec, x)

    # ------------------------------------------------- deadline watchdogs
    def _deadline(self, reason: str) -> DeadlineWatchdog:
        """Arm a watchdog around one blocking rendezvous/launch.  Disarmed
        when ``launch_timeout`` is None or the fleet is one process
        (nothing to rendezvous with)."""
        seconds = self.launch_timeout if self.n_processes > 1 else None
        return DeadlineWatchdog(seconds, reason=reason,
                                on_timeout=self._deadline_abort)

    def _deadline_abort(self, reason: str, seconds: float) -> None:
        # the main thread is stuck inside a collective, but the host-side
        # scheduler state is consistent between result applications: dump
        # the drain record first so a restarted coordinator can resume,
        # then declare this process dead with the typed exit code.
        if self.is_coordinator and self.snapshot_path:
            try:
                save_snapshot(self.snapshot_path, self.snapshot())
            except Exception:
                pass
        _default_deadline_abort(f"process {self.process_id}: {reason}",
                                seconds)

    # -------------------------------------------------------- broadcasts
    def _build_broadcast(self):
        devs = np.array(jax.devices()).reshape(self.n_processes,
                                               jax.local_device_count())
        self._bc_mesh = Mesh(devs, ("proc", "dev"))
        self._bc_jit = jax.jit(
            lambda tree: jax.tree.map(lambda x: jnp.sum(x, axis=0), tree),
            out_shardings=NamedSharding(self._bc_mesh, P()))

    def _broadcast(self, arrays: tuple, *, all_ranks: bool = False,
                   src: int = 0) -> list[np.ndarray]:
        """psum-exchange int32 arrays across the fleet.  All processes must
        call with equal shapes.  Default: one-to-all from ``src`` (every
        other process contributes zero rows, everyone reads the source's
        values; the coordinator ships plans with src=0, an ingress pull
        reverses direction with src=worker).  With ``all_ranks`` every
        process contributes its OWN row - the command header uses this so
        worker acks + ingress counts ride the same exchange."""
        if self.n_processes == 1:
            return [np.asarray(a, np.int32) for a in arrays]
        row = self.process_id if all_ranks else src

        def pre(x):
            x = np.asarray(x, np.int32)
            full = np.zeros((self.n_processes,) + x.shape, np.int32)
            if all_ranks or self.process_id == src:
                full[row] = x            # others sum in their zero rows
            return make_global(self._bc_mesh, P("proc"), full)

        with self._deadline("collective broadcast"):
            out = self._bc_jit(tuple(pre(a) for a in arrays))
            jax.block_until_ready(out)   # every local shard, see above
        return [np.asarray(x.addressable_data(0)) for x in out]

    # ----------------------------------------------------- command stream
    def _cmd(self, op: int, arg: int = 0, n_extras: int = 0) -> None:
        if not self.is_coordinator:
            # a worker that drives scheduling (submit()/run()) would
            # contribute zero rows to its own command broadcast and hang
            # or desync the fleet - fail loudly at the first command
            raise RuntimeError(
                f"process {self.process_id} is a worker: only the "
                "coordinator (process 0) issues commands; call "
                "serve_worker() here")
        seq = self._seq
        N = self.n_processes
        hdr = np.zeros((self._hdr,), np.int32)
        hdr[0], hdr[1], hdr[2], hdr[3] = op, arg, seq, n_extras
        hdr[4] = seq - 1                 # coordinator's own ack slot
        hdr = self.fault.on_broadcast(seq, hdr)
        out, = self._broadcast((hdr,), all_ranks=True)
        self._seq += 1
        # piggybacked worker ingress announcement (see header layout)
        self._ingress_counts = [int(out[4 + N + p]) for p in range(N)]
        # piggybacked worker launch timings: slot p carries the wall time
        # of worker p's PREVIOUS command, so attribute it to the kind of
        # the command issued one seq earlier
        if self._prev_kind is not None and self.tel.enabled:
            tr = self.tel.tracer
            for p in range(1, N):
                us = int(out[4 + 2 * N + p])
                if us > 0:
                    self.tel.launch_histogram(
                        self._prev_kind, process=p).observe(us / 1e6)
                    if tr.enabled:
                        tr.add(f"launch:{self._prev_kind}",
                               ts=tr.now_us() - us, dur=us, pid=p,
                               tid=tmod.TID_LAUNCH, args={"process": p})
        self._prev_kind = _CMD_KINDS.get(op)
        # piggybacked heartbeat: the worker loop is sequential, so at this
        # rendezvous every live worker must have completed seq - 1 exactly
        for p in range(1, N):
            if int(out[4 + p]) != seq - 1:
                raise ProtocolError(
                    f"worker {p} acked command seq {int(out[4 + p])} at "
                    f"command seq {seq} (expected {seq - 1}): the fleet is "
                    "desynchronized")

    def _recv_cmd(self) -> tuple[int, int, int, int]:
        hdr = np.zeros((self._hdr,), np.int32)
        hdr[4 + self.process_id] = self._done_seq      # heartbeat/ack
        with self._ingress_lock:                       # queued submits
            hdr[4 + self.n_processes + self.process_id] = len(self._out_q)
        # previous command's exec wall time (telemetry piggyback)
        hdr[4 + 2 * self.n_processes + self.process_id] = self._last_exec_us
        hdr = self.fault.on_broadcast(self._done_seq + 1, hdr)
        out, = self._broadcast((hdr,), all_ranks=True)
        op, arg, seq, n_ex = (int(out[0]), int(out[1]), int(out[2]),
                              int(out[3]))
        if op == CMD_ABORT:
            raise CoordinatorAbort(arg)
        return op, arg, seq, n_ex

    def _send(self, arrays: list[np.ndarray]) -> None:
        self._broadcast(tuple(arrays))

    def _recv(self, shapes: list[tuple[int, ...]]) -> list[np.ndarray]:
        return self._broadcast(tuple(np.zeros(s, np.int32) for s in shapes))

    # ------------------------------------------------------ extras payload
    # Vision patches / encdec frames are float32 side inputs shared across
    # the batch (seed semantics, like the single-process engines).  They
    # ride the int32 exchange as [shape-tag header, bitcast values] pairs:
    # header int32[6] = [key_id, ndim, d0, d1, d2, d3], then the raveled
    # float32 buffer reinterpreted as int32 (psum over zero contributions
    # is bit-preserving, so no float rounding can occur in transit).
    def _norm_extras(self, extras) -> list[tuple[str, np.ndarray]]:
        if not extras:
            return []
        out = []
        for key in sorted(dict(extras)):       # deterministic wire order
            a = np.ascontiguousarray(np.asarray(extras[key], np.float32))
            out.append((key, a))
        return out

    def _send_extras(self, ex: list[tuple[str, np.ndarray]]) -> None:
        for key, a in ex:
            hdr = np.zeros((6,), np.int32)
            hdr[0], hdr[1] = _EXTRA_KEYS[key], a.ndim
            hdr[2:2 + a.ndim] = a.shape
            self._send([hdr])
            self._send([a.ravel().view(np.int32)])

    def _recv_extras(self, n: int) -> dict[str, np.ndarray]:
        ex = {}
        for _ in range(n):
            hdr, = self._recv([(6,)])
            key = _EXTRA_IDS.get(int(hdr[0]))
            nd = int(hdr[1])
            if key is None or not 1 <= nd <= 4:
                raise ProtocolError(
                    f"bad extras shape tag {hdr.tolist()} in prefill "
                    "payload (unknown key id or ndim out of range)")
            shape = tuple(int(d) for d in hdr[2:2 + nd])
            flat, = self._recv([(int(np.prod(shape)),)])
            ex[key] = flat.view(np.float32).reshape(shape)
        return ex

    # ------------------------------------------------- shared launch bodies
    # Each _do_* runs on EVERY process with identical host arrays (the
    # coordinator's plan, either local or just received) and performs the
    # same global-mesh launch; the replicated (tokens, ok) outputs are
    # locally addressable everywhere.
    def _us(self, uids, steps):
        # per-row sampling metadata: split over 'data' like the rows it
        # describes (sampling runs per replica inside the shard_map body)
        return (self._glob(np.asarray(uids, np.int32), P("data")),
                self._glob(np.asarray(steps, np.int32), P("data")))

    def _batch(self, tokens, extras) -> dict:
        batch = {"tokens": self._glob(tokens, P("data"))}
        for key, a in (extras or {}).items():
            # shared across requests (seed semantics): broadcast the
            # leading batch dim across the prefill rows, exactly like the
            # single-process engines' _extras_batch
            b = np.broadcast_to(a[:1], (self.slots,) + a.shape[1:])
            batch[key] = self._glob(np.ascontiguousarray(b), P("data"))
        return batch

    def _land_global(self, sub, src_map, land_rows, land_js) -> None:
        """Land a finished prefill: page-wise through the plan's land maps
        (paged pool) or whole slot rows (slot-row pool)."""
        if self.paged:
            self.caches = self._land(self.caches, sub,
                                     self._glob(src_map, P("data")),
                                     self._glob(land_rows, P("data")),
                                     self._glob(land_js, P("data")))
        else:
            self.caches = self._scatter(self.caches, sub,
                                        self._glob(src_map, P("data")))

    def _do_prefill(self, tokens, seq_lens, src_map, uids, steps,
                    extras=None, land_rows=None, land_js=None):
        u, s = self._us(uids, steps)
        with self._deadline("prefill launch"):
            (nxt, ok, sub), tel = self._prefill_many(
                self._rng_glob, self.params, self._batch(tokens, extras),
                self._prefill_pool, self._glob(seq_lens, P("data")), u, s)
            self._land_global(sub, src_map, land_rows, land_js)
            jax.block_until_ready((nxt, ok, tel, self.caches))
        nxt, ok = np.asarray(nxt), np.asarray(ok)
        self._observe_pdq(tel)      # psum'd fleet totals, replicated
        self._track_remote(nxt, ok, uids, steps)
        return nxt, ok

    def _do_chunk_first(self, tokens, seq_lens, uids, steps):
        self._chunk_us = self._us(uids, steps)
        self._chunk_track = (np.asarray(uids, np.int32),
                             np.asarray(steps, np.int32))
        u, s = self._chunk_us
        with self._deadline("chunked-prefill launch"):
            (nxt, ok, self._chunk_sub), tel = self._prefill_many(
                self._rng_glob, self.params,
                {"tokens": self._glob(tokens, P("data"))},
                self._prefill_pool, self._glob(seq_lens, P("data")), u, s)
            jax.block_until_ready((nxt, ok, tel, self._chunk_sub))
        self._observe_pdq(tel)
        self._chunk_nxt = (np.asarray(nxt), np.asarray(ok))
        return self._chunk_nxt

    def _do_chunk_next(self, tokens, seq_lens, start_lens):
        u, s = self._chunk_us
        with self._deadline("chunked-prefill launch"):
            (nxt, ok, self._chunk_sub), tel = self._prefill_chunk(
                self._rng_glob, self.params,
                {"tokens": self._glob(tokens, P("data"))},
                self._chunk_sub, self._glob(seq_lens, P("data")),
                self._glob(start_lens, P("data")), u, s)
            jax.block_until_ready((nxt, ok, tel, self._chunk_sub))
        self._observe_pdq(tel)
        self._chunk_nxt = (np.asarray(nxt), np.asarray(ok))
        return self._chunk_nxt

    def _do_chunk_end(self, src_map, land_rows=None, land_js=None) -> None:
        with self._deadline("chunk cache scatter"):
            self._land_global(self._chunk_sub, src_map, land_rows, land_js)
            jax.block_until_ready(self.caches)
        if self._chunk_nxt is not None and self._chunk_track is not None:
            # only the LAST chunk's sampled token is the request's first
            # real token; commit it to remote trackers now that the
            # sequence is complete
            nxt, ok = self._chunk_nxt
            self._track_remote(nxt, ok, *self._chunk_track)
        self._chunk_sub = None
        self._chunk_us = None
        self._chunk_track = None
        self._chunk_nxt = None

    def _do_decode(self, tokens, positions, uids, steps, n_steps,
                   page_tables=None):
        u, s = self._us(uids, steps)
        ns = self._glob(np.asarray(n_steps, np.int32), P("data"))
        with self._deadline("decode launch"):
            if self.paged:
                nxt, ok, self.caches, tel = self._decode_paged(
                    self._rng_glob, self.params, self.caches,
                    self._glob(page_tables, P("data", None)),
                    self._glob(tokens, P("data")),
                    self._glob(positions, P("data")), u, s, ns)
            else:
                nxt, ok, self.caches, tel = self._decode(
                    self._rng_glob, self.params, self.caches,
                    self._glob(tokens, P("data")),
                    self._glob(positions, P("data")), u, s, ns)
            jax.block_until_ready((nxt, ok, tel, self.caches))
        nxt, ok = np.asarray(nxt), np.asarray(ok)
        self._observe_pdq(tel)
        self._track_remote(nxt, ok, uids, steps)
        return nxt, ok

    def _do_page_copy(self, cmap) -> None:
        with self._deadline("page copy launch"):
            self.caches = self._page_copy(self.caches,
                                          self._glob(cmap, P("data")))
            jax.block_until_ready(self.caches)

    def _track_remote(self, nxt, ok, uids, steps) -> None:
        """Worker-side token mirror for its own remote submits: sampled
        tokens are replicated to every process in-program, so a worker
        reads its requests' streams straight off the plans it already
        executes - no result backhaul.  The (uid, step)-keyed append makes
        it robust to dummy rows and replays: a token only lands if its
        step equals the tokens mirrored so far.  ``nxt``/``ok`` may be
        (slots,) prefill rows or (slots, N) decode blocks; a row's block
        walk stops at the first bad token (non-finite row, DECODE_PAD
        budget padding, step replay, or max_new reached)."""
        if not self._remote:
            return
        uids = np.asarray(uids)
        steps = np.asarray(steps)
        nxt = np.asarray(nxt).reshape(len(uids), -1)
        ok = np.asarray(ok).reshape(len(uids), -1)
        for row, uid in enumerate(uids):
            rec = self._remote.get(int(uid))
            if rec is None:
                continue
            for t in range(nxt.shape[1]):
                tok = int(nxt[row, t])
                if (not bool(ok[row, t]) or tok == DECODE_PAD
                        or int(steps[row]) + t != len(rec["tokens"])
                        or len(rec["tokens"]) >= rec["max_new"]):
                    break
                rec["tokens"].append(tok)

    # --------------------------------------------------- coordinator driver
    def _exec_prefill(self, plan: PrefillPlan, extras):
        ex = self._norm_extras(extras)
        self._cmd(CMD_PREFILL, plan.bucket, n_extras=len(ex))
        payload = [plan.tokens, plan.seq_lens, plan.src_map,
                   plan.row_uids, plan.row_steps]
        if self.paged:          # page landing maps ride the same payload
            payload += [plan.land_rows, plan.land_js]
        self._send(payload)
        self._send_extras(ex)
        # launch with the NORMALIZED (wire-format float32) arrays so the
        # coordinator computes on bit-identical inputs to the workers
        return self._do_prefill(plan.tokens, plan.seq_lens, plan.src_map,
                                plan.row_uids, plan.row_steps,
                                extras=dict(ex), land_rows=plan.land_rows,
                                land_js=plan.land_js)

    def _exec_chunked(self, plan: ChunkedPlan, extras):
        if extras:
            # unreachable for well-formed use: _validate_extras rejects the
            # combination at submit()/run() entry, before any slot is held
            raise ProtocolError(
                "chunked-prefill commands carry no extras payload")
        b, tokens, seq_lens = plan.first
        self._cmd(CMD_CHUNK_FIRST, b)
        self._send([tokens, seq_lens, plan.row_uids, plan.row_steps])
        res = self._do_chunk_first(tokens, seq_lens,
                                   plan.row_uids, plan.row_steps)
        for b, tokens, seq_lens, start_lens in plan.chunks:
            self._cmd(CMD_CHUNK_NEXT, b)
            self._send([tokens, seq_lens, start_lens])
            res = self._do_chunk_next(tokens, seq_lens, start_lens)
        self._cmd(CMD_CHUNK_END)
        payload = [plan.src_map]
        if self.paged:
            payload += [plan.land_rows, plan.land_js]
        self._send(payload)
        self._do_chunk_end(plan.src_map, plan.land_rows, plan.land_js)
        return res

    def _exec_decode(self, plan: DecodePlan):
        # arg carries the BLOCK size N: a worker built with a different
        # decode_steps would trace a different executable and desync the
        # fleet, so it verifies lockstep before executing
        self._cmd(CMD_DECODE, self.decode_steps)
        payload = [plan.tokens, plan.positions,
                   plan.row_uids, plan.row_steps, plan.n_steps]
        if self.paged:          # (slots, n_pp) replica-local page tables
            payload += [plan.page_tables]
        self._send(payload)
        return self._do_decode(plan.tokens, plan.positions,
                               plan.row_uids, plan.row_steps, plan.n_steps,
                               page_tables=plan.page_tables)

    def _exec_page_copy(self, replica: int, pairs) -> None:
        cmap = self._copy_map(replica, pairs)
        self._cmd(CMD_PAGE_COPY)
        self._send([cmap])
        self._do_page_copy(cmap)

    def _validate_extras(self, prompt_len: int, extras) -> None:
        # entry-point rejection, BEFORE anything queues or a plan claims a
        # slot (raising mid-admission would drop dequeued peers / leak the
        # planned slot).  Unsupported combinations are typed protocol
        # errors: they describe what the COMMAND STREAM cannot carry.
        if not extras:
            return
        for key, v in dict(extras).items():
            if key not in _EXTRA_KEYS:
                raise ProtocolError(
                    f"extras key {key!r} is not part of the multi-host "
                    f"command protocol (known: {sorted(_EXTRA_KEYS)})")
            a = np.asarray(v)
            if a.dtype.kind != "f":
                raise ProtocolError(
                    f"extras[{key!r}] dtype {a.dtype} is not a float type: "
                    "the prefill payload bitcasts float32 over the int32 "
                    "exchange")
            if not 1 <= a.ndim <= 4:
                raise ProtocolError(
                    f"extras[{key!r}] ndim {a.ndim} exceeds the shape-tag "
                    "header (1..4 dims)")
        if self.chunked_prefill and prompt_len > self.buckets[-1]:
            raise ProtocolError(
                "chunked-prefill commands carry no extras payload: "
                f"oversized prompt ({prompt_len} > bucket "
                f"{self.buckets[-1]}) cannot combine with vision/encdec "
                "extras on a multi-host fleet")

    def run(self, requests, extras=None):
        if not self.is_coordinator:
            raise RuntimeError(
                f"process {self.process_id} is a worker: call "
                "serve_worker(), only process 0 drives run()")
        if extras:
            self._validate_extras(0, extras)   # even for an empty trace
        try:
            return super().run(requests, extras)
        except BaseException as e:
            self._fleet_abort(e)
            raise

    def _fleet_abort(self, e: BaseException) -> None:
        # the fleet is lost: first persist the drain record (resume
        # needs it even if the abort below hangs on a dead peer), then
        # best-effort unblock workers waiting at the next header
        # rendezvous (a worker already desynced inside a payload
        # collective is covered by the deadline watchdog / CI timeout
        # instead).  The workers then EXIT, so mark the fleet stopped -
        # a `finally: stop_workers()` cleanup must not broadcast into
        # dead peers and hang on the gloo timeout.  Shared with the
        # streaming service's step loop (serve/service.py), whose driver
        # bypasses run().
        if self.snapshot_path:
            try:
                save_snapshot(self.snapshot_path, self.snapshot())
            except Exception:
                pass
        reason = (ABORT_DESYNC if isinstance(e, ProtocolError)
                  else ABORT_EXC)
        try:
            self._cmd(CMD_ABORT, reason)
        except Exception:
            pass               # peer already gone: keep the original error
        finally:
            self._stopped = True

    def stop_workers(self) -> None:
        """Release the worker loops; the engine stays usable for stats."""
        if self.is_coordinator and not self._stopped:
            self._cmd(CMD_STOP)
            self._stopped = True

    # ------------------------------------------------------ worker ingress
    # The multi-host residual of the streaming front door: a request can
    # enter the fleet through ANY process.  A worker's submit_remote()
    # queues locally; the queue LENGTH rides every header exchange (see
    # _recv_cmd), so the coordinator learns about remote submits at its
    # next command - or at an explicit CMD_POLL when otherwise idle - and
    # pulls the payload with CMD_INGRESS.  Tokens need no backhaul: the
    # in-program broadcast already replicates every sampled token to every
    # process, and _track_remote mirrors the worker's own uids off the
    # plans it executes anyway.
    def submit_remote(self, prompt, *, max_new: int = 16,
                      deadline_ms: int = 0) -> int:
        """Worker-side submit: queue a request for coordinator pickup.
        Returns its fleet-unique uid (namespaced by process id so remote
        uids never collide with the coordinator's counter).  ``deadline_ms``
        is RELATIVE (processes share no clock): the coordinator arms the
        absolute deadline at ingestion; 0 = none."""
        assert not self.is_coordinator, \
            "the coordinator submits locally (submit()/ServeService)"
        uid = (self.process_id << 20) | self._remote_seq
        self._remote_seq += 1
        prompt = np.asarray(prompt, np.int32)
        self._remote[uid] = {"max_new": int(max_new), "tokens": []}
        with self._ingress_lock:
            self._out_q.append((uid, prompt, int(max_new), int(deadline_ms)))
        return uid

    def remote_tokens(self, uid: int) -> list[int]:
        """Tokens mirrored so far for a submit_remote() uid (worker-side)."""
        return list(self._remote[uid]["tokens"])

    def remote_done(self, uid: int) -> bool:
        rec = self._remote[uid]
        return len(rec["tokens"]) >= rec["max_new"]

    def poll_ingress(self) -> list[Request]:
        """Coordinator: pull every announced worker submit into Request
        objects (the streaming service enqueues them like local traffic).
        Issues a CMD_POLL rendezvous first when no counts are known yet -
        an idle fleet still discovers remote submits."""
        if (not self.is_coordinator or self.n_processes == 1
                or self._stopped):
            return []
        if not any(self._ingress_counts[1:]):
            self._cmd(CMD_POLL)          # refresh counts via the heartbeat
        out: list[Request] = []
        for p in range(1, self.n_processes):
            if self._ingress_counts[p]:
                out.extend(self._pull_ingress(p))
        self.stats["remote_ingress"] += len(out)
        return out

    def _pull_ingress(self, p: int) -> list[Request]:
        self._cmd(CMD_INGRESS, p)
        cnt, = self._broadcast((np.zeros((1,), np.int32),), src=p)
        reqs = []
        for _ in range(int(cnt[0])):
            meta, = self._broadcast((np.zeros((4,), np.int32),), src=p)
            uid, L, max_new, dl_ms = (int(x) for x in meta)
            prompt, = self._broadcast((np.zeros((L,), np.int32),), src=p)
            r = Request(uid=uid, prompt=prompt.astype(np.int32),
                        max_new=max_new)
            if dl_ms > 0:
                r.deadline = self._clock() + dl_ms / 1000.0
            reqs.append(r)
        return reqs

    def _serve_ingress(self, src: int) -> None:
        """Worker side of CMD_INGRESS: process ``src`` drains its queue
        onto the wire; every other process contributes zeros and discards
        the received requests (only the coordinator schedules)."""
        mine = src == self.process_id
        if mine:
            with self._ingress_lock:
                batch = list(self._out_q)
                self._out_q.clear()
        else:
            batch = []
        cnt, = self._broadcast(
            (np.array([len(batch)], np.int32),), src=src)
        for i in range(int(cnt[0])):
            if mine:
                uid, prompt, max_new, dl_ms = batch[i]
                meta = np.array([uid, len(prompt), max_new, dl_ms],
                                np.int32)
            else:
                meta = np.zeros((4,), np.int32)
            meta, = self._broadcast((meta,), src=src)
            L = int(meta[1])
            pr = batch[i][1] if mine else np.zeros((L,), np.int32)
            self._broadcast((pr,), src=src)

    # --------------------------------------------------------- worker loop
    def serve_worker(self) -> None:
        """Follow the coordinator's command stream until CMD_STOP.

        Each completed command's seq is acked on the NEXT header exchange
        (the piggybacked heartbeat); a coordinator abort raises the typed
        ``CoordinatorAbort``, an unknown opcode the typed
        ``ProtocolError``."""
        assert not self.is_coordinator, "process 0 is the coordinator"
        S = self.slots
        # paged payloads: land maps (Np,), page tables (S, n_pp)
        Np = self.pool_pages * self.n_replicas if self.paged else 0
        lnd = [(Np,), (Np,)] if self.paged else []
        while True:
            op, arg, seq, n_ex = self._recv_cmd()
            if op == CMD_STOP:
                return
            t0 = time.perf_counter()   # stamped on the NEXT header exchange
            if op == CMD_PREFILL:
                recv = self._recv([(S, arg), (S,), (S,), (S,), (S,)] + lnd)
                t, sl, m, u, st = recv[:5]
                ex = self._recv_extras(n_ex)
                self._do_prefill(t, sl, m, u, st, extras=ex,
                                 land_rows=recv[5] if self.paged else None,
                                 land_js=recv[6] if self.paged else None)
            elif op == CMD_CHUNK_FIRST:
                t, sl, u, st = self._recv([(S, arg), (S,), (S,), (S,)])
                self._do_chunk_first(t, sl, u, st)
            elif op == CMD_CHUNK_NEXT:
                t, sl, st = self._recv([(S, arg), (S,), (S,)])
                self._do_chunk_next(t, sl, st)
            elif op == CMD_CHUNK_END:
                recv = self._recv([(S,)] + lnd)
                self._do_chunk_end(recv[0],
                                   recv[1] if self.paged else None,
                                   recv[2] if self.paged else None)
            elif op == CMD_DECODE:
                if arg != self.decode_steps:
                    raise ProtocolError(
                        f"coordinator decode block size {arg} != this "
                        f"worker's decode_steps {self.decode_steps}: every "
                        "process must construct the engine with identical "
                        "arguments")
                recv = self._recv([(S, 1), (S, 1), (S,), (S,), (S,)]
                                  + ([(S, self.n_pp)] if self.paged else []))
                self._do_decode(*recv[:5],
                                page_tables=recv[5] if self.paged else None)
            elif op == CMD_PAGE_COPY:
                cmap, = self._recv([(Np,)])
                self._do_page_copy(cmap)
            elif op == CMD_INGRESS:
                self._serve_ingress(arg)
            elif op == CMD_POLL:
                pass        # pure rendezvous: ack + counts already rode it
            else:
                raise ProtocolError(
                    f"unknown multi-host serve opcode {op} at command seq "
                    f"{seq} (corrupt or desynchronized command stream)")
            if op in _CMD_KINDS:       # launch kinds only: the coordinator
                self._last_exec_us = int(min(   # skips non-exec commands
                    (time.perf_counter() - t0) * 1e6, 2**31 - 1))
            self._done_seq = seq

    # ------------------------------------------------------ per-host stats
    def host_stats(self) -> dict[int, dict[str, int]]:
        """Coordinator-side admit/occupancy totals per OWNING process,
        derived from the replica->process map (the scheduler only exists
        on process 0, so these are its authoritative counters)."""
        out: dict[int, dict[str, int]] = {}
        for proc, reps in self.host_replicas.items():
            out[proc] = {
                "replicas": len(reps),
                "admits": sum(self.stats["replica_admits"][r] for r in reps),
                "occupied": sum(self.stats["replica_occupancy"][r]
                                for r in reps),
                "slots": len(reps) * self.slots_per_replica,
            }
        return out
