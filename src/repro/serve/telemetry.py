"""Telemetry plane for the serving stack: tracing + metrics, stdlib-only.

Two halves, deliberately decoupled from the scheduler so every engine
(single-device / sharded / multi-host coordinator) instruments the same
way:

  * ``Tracer`` - a bounded ring of completed spans.  The engines wrap
    request lifecycle edges (submit -> queued -> admit -> prefill/chunk ->
    decode -> finish/evict) and per-round phases (plan build, device
    launch, sample/apply, cache land, page COW copy, snapshot) in
    ``tracer.span(...)``; the multi-host coordinator additionally
    reconstructs worker-side launch spans from the timing slots riding
    the command-header exchange (``Tracer.add``).  ``export()`` emits
    Chrome trace-event JSON ({"traceEvents": [...]}; "X" complete events
    plus "M" process/thread-name metadata) loadable in Perfetto or
    chrome://tracing - one process row per jax process, one thread row
    per engine phase.  When disabled, ``span()`` returns a shared no-op
    context manager: the hot path pays one attribute check.

  * ``MetricsRegistry`` - counters, gauges and fixed-bucket histograms
    (TTFT, per-token latency, queue wait, launch wall time,
    admission-round occupancy, pdq health) rendered in the Prometheus
    text exposition format by ``render()`` (HELP/TYPE lines, cumulative
    ``_bucket{le=...}`` + ``_sum``/``_count`` series, label escaping).
    Histograms also answer ``percentile(q)`` from their buckets for the
    drain/exit printout, and ``merge()`` other histograms losslessly
    (fleet aggregation: per-worker timings fold into one distribution).

The facade ``Telemetry`` bundles one of each with the enable/trace
switches the engines thread from ``ServeConfig``.  Everything here is
thread-safe: the service loop thread records while the HTTP thread
scrapes.
"""
from __future__ import annotations

import bisect
import collections
import json
import math
import threading
import time

# Prometheus-style latency buckets (seconds): sub-millisecond kernels up
# to multi-second cold compiles all land in a finite bucket.
LATENCY_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 30.0)
# admission-round occupancy (requests admitted / slots live per round)
OCCUPANCY_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)
# fraction buckets (e.g. pdq clip-saturation rate per launch)
RATIO_BUCKETS = (0.0001, 0.001, 0.01, 0.05, 0.1, 0.25, 0.5, 1.0)


def _fmt(v: float) -> str:
    """Prometheus sample-value formatting: integral floats print as
    integers, +/-Inf spell Prometheus's '+Inf'/'-Inf'."""
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _escape_label(v: str) -> str:
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _escape_help(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace("\n", "\\n")


def _labels_text(labels: tuple[tuple[str, str], ...],
                 extra: tuple[tuple[str, str], ...] = ()) -> str:
    items = labels + extra
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{_escape_label(v)}"' for k, v in items) + "}"


class Counter:
    """Monotone counter.  ``inc`` is a single float add under the GIL, so
    scrapes racing the serving loop read a consistent (if slightly stale)
    value."""
    kind = "counter"

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def samples(self, labels):
        yield "", labels, (), self.value


class Gauge:
    kind = "gauge"

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def samples(self, labels):
        yield "", labels, (), self.value


class Histogram:
    """Fixed-bucket histogram with Prometheus exposition semantics.

    ``counts[i]`` is the RAW count of observations in bucket i (le =
    ``buckets[i]``); the +Inf overflow rides ``counts[-1]``.  Rendering
    accumulates, so ``_bucket{le="x"}`` is cumulative as Prometheus
    requires; ``merge`` adds raw counts, which can never lose an
    observation (the property test pins sum(counts) == count through any
    observe/merge interleaving)."""
    kind = "histogram"

    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets=LATENCY_BUCKETS):
        bs = sorted(float(b) for b in buckets)
        assert bs and all(b < c for b, c in zip(bs, bs[1:])), buckets
        self.buckets = tuple(bs)
        self.counts = [0] * (len(bs) + 1)         # [-1] is the +Inf bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1

    def merge(self, other: "Histogram") -> None:
        assert self.buckets == other.buckets, (self.buckets, other.buckets)
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.sum += other.sum
        self.count += other.count

    def percentile(self, q: float) -> float:
        """Bucket-resolution quantile (upper bound of the bucket holding
        the q-th observation, linearly interpolated inside it); 0.0 when
        empty.  Good enough for a drain printout; the real distribution
        lives in Prometheus."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        lo = 0.0
        for i, c in enumerate(self.counts[:-1]):
            hi = self.buckets[i]
            if cum + c >= target:
                frac = (target - cum) / c if c else 1.0
                return lo + frac * (hi - lo)
            cum += c
            lo = hi
        return self.buckets[-1]        # overflow bucket: report the edge

    def samples(self, labels):
        cum = 0
        for i, le in enumerate(self.buckets):
            cum += self.counts[i]
            yield "_bucket", labels, (("le", _fmt(le)),), cum
        yield "_bucket", labels, (("le", "+Inf"),), self.count
        yield "_sum", labels, (), self.sum
        yield "_count", labels, (), self.count


class _Family:
    __slots__ = ("name", "help", "kind", "children")

    def __init__(self, name, help_, kind):
        self.name = name
        self.help = help_
        self.kind = kind
        self.children: dict[tuple, Counter | Gauge | Histogram] = {}


class MetricsRegistry:
    """Name -> metric family; families hold one child per label set.
    Repeated ``counter/gauge/histogram`` calls with the same (name,
    labels) return the SAME child, so hook sites can either cache the
    handle or re-look it up."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    def _child(self, name, help_, kind, ctor, labels):
        key = tuple(sorted((str(k), str(v)) for k, v in labels.items()))
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = _Family(name, help_, kind)
            assert fam.kind == kind, (name, fam.kind, kind)
            child = fam.children.get(key)
            if child is None:
                child = fam.children[key] = ctor()
            return child

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._child(name, help, "counter", Counter, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._child(name, help, "gauge", Gauge, labels)

    def histogram(self, name: str, help: str = "",
                  buckets=LATENCY_BUCKETS, **labels) -> Histogram:
        return self._child(name, help, "histogram",
                           lambda: Histogram(buckets), labels)

    def get(self, name: str):
        """The family's children dict ({label tuple: metric}) or None."""
        with self._lock:
            fam = self._families.get(name)
            return dict(fam.children) if fam is not None else None

    def render(self) -> str:
        """Prometheus text exposition (version 0.0.4)."""
        out = []
        with self._lock:
            fams = [(f.name, f.help, f.kind,
                     list(f.children.items())) for f in
                    sorted(self._families.values(), key=lambda f: f.name)]
        for name, help_, kind, children in fams:
            if help_:
                out.append(f"# HELP {name} {_escape_help(help_)}")
            out.append(f"# TYPE {name} {kind}")
            for labels, metric in children:
                for suffix, lbl, extra, value in metric.samples(labels):
                    out.append(f"{name}{suffix}"
                               f"{_labels_text(lbl, extra)} {_fmt(value)}")
        return "\n".join(out) + "\n"


# ---------------------------------------------------------------- tracing


class _NullSpan:
    """Shared no-op context manager: the disabled-tracer fast path."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "cat", "tid", "args", "_t0")

    def __init__(self, tracer, name, cat, tid, args):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.tid = tid
        self.args = args

    def __enter__(self):
        self._t0 = self._tracer.now_us()
        return self

    def __exit__(self, *exc):
        t1 = self._tracer.now_us()
        self._tracer.add(self.name, cat=self.cat, ts=self._t0,
                         dur=t1 - self._t0, tid=self.tid,
                         args=self.args or None)
        return False


class Tracer:
    """Bounded span ring -> Chrome trace-event JSON (Perfetto-loadable).

    Timestamps are microseconds since tracer construction on
    ``time.perf_counter`` (monotonic).  ``add`` accepts retroactive spans
    with an explicit pid: the multi-host coordinator reconstructs worker
    launch spans from the header timing slots (ts = arrival - duration on
    the coordinator clock), so the merged trace carries one process row
    per jax process without any clock-sync machinery - good enough to
    read phase overlap, not for cross-host causality."""

    def __init__(self, *, enabled: bool = False, capacity: int = 65536,
                 pid: int = 0, clock=time.perf_counter):
        self.enabled = enabled
        self.pid = pid
        self._clock = clock
        self._epoch = clock()
        self._lock = threading.Lock()
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self.dropped = 0
        self._proc_names: dict[int, str] = {}
        self._thread_names: dict[tuple[int, int], str] = {}

    def now_us(self) -> float:
        return (self._clock() - self._epoch) * 1e6

    def to_us(self, t: float) -> float:
        """Convert a raw clock stamp (time.perf_counter by default) to
        trace microseconds."""
        return (t - self._epoch) * 1e6

    def name_process(self, pid: int, name: str) -> None:
        self._proc_names[int(pid)] = str(name)

    def name_thread(self, pid: int, tid: int, name: str) -> None:
        self._thread_names[(int(pid), int(tid))] = str(name)

    def span(self, name: str, *, cat: str = "phase", tid: int = 0, **args):
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, tid, args)

    def add(self, name: str, *, cat: str = "phase", ts: float, dur: float,
            pid: int | None = None, tid: int = 0, args=None) -> None:
        if not self.enabled:
            return
        ev = {"name": str(name), "cat": str(cat), "ph": "X",
              "ts": round(float(ts), 3), "dur": round(max(float(dur), 0.0), 3),
              "pid": int(self.pid if pid is None else pid), "tid": int(tid)}
        if args:
            ev["args"] = {k: (v if isinstance(v, (int, float, str, bool))
                              else str(v)) for k, v in args.items()}
        with self._lock:
            if len(self._ring) == self._ring.maxlen:
                self.dropped += 1
            self._ring.append(ev)

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._ring)

    def export(self) -> dict:
        """The Chrome trace object: span events + M-metadata rows naming
        every (pid, tid) seen, so Perfetto shows 'proc N' process tracks
        with one named thread row per engine phase."""
        spans = self.events()
        pids = sorted({ev["pid"] for ev in spans} | set(self._proc_names))
        tids = sorted({(ev["pid"], ev["tid"]) for ev in spans}
                      | set(self._thread_names))
        meta = []
        for pid in pids:
            meta.append({"name": "process_name", "ph": "M", "pid": pid,
                         "tid": 0, "args": {"name": self._proc_names.get(
                             pid, f"jax process {pid}")}})
        for pid, tid in tids:
            meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                         "tid": tid, "args": {"name": self._thread_names.get(
                             (pid, tid), f"tid {tid}")}})
        return {"traceEvents": meta + spans,
                "displayTimeUnit": "ms",
                "otherData": {"dropped_spans": self.dropped}}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.export(), f)
            f.write("\n")


# trace thread rows: one per engine phase, stable ids so every engine's
# trace lines up the same way in Perfetto
TID_REQUEST = 0      # request lifecycle spans (queued/admit/finish)
TID_PLAN = 1         # plan build (host numpy)
TID_LAUNCH = 2       # device launch (prefill/chunk/decode/copy)
TID_APPLY = 3        # sample gather + result apply
TID_SNAPSHOT = 4     # drain snapshot capture
_TID_NAMES = {TID_REQUEST: "requests", TID_PLAN: "plan",
              TID_LAUNCH: "launch", TID_APPLY: "apply",
              TID_SNAPSHOT: "snapshot"}


class Telemetry:
    """One per engine: the metrics registry + tracer pair, plus the
    standard serving metric handles the scheduler hooks feed.  ``enabled``
    gates ALL recording (the <=2% overhead budget is measured against
    this switch); ``trace`` additionally turns on span capture (ring
    memory + a clock read per phase, so it is a separate opt-in via
    ``--trace-out``)."""

    def __init__(self, *, enabled: bool = True, trace: bool = False,
                 pid: int = 0, capacity: int = 65536,
                 clock=time.perf_counter):
        self.enabled = bool(enabled)
        self.metrics = MetricsRegistry()
        self.tracer = Tracer(enabled=self.enabled and bool(trace),
                             capacity=capacity, pid=pid, clock=clock)
        self.tracer.name_process(pid, f"jax process {pid}"
                                 + (" (coordinator)" if pid == 0 else ""))
        for tid, name in _TID_NAMES.items():
            self.tracer.name_thread(pid, tid, name)
        m = self.metrics
        if self.enabled:
            self.ttft = m.histogram(
                "serve_ttft_seconds", "submit -> first token latency")
            self.per_token = m.histogram(
                "serve_per_token_seconds",
                "inter-token latency after the first token")
            self.queue_wait = m.histogram(
                "serve_queue_wait_seconds", "submit -> slot admission wait")
            self.round_occupancy = m.histogram(
                "serve_round_occupancy",
                "live slots at each decode round", buckets=OCCUPANCY_BUCKETS)
            self.shed = m.counter(
                "serve_shed_total",
                "requests shed at the admission watermark (HTTP 429)")
            self.pdq_fallbacks = m.counter(
                "pdq_fallbacks",
                "pdq_guard fp-dequant fallback activations (per guarded "
                "projection per launch)")
            self.pdq_clip_hits = m.counter(
                "pdq_clip_hits", "int8 outputs saturated at the clip edges")
            self.pdq_clip_total = m.counter(
                "pdq_clip_total", "int8 outputs checked for clip saturation")
            self.pdq_clip_rate = m.gauge(
                "pdq_clip_rate",
                "cumulative int8 clip-saturation rate (hits / total)")

    def span(self, name: str, *, cat: str = "phase", tid: int = TID_LAUNCH,
             **args):
        return self.tracer.span(name, cat=cat, tid=tid, **args)

    def launch_histogram(self, kind: str, process: int | None = None
                         ) -> Histogram:
        """Per-kind (and, fleet-aggregated, per-process) launch wall-time
        histogram; created lazily so only kinds that actually run
        appear in /metrics."""
        labels = {"kind": kind}
        if process is not None:
            labels["process"] = str(process)
        return self.metrics.histogram(
            "serve_launch_seconds", "device launch wall time", **labels)

    def observe_pdq(self, fallbacks: float, clip_hits: float,
                    clip_total: float) -> None:
        """Fold one launch's device-side pdq health summary (rode the
        existing token gather; see kernels/ops.pdq_telemetry)."""
        if not self.enabled:
            return
        self.pdq_fallbacks.inc(float(fallbacks))
        self.pdq_clip_hits.inc(float(clip_hits))
        self.pdq_clip_total.inc(float(clip_total))
        if self.pdq_clip_total.value > 0:
            self.pdq_clip_rate.set(
                self.pdq_clip_hits.value / self.pdq_clip_total.value)

    def summary(self) -> dict:
        """Drain/exit printout payload: p50/p90/p99 of the latency
        histograms (seconds)."""
        out = {}
        if not self.enabled:
            return out
        for key, h in (("ttft", self.ttft), ("per_token", self.per_token),
                       ("queue_wait", self.queue_wait)):
            out[key] = {"count": h.count,
                        "p50": h.percentile(0.50),
                        "p90": h.percentile(0.90),
                        "p99": h.percentile(0.99)}
        return out
