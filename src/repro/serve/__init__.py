from . import engine
from .engine import DEFAULT_BUCKETS, Request, ServeEngine
from .sharded import ShardedServeEngine
