from . import engine
from .engine import Request, ServeEngine
