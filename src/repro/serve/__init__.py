from . import core, engine
from .core import (DEFAULT_BUCKETS, EngineDraining, Request, SchedulerCore,
                   resume_requests)
from .engine import ServeEngine
from .frontend import HttpFrontend
from .multihost import CoordinatorAbort, MultiHostServeEngine, ProtocolError
from .service import OverloadedError, ServeService, TokenStream
from .sharded import ShardedServeEngine

__all__ = ["DEFAULT_BUCKETS", "Request", "SchedulerCore", "ServeEngine",
           "ShardedServeEngine", "MultiHostServeEngine", "CoordinatorAbort",
           "ProtocolError", "EngineDraining", "OverloadedError",
           "ServeService", "TokenStream", "HttpFrontend", "resume_requests",
           "core", "engine"]
