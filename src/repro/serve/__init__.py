from . import core, engine
from .core import DEFAULT_BUCKETS, Request, SchedulerCore, resume_requests
from .engine import ServeEngine
from .multihost import CoordinatorAbort, MultiHostServeEngine, ProtocolError
from .sharded import ShardedServeEngine

__all__ = ["DEFAULT_BUCKETS", "Request", "SchedulerCore", "ServeEngine",
           "ShardedServeEngine", "MultiHostServeEngine", "CoordinatorAbort",
           "ProtocolError", "resume_requests", "core", "engine"]
