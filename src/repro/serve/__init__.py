"""Public serving surface.

Construct engines through ``ServeConfig`` + ``build_engine`` (the one
factory every launcher/benchmark/test shares); the engine classes remain
importable for subclassing and isinstance checks.  Everything in
``__all__`` is covered by the cross-PR compatibility expectation -
anything else under ``repro.serve.*`` is internal.
"""
from . import core, engine, telemetry
from .config import ServeConfig, build_engine, resolve_model
from .core import (DEFAULT_BUCKETS, EngineDraining, Request, SchedulerCore,
                   resume_requests)
from .engine import ServeEngine
from .frontend import HttpFrontend
from .multihost import CoordinatorAbort, MultiHostServeEngine, ProtocolError
from .pages import PageError, PagePool, PrefixStore
from .service import OverloadedError, ServeService, TokenStream
from .sharded import ShardedServeEngine
from .telemetry import MetricsRegistry, Telemetry, Tracer

__all__ = ["ServeConfig", "build_engine", "resolve_model",
           "DEFAULT_BUCKETS", "Request", "SchedulerCore", "ServeEngine",
           "ShardedServeEngine", "MultiHostServeEngine", "CoordinatorAbort",
           "ProtocolError", "EngineDraining", "OverloadedError",
           "PagePool", "PrefixStore", "PageError",
           "ServeService", "TokenStream", "HttpFrontend", "resume_requests",
           "Telemetry", "Tracer", "MetricsRegistry", "telemetry",
           "core", "engine"]
