from . import core, engine
from .core import DEFAULT_BUCKETS, Request, SchedulerCore
from .engine import ServeEngine
from .multihost import MultiHostServeEngine
from .sharded import ShardedServeEngine

__all__ = ["DEFAULT_BUCKETS", "Request", "SchedulerCore", "ServeEngine",
           "ShardedServeEngine", "MultiHostServeEngine", "core", "engine"]
