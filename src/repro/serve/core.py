"""Scheduler core: the device-agnostic half of every ServeEngine.

The serving engines share one scheduler - request validation, the FIFO
pending queue, bucket grouping, per-replica free-slot deques with
least-loaded routing, slot/length accounting, and ``engine.stats`` - but
differ in WHERE the device programs run (one device, a single-process
('data', 'model') mesh, or a ``jax.distributed`` multi-process mesh).
This module expresses the scheduler as host-side PLANS so that split is
structural:

  * ``SchedulerCore`` builds plans (pure numpy: padded token batches,
    seq_lens, scatter maps, slot placements) and applies sampled results
    back to the queue/slot state.  It never touches a jax array.
  * an engine subclass implements three exec hooks, each consuming a plan
    and returning the sampled next token per pool row:

        _exec_prefill(plan, extras)   # one bucketed prefill + scatter
        _exec_chunked(plan, extras)   # a chunked-prefill launch sequence
        _exec_decode(plan)            # one batched decode step

Because a plan is plain numpy, it can also be SHIPPED: the multi-host
engine's coordinator broadcasts each plan's arrays to the worker
processes, which execute the same SPMD launches (serve/multihost.py) -
the scheduler itself keeps running as a host-side singleton on the
coordinator, exactly as it does on one process.

Dummy rows (pool rows a prefill batch does not fill) carry ``seq_lens ==
0``: every token of the row is masked out end to end - attention writes
clamp to index 0, the SSM recurrence skips all of them (dt = 0), and MoE
routing masks the whole row (moe.route token_mask), so a dummy row claims
NO expert-capacity slot.  (Until PR 5 dummy rows carried seq_lens == 1
and each routed one token through the MoE router, which could evict real
tokens' capacity slots at tight capacity factors.)
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any

import numpy as np

DEFAULT_BUCKETS = (32, 64, 128, 256)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 16
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class PrefillPlan:
    """One bucketed prefill launch spanning every replica: prompts
    right-padded to ``bucket``, replica r's admits in rows [r*spr, r*spr +
    n_r) of the fixed ``slots``-row batch; rows with seq_lens == 0 are
    dummies the scatter drops.  ``src_map`` carries replica-LOCAL source
    rows (identical to global rows when n_replicas == 1)."""
    bucket: int
    tokens: np.ndarray               # (slots, bucket) int32
    seq_lens: np.ndarray             # (slots,) int32; 0 = dummy row
    src_map: np.ndarray              # (slots,) int32; -1 = keep pool slot
    placed: list[tuple[int, int, Request]]   # (slot, batch row, request)
    per_counts: list[int]            # admits per replica
    real_tokens: int                 # prompt tokens (pads excluded)


@dataclasses.dataclass
class ChunkedPlan:
    """A chunked prefill of ONE oversized prompt: the first chunk runs as
    a normal bucketed prefill, later chunks continue against the
    accumulating rows, then the finished row lands via ``src_map``."""
    req: Request
    replica: int
    row: int                         # batch row carrying the prompt
    slot: int
    prompt_len: int
    first: tuple[int, np.ndarray, np.ndarray]      # (bucket, tokens, seq_lens)
    chunks: list[tuple[int, np.ndarray, np.ndarray, np.ndarray]]
    #          (bucket, tokens, seq_lens, start_lens)
    src_map: np.ndarray              # (slots,) int32


@dataclasses.dataclass
class DecodePlan:
    live: list[int]                  # slots with an active request
    tokens: np.ndarray               # (slots, 1) int32
    positions: np.ndarray            # (slots, 1) int32


class SchedulerCore:
    """Replica-aware admission/decode scheduler over a fixed slot pool.

    Subclasses must set up device state and implement the exec hooks; the
    driver methods here (``submit``/``run``/``step``) are shared by the
    single-device, sharded, and multi-host engines.
    """

    # ------------------------------------------------------------ state init
    def _init_scheduler(self, *, slots: int, n_replicas: int, max_len: int,
                        patch_tokens: int, buckets: tuple[int, ...],
                        batch_prefill: bool, chunked_prefill: bool) -> None:
        assert slots % n_replicas == 0, (slots, n_replicas)
        assert batch_prefill or n_replicas == 1, (
            "the legacy per-request prefill baseline is single-replica only")
        assert batch_prefill or not chunked_prefill, (
            "chunked prefill requires the bucketed batched-prefill path")
        self.slots = slots
        self.n_replicas = n_replicas
        self.slots_per_replica = slots // n_replicas
        self.max_len = max_len
        self.patch_tokens = patch_tokens
        self.batch_prefill = batch_prefill
        self.chunked_prefill = chunked_prefill
        self.lengths = np.zeros((slots,), np.int64)
        self.active: list[Request | None] = [None] * slots
        self.last_tokens = np.zeros((slots,), np.int64)
        self.finished: list[Request] = []   # completion order, appended O(1)
        # clamp buckets so prompt + patches + the first decode token always
        # fit the cache (a prompt filling the cache exactly would ring-wrap
        # the first decode write onto slot 0), dedupe and sort ascending;
        # _bucket() picks the smallest bucket >= prompt len.  Without
        # chunking the capacity limit always rides as the last bucket, so
        # any prompt the legacy per-request path served safely is still
        # servable (at most one extra executable); with chunking the
        # largest CONFIGURED bucket is the chunk size and longer prompts
        # (up to capacity) are split instead.
        limit = max_len - patch_tokens - 1
        if limit <= 0:
            raise ValueError(
                f"max_len ({max_len}) leaves no room for a prompt: need "
                f"patch_tokens ({patch_tokens}) + prompt + 1 decode slot")
        self._capacity = limit
        bset = {min(int(b), limit) for b in buckets if int(b) > 0}
        if not chunked_prefill:
            bset |= {limit}
        if not bset:
            raise ValueError("chunked prefill needs at least one bucket")
        self.buckets = tuple(sorted(bset))
        # admission scheduler state: FIFO pending queue + one free-slot
        # deque per replica (O(1) admit, no rescans of self.active; the
        # per-replica split is what least-loaded routing reads)
        self.pending: collections.deque[Request] = collections.deque()
        spr = self.slots_per_replica
        self._free_r: list[collections.deque[int]] = [
            collections.deque(range(r * spr, (r + 1) * spr))
            for r in range(n_replicas)]
        self.stats: dict[str, Any] = {
            "prefill_compiles": 0,     # distinct prefill executables traced
            "chunk_compiles": 0,       # distinct prefill_chunk executables
            "decode_compiles": 0,
            "prefill_batches": 0,      # prefill launches (bucketed: one per
                                       # bucket group; legacy: one per request)
            "chunk_batches": 0,        # prefill_chunk launches
            "prefill_requests": 0,     # requests admitted through prefill
            "chunked_requests": 0,     # ... of which needed chunking
            "prefill_tokens": 0,       # real prompt tokens prefetched
            "prefill_padded_tokens": 0,  # tokens actually executed (pads incl)
            "decode_steps": 0,
            "decode_tokens": 0,
            "completed": 0,
            # per-replica occupancy/admit accounting (single-replica engines
            # report one-element lists)
            "replica_admits": [0] * n_replicas,
            "replica_occupancy": [0] * n_replicas,
        }

    # ------------------------------------------------------------ exec hooks
    def _exec_prefill(self, plan: PrefillPlan, extras) -> np.ndarray:
        """Run ONE bucketed prefill + cache scatter; return the sampled
        next token per pool row (dummy rows' entries are ignored)."""
        raise NotImplementedError

    def _exec_chunked(self, plan: ChunkedPlan, extras) -> np.ndarray:
        raise NotImplementedError

    def _exec_decode(self, plan: DecodePlan) -> np.ndarray:
        raise NotImplementedError

    def _submit_one(self, req: Request, extras) -> bool:
        raise NotImplementedError(
            "the legacy per-request path is single-device only")

    # ----------------------------------------------------------------- admin
    def _bucket(self, prompt_len: int) -> int:
        if prompt_len <= 0:
            raise ValueError("empty prompt: nothing to prefill")
        for b in self.buckets:
            if prompt_len <= b:
                return b
        raise ValueError(
            f"prompt of {prompt_len} tokens exceeds the largest prefill "
            f"bucket {self.buckets[-1]} (max_len={self.max_len}, "
            f"patch_tokens={self.patch_tokens})")

    def _validate(self, prompt_len: int) -> None:
        """Reject empty/oversized prompts up front (before any dequeue)."""
        if self.chunked_prefill and prompt_len > self.buckets[-1]:
            if prompt_len > self._capacity:
                raise ValueError(
                    f"prompt of {prompt_len} tokens exceeds the cache "
                    f"capacity {self._capacity} (max_len={self.max_len}, "
                    f"patch_tokens={self.patch_tokens})")
            return
        self._bucket(prompt_len)

    def _validate_extras(self, prompt_len: int, extras) -> None:
        """Entry-point companion of _validate: reject unsupported extras
        combinations BEFORE anything is queued or any plan claims a slot
        (raising mid-admission would drop dequeued peers / leak slots).
        The multi-host engine overrides this to reject all extras."""
        if extras and self.chunked_prefill and prompt_len > self.buckets[-1]:
            raise NotImplementedError(
                "chunked prefill is text-only (no vision/encdec extras)")

    def _free_total(self) -> int:
        return sum(len(f) for f in self._free_r)

    def _take_slot(self, replica: int) -> int:
        slot = self._free_r[replica].popleft()
        self.stats["replica_occupancy"][replica] += 1
        return slot

    def _release_slot(self, slot: int) -> None:
        r = slot // self.slots_per_replica
        self._free_r[r].append(slot)
        self.stats["replica_occupancy"][r] -= 1

    def _assign(self, reqs: list[Request]) -> list[list[Request]]:
        """Route same-bucket admits to replicas, least-loaded first (most
        free slots net of this round's assignments; FIFO within the
        round).  Caller guarantees len(reqs) <= total free slots."""
        per: list[list[Request]] = [[] for _ in range(self.n_replicas)]
        for r in reqs:
            ri = max(range(self.n_replicas),
                     key=lambda i: (len(self._free_r[i]) - len(per[i]), -i))
            assert len(self._free_r[ri]) > len(per[ri]), "no free slot"
            per[ri].append(r)
        return per

    def _activate(self, slot: int, req: Request, prompt_len: int, tok: int):
        req.generated.append(tok)
        if len(req.generated) >= req.max_new:
            # prefill already produced the full budget: complete without
            # ever occupying a decode slot (max_new=1 = pure ingest)
            req.done = True
            self.finished.append(req)
            self._release_slot(slot)
            self.stats["completed"] += 1
            return
        self.active[slot] = req
        self.lengths[slot] = prompt_len + self.patch_tokens
        self.last_tokens[slot] = tok

    # ------------------------------------------------------- prefill planning
    def _plan_prefill(self, per: list[list[Request]], bucket: int) -> PrefillPlan:
        """Lay replica r's admits into rows [r*spr, r*spr + len(per[r]))
        of a fixed ``slots``-row batch and claim their slots.  Rows beyond
        a replica's admits are dummies: seq_lens == 0 masks every one of
        their tokens out of attention writes, the SSM recurrence and MoE
        routing, and src_map == -1 makes the scatter drop them."""
        spr = self.slots_per_replica
        n = sum(len(g) for g in per)
        assert 0 < n <= self._free_total()
        tokens = np.zeros((self.slots, bucket), np.int32)
        seq_lens = np.zeros((self.slots,), np.int32)     # dummy rows: 0
        src_map = np.full((self.slots,), -1, np.int32)
        placed: list[tuple[int, int, Request]] = []
        for ri, reqs in enumerate(per):
            for i, r in enumerate(reqs):
                S = len(r.prompt)
                tokens[ri * spr + i, :S] = r.prompt
                seq_lens[ri * spr + i] = S
                slot = self._take_slot(ri)
                src_map[slot] = i                        # replica-local row
                placed.append((slot, ri * spr + i, r))
        return PrefillPlan(bucket=bucket, tokens=tokens, seq_lens=seq_lens,
                           src_map=src_map, placed=placed,
                           per_counts=[len(g) for g in per],
                           real_tokens=int(seq_lens.sum()))

    def _apply_prefill(self, plan: PrefillPlan, nxt: np.ndarray) -> None:
        for ri, c in enumerate(plan.per_counts):
            self.stats["replica_admits"][ri] += c
        for slot, row, r in plan.placed:
            self._activate(slot, r, int(plan.seq_lens[row]), int(nxt[row]))
        self.stats["prefill_batches"] += 1
        self.stats["prefill_requests"] += len(plan.placed)
        self.stats["prefill_tokens"] += plan.real_tokens
        self.stats["prefill_padded_tokens"] += self.slots * plan.bucket

    def _plan_chunked(self, req: Request) -> ChunkedPlan:
        """Split ONE oversized prompt into bucket-sized chunks.  The
        prompt rides row 0 of the least-loaded replica's block; all other
        rows are dummies (seq_lens == 0)."""
        spr = self.slots_per_replica
        Bp = self.slots
        chunk = self.buckets[-1]
        S = len(req.prompt)
        ri = max(range(self.n_replicas),
                 key=lambda i: (len(self._free_r[i]), -i))
        row = ri * spr
        prompt = np.asarray(req.prompt)

        tokens = np.zeros((Bp, chunk), np.int32)
        seq_lens = np.zeros((Bp,), np.int32)
        tokens[row] = prompt[:chunk]
        seq_lens[row] = chunk
        first = (chunk, tokens, seq_lens)

        chunks: list[tuple[int, np.ndarray, np.ndarray, np.ndarray]] = []
        off = chunk
        while off < S:
            rem = min(chunk, S - off)
            b = self._bucket(rem)        # ragged last chunk pads to a bucket
            tokens = np.zeros((Bp, b), np.int32)
            seq_lens = np.zeros((Bp,), np.int32)
            start_lens = np.zeros((Bp,), np.int32)
            tokens[row, :rem] = prompt[off:off + rem]
            seq_lens[row] = rem
            start_lens[row] = off
            chunks.append((b, tokens, seq_lens, start_lens))
            off += rem

        slot = self._take_slot(ri)
        src_map = np.full((Bp,), -1, np.int32)
        src_map[slot] = 0                                 # replica-local row 0
        return ChunkedPlan(req=req, replica=ri, row=row, slot=slot,
                           prompt_len=S, first=first, chunks=chunks,
                           src_map=src_map)

    def _apply_chunked(self, plan: ChunkedPlan, nxt: np.ndarray) -> None:
        self.stats["prefill_batches"] += 1
        self.stats["chunk_batches"] += len(plan.chunks)
        self.stats["prefill_padded_tokens"] += self.slots * (
            plan.first[0] + sum(c[0] for c in plan.chunks))
        self.stats["replica_admits"][plan.replica] += 1
        self._activate(plan.slot, plan.req, plan.prompt_len,
                       int(nxt[plan.row]))
        self.stats["prefill_requests"] += 1
        self.stats["chunked_requests"] += 1
        self.stats["prefill_tokens"] += plan.prompt_len

    # ------------------------------------------------------------- admission
    def submit(self, req: Request, extras: dict[str, Any] | None = None) -> bool:
        """Admit the request into a free slot now; False if engine is full.

        On the bucketed path this may opportunistically co-admit queued
        same-bucket requests into the same prefill launch.
        """
        if not self._free_total():
            return False
        if not self.batch_prefill:
            return self._submit_one(req, extras)
        self._validate(len(req.prompt))  # validate before touching the queue
        self._validate_extras(len(req.prompt), extras)
        self.pending.appendleft(req)
        self._admit(extras)
        return True

    def _admit(self, extras=None) -> int:
        """Bucket-grouped admission: ONE pass over the pending queue assigns
        the first len(free) requests (FIFO) to per-bucket groups, then each
        group prefills in ONE batched call spanning every replica (groups
        launch in first-arrival order; a chunk-needing request flushes the
        groups gathered so far and runs its chunk sequence solo).
        O(pending) per admission call, not per batch.  Returns the number
        of requests admitted."""
        free = self._free_total()
        groups: dict[int, list[Request]] = {}
        order: list[int] = []
        admitted = 0

        def flush():
            for b in order:
                plan = self._plan_prefill(self._assign(groups[b]), b)
                self._apply_prefill(plan, self._exec_prefill(plan, extras))
            groups.clear()
            order.clear()

        while self.pending and admitted < free:   # consumes a queue prefix
            r = self.pending.popleft()
            S = len(r.prompt)
            if self.chunked_prefill and S > self.buckets[-1]:
                # extras were rejected at submit()/run() entry
                # (_validate_extras) - raising here would drop the
                # dequeued peers and leak the planned slot
                flush()                  # keep arrival order across launches
                plan = self._plan_chunked(r)
                self._apply_chunked(plan, self._exec_chunked(plan, extras))
                admitted += 1
                continue
            b = self._bucket(S)
            if b not in groups:
                groups[b] = []
                order.append(b)
            groups[b].append(r)
            admitted += 1
        flush()
        return admitted

    # ---------------------------------------------------------------- decode
    def _plan_decode(self) -> DecodePlan | None:
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return None
        return DecodePlan(live=live,
                          tokens=self.last_tokens[:, None].astype(np.int32),
                          positions=self.lengths[:, None].astype(np.int32))

    def _apply_decode(self, plan: DecodePlan, nxt: np.ndarray) -> None:
        self.stats["decode_steps"] += 1
        self.stats["decode_tokens"] += len(plan.live)
        for i in plan.live:
            req = self.active[i]
            req.generated.append(int(nxt[i]))
            self.lengths[i] += 1
            self.last_tokens[i] = int(nxt[i])
            if (len(req.generated) >= req.max_new
                    or self.lengths[i] >= self.max_len - 1):
                req.done = True
                self.finished.append(req)
                self.active[i] = None
                self._release_slot(i)   # slot freed for the next admission
                self.stats["completed"] += 1

    def step(self) -> int:
        """One batched decode step over all active slots; returns #active."""
        plan = self._plan_decode()
        if plan is None:
            return 0
        self._apply_decode(plan, self._exec_decode(plan))
        return len([r for r in self.active if r is not None])

    def run(self, requests: list[Request], extras=None) -> list[Request]:
        """Drain a request list through the engine (continuous batching).

        Admission is bucket-grouped and batched (``_admit``); completion is
        tracked incrementally: ``step`` appends each finished request to
        ``self.finished`` as its slot frees, so draining is O(1) per
        completion instead of rescanning the whole request list every
        decode step.
        """
        for r in requests:                 # validate upfront: an oversized
            self._validate(len(r.prompt))  # prompt must not dequeue peers
            self._validate_extras(len(r.prompt), extras)
        self.pending.extend(requests)
        n_active = sum(r is not None for r in self.active)   # pre-submitted
        while self.pending or n_active:
            if self.batch_prefill:
                self._admit(extras)
            else:
                while self.pending and self._free_total():
                    self._submit_one(self.pending.popleft(), extras)
            n_active = self.step()
        return requests
