"""Scheduler core: the device-agnostic half of every ServeEngine.

The serving engines share one scheduler - request validation, the FIFO
pending queue, bucket grouping, per-replica free-slot deques with
least-loaded routing, slot/length accounting, and ``engine.stats`` - but
differ in WHERE the device programs run (one device, a single-process
('data', 'model') mesh, or a ``jax.distributed`` multi-process mesh).
This module expresses the scheduler as host-side PLANS so that split is
structural:

  * ``SchedulerCore`` builds plans (pure numpy: padded token batches,
    seq_lens, scatter maps, slot placements) and applies sampled results
    back to the queue/slot state.  It never touches a jax array.
  * an engine subclass implements three exec hooks, each consuming a plan
    and returning the sampled next token per pool row:

        _exec_prefill(plan, extras)   # one bucketed prefill + scatter
        _exec_chunked(plan, extras)   # a chunked-prefill launch sequence
        _exec_decode(plan)            # one batched decode step

Because a plan is plain numpy, it can also be SHIPPED: the multi-host
engine's coordinator broadcasts each plan's arrays to the worker
processes, which execute the same SPMD launches (serve/multihost.py) -
the scheduler itself keeps running as a host-side singleton on the
coordinator, exactly as it does on one process.

Dummy rows (pool rows a prefill batch does not fill) carry ``seq_lens ==
0``: every token of the row is masked out end to end - attention writes
clamp to index 0, the SSM recurrence skips all of them (dt = 0), and MoE
routing masks the whole row (moe.route token_mask), so a dummy row claims
NO expert-capacity slot.  (Until PR 5 dummy rows carried seq_lens == 1
and each routed one token through the MoE router, which could evict real
tokens' capacity slots at tight capacity factors.)
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any

import numpy as np

from repro.distributed.fault import (FailureLog, FaultInjector,
                                     StragglerWatchdog, save_snapshot)

from . import telemetry as tmod
from .pages import PageError, PagePool, PrefixStore, pages_for

DEFAULT_BUCKETS = (32, 64, 128, 256)


class EngineDraining(RuntimeError):
    """``submit()``/``run()`` called after ``request_drain()``: the engine
    is stopping and accepts no new work (the service front door maps this
    to HTTP 503)."""


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 16
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    error: str | None = None         # set iff the request FAILED (isolated)
    # absolute deadline on the scheduler's clock (engine._clock, default
    # time.monotonic); None = no deadline.  Checked at round boundaries:
    # an expired request is evicted alone, peers untouched.
    deadline: float | None = None
    # how the request left the engine: 'complete' | 'failed' | 'cancel' |
    # 'deadline' | 'disconnect' | 'slow_consumer' | 'drain' (service-side)
    finish_reason: str | None = None
    # tokens already delivered to stream observers: a preempted request
    # regenerates its tokens bit-exactly ((uid, step) sampling keys), and
    # this watermark keeps ``_emit_token`` from delivering them twice
    emitted: int = 0
    # telemetry lifecycle stamps (time.perf_counter; None until reached):
    # TTFT = first_token_at - submitted_at, queue wait = admitted_at -
    # submitted_at, inter-token gaps stream off last_token_at.  Excluded
    # from snapshots - a resumed request re-times from scratch.
    submitted_at: float | None = None
    admitted_at: float | None = None
    first_token_at: float | None = None
    last_token_at: float | None = None


@dataclasses.dataclass
class PrefillPlan:
    """One bucketed prefill launch spanning every replica: prompts
    right-padded to ``bucket``, replica r's admits in rows [r*spr, r*spr +
    n_r) of the fixed ``slots``-row batch; rows with seq_lens == 0 are
    dummies the scatter drops.  ``src_map`` carries replica-LOCAL source
    rows (identical to global rows when n_replicas == 1)."""
    bucket: int
    tokens: np.ndarray               # (slots, bucket) int32
    seq_lens: np.ndarray             # (slots,) int32; 0 = dummy row
    src_map: np.ndarray              # (slots,) int32; -1 = keep pool slot
    placed: list[tuple[int, int, Request]]   # (slot, batch row, request)
    per_counts: list[int]            # admits per replica
    real_tokens: int                 # prompt tokens (pads excluded)
    row_uids: np.ndarray = None      # (slots,) int32; -1 = dummy row
    row_steps: np.ndarray = None     # (slots,) int32 token index; -1 = dummy
    # paged pool landing maps (None on slot-row engines): pool page p takes
    # page ``land_js[p]`` of replica-local scratch row ``land_rows[p]``;
    # -1 keeps the page (unallocated, or a shared prefix page)
    land_rows: np.ndarray = None     # (n_replicas * pool_pages,) int32
    land_js: np.ndarray = None       # (n_replicas * pool_pages,) int32
    share_ok: bool = False           # apply may register prefix pages


@dataclasses.dataclass
class ChunkedPlan:
    """A chunked prefill of one or more oversized prompts with the SAME
    chunk count (equal-length launch sequences co-batch into shared rows -
    solo chunking burned every dummy row's FLOPs): the first chunk runs as
    a normal bucketed prefill, later chunks continue against the
    accumulating rows, then the finished rows land via ``src_map``."""
    placed: list[tuple[int, int, Request]]   # (slot, batch row, request)
    per_counts: list[int]            # admits per replica
    real_tokens: int                 # prompt tokens (pads excluded)
    first: tuple[int, np.ndarray, np.ndarray]      # (bucket, tokens, seq_lens)
    chunks: list[tuple[int, np.ndarray, np.ndarray, np.ndarray]]
    #          (bucket, tokens, seq_lens, start_lens)
    src_map: np.ndarray              # (slots,) int32
    row_uids: np.ndarray = None      # (slots,) int32; -1 = dummy row
    row_steps: np.ndarray = None     # (slots,) int32; -1 = dummy row
    land_rows: np.ndarray = None     # (n_replicas * pool_pages,) int32
    land_js: np.ndarray = None       # (n_replicas * pool_pages,) int32
    share_ok: bool = False


@dataclasses.dataclass
class DecodePlan:
    live: list[int]                  # slots with an active request
    tokens: np.ndarray               # (slots, 1) int32
    positions: np.ndarray            # (slots, 1) int32
    row_uids: np.ndarray = None      # (slots,) int32; -1 = free slot
    row_steps: np.ndarray = None     # (slots,) int32; -1 = free slot
    # paged pool: per-slot page-table rows with replica-LOCAL page ids
    # (-1 beyond each row's allocation; free slots all -1)
    page_tables: np.ndarray = None   # (slots, n_pp) int32
    # multi-step decode: tokens each row consumes from this dispatch's
    # on-device block (min of the engine's decode_steps, the row's
    # remaining max_new budget, and its cache headroom; 0 = free slot)
    n_steps: np.ndarray = None       # (slots,) int32


class SchedulerCore:
    """Replica-aware admission/decode scheduler over a fixed slot pool.

    Subclasses must set up device state and implement the exec hooks; the
    driver methods here (``submit``/``run``/``step``) are shared by the
    single-device, sharded, and multi-host engines.
    """

    # ------------------------------------------------------------ state init
    # a launch exception fails the launch's requests and keeps serving;
    # the multi-host engine overrides this to False (a coordinator that
    # keeps scheduling after a desynced collective would hang the fleet -
    # it aborts and lets drain-and-resume requeue the work instead)
    _isolate_exec = True

    def _init_scheduler(self, *, slots: int, n_replicas: int, max_len: int,
                        patch_tokens: int, buckets: tuple[int, ...],
                        batch_prefill: bool, chunked_prefill: bool,
                        decode_steps: int = 1,
                        fault: FaultInjector | None = None,
                        tel: tmod.Telemetry | None = None) -> None:
        assert slots % n_replicas == 0, (slots, n_replicas)
        assert decode_steps >= 1, decode_steps
        assert batch_prefill or n_replicas == 1, (
            "the legacy per-request prefill baseline is single-replica only")
        assert batch_prefill or not chunked_prefill, (
            "chunked prefill requires the bucketed batched-prefill path")
        self.slots = slots
        self.n_replicas = n_replicas
        self.slots_per_replica = slots // n_replicas
        self.max_len = max_len
        # decode block size N: every decode dispatch runs N model steps
        # on-device (lax.scan) and backhauls an (slots, N) token block, so
        # host round-trips per token drop to 1/N.  Admission, deadline
        # sweeps, cancellation and stream flushes quantize to dispatch
        # boundaries; per-(uid, step) sampling keys keep N>1 output
        # token-for-token equal to N=1
        self.decode_steps = int(decode_steps)
        self.patch_tokens = patch_tokens
        self.batch_prefill = batch_prefill
        self.chunked_prefill = chunked_prefill
        self.lengths = np.zeros((slots,), np.int64)
        self.active: list[Request | None] = [None] * slots
        self.last_tokens = np.zeros((slots,), np.int64)
        self.finished: list[Request] = []   # completion order, appended O(1)
        # clamp buckets so prompt + patches + the first decode token always
        # fit the cache (a prompt filling the cache exactly would ring-wrap
        # the first decode write onto slot 0), dedupe and sort ascending;
        # _bucket() picks the smallest bucket >= prompt len.  Without
        # chunking the capacity limit always rides as the last bucket, so
        # any prompt the legacy per-request path served safely is still
        # servable (at most one extra executable); with chunking the
        # largest CONFIGURED bucket is the chunk size and longer prompts
        # (up to capacity) are split instead.
        limit = max_len - patch_tokens - 1
        if limit <= 0:
            raise ValueError(
                f"max_len ({max_len}) leaves no room for a prompt: need "
                f"patch_tokens ({patch_tokens}) + prompt + 1 decode slot")
        self._capacity = limit
        bset = {min(int(b), limit) for b in buckets if int(b) > 0}
        if not chunked_prefill:
            bset |= {limit}
        if not bset:
            raise ValueError("chunked prefill needs at least one bucket")
        self.buckets = tuple(sorted(bset))
        # admission scheduler state: FIFO pending queue + one free-slot
        # deque per replica (O(1) admit, no rescans of self.active; the
        # per-replica split is what least-loaded routing reads)
        self.pending: collections.deque[Request] = collections.deque()
        spr = self.slots_per_replica
        self._free_r: list[collections.deque[int]] = [
            collections.deque(range(r * spr, (r + 1) * spr))
            for r in range(n_replicas)]
        # fault-tolerance state: a no-op-by-default injector (tests thread
        # a FaultPlan injector through the engine kwarg), a straggler EMA
        # over decode launch times, a failure event log, the scheduler
        # round counter the injector keys off, and the drain flag that
        # preempts the run loop (SIGTERM / coordinator preemption)
        self.fault = fault if fault is not None else FaultInjector()
        self.fault.bind(self)
        self.straggler = StragglerWatchdog()
        # prefill/chunked launches get their OWN EMA: a bucketed prefill is
        # legitimately 10-100x a decode step, so sharing the decode EMA
        # would either flag every prefill or never flag a slow one
        self.prefill_straggler = StragglerWatchdog()
        self.failures = FailureLog()
        # telemetry plane (serve/telemetry.py): metrics registry + tracer;
        # engines thread enabled/trace through from ServeConfig
        self.tel = tel if tel is not None else tmod.Telemetry()
        # guards stats_snapshot()/events_snapshot() against the serving
        # loop thread mutating while an HTTP scrape serializes
        self.stats_lock = threading.Lock()
        self.snapshot_path: str | None = None
        self._round = 0
        self._draining = False
        self._inflight: list[Request] = []   # claimed by an unapplied plan
        # deadline clock: overridable so tests pin expiry to scheduler
        # rounds (e.g. ``eng._clock = lambda: float(eng._round)``) instead
        # of wall time - deterministic on every engine including multihost
        self._clock = time.monotonic
        # uids cancelled while claimed by an in-flight plan: the apply
        # handler releases the slot instead of activating (kind, reason)
        self._cancelled: dict[int, tuple[str, str]] = {}
        # token/finish observers for the streaming service (serve/service):
        # on_token(req, tok) fires for every token the engine produces, in
        # order, ON the scheduler thread; on_finish(req) fires exactly once
        # when a request leaves the engine (complete or failed/evicted)
        self.on_token = None
        self.on_finish = None
        # paged-pool defaults: engines opt in via _init_paging() AFTER this
        self.paged = False
        self.page_pools: list[PagePool] = []
        self._slot_uids: list[int | None] = [None] * slots
        self._spilled: dict[int, Any] = {}      # uid -> SpillRecord
        self.stats: dict[str, Any] = {
            "prefill_compiles": 0,     # distinct prefill executables traced
            "chunk_compiles": 0,       # distinct prefill_chunk executables
            "decode_compiles": 0,
            "prefill_batches": 0,      # prefill launches (bucketed: one per
                                       # bucket group; legacy: one per request)
            "chunk_batches": 0,        # prefill_chunk launches
            "prefill_requests": 0,     # requests admitted through prefill
            "chunked_requests": 0,     # ... of which needed chunking
            "prefill_tokens": 0,       # real prompt tokens prefetched
            "prefill_padded_tokens": 0,  # tokens actually executed (pads incl)
            "decode_steps": 0,
            "decode_tokens": 0,
            "completed": 0,
            "failed": 0,               # requests failed + evicted (isolated)
            "cancelled": 0,            # client cancel / disconnect evictions
            "deadline_expired": 0,     # per-request deadline evictions
            "shed": 0,                 # admissions refused at the watermark
                                       # (service front door: HTTP 429)
            "straggler_flags": 0,      # decode rounds flagged slow (EMA)
            "prefill_straggler_flags": 0,   # prefill/chunk launches flagged
            "pdq_fallbacks": 0,        # guarded-projection fp fallbacks fired
            "pdq_clip_hits": 0,        # int8 outputs saturated at clip edges
            "pdq_clip_total": 0,       # int8 outputs checked
            # per-replica occupancy/admit accounting (single-replica engines
            # report one-element lists)
            "replica_admits": [0] * n_replicas,
            "replica_occupancy": [0] * n_replicas,
        }

    def _init_paging(self, *, page_size: int, pool_pages: int, n_pp: int,
                     prefix_sharing: bool = True, spill: bool = False) -> None:
        """Turn the slot pool into a paged pool: one ``PagePool`` allocator
        (+ ``PrefixStore``) per replica, driven entirely at plan time - the
        device side consumes page tables and land maps shipped inside the
        plans.  ``pool_pages`` is per replica and INCLUDES the dump page;
        ``pool_pages >= n_pp + 1`` (asserted by PagePool) guarantees a
        sole live request can always grow to max_len, which is what makes
        the preemption loop terminate."""
        self.paged = True
        self.page_size = int(page_size)
        self.n_pp = int(n_pp)
        self.pool_pages = int(pool_pages)
        # sharing keys on token prefixes; patch tokens (vision) shift every
        # position, and per-request extras change cache content - disable
        self.prefix_sharing = bool(prefix_sharing) and self.patch_tokens == 0
        self.spill_enabled = bool(spill)
        self.page_pools = [PagePool(pool_pages, n_pp, page_size)
                           for _ in range(self.n_replicas)]
        self.prefix_stores = [PrefixStore(page_size)
                              for _ in range(self.n_replicas)]
        for pool, store in zip(self.page_pools, self.prefix_stores):
            pool.on_free = store.drop_page
        if self.tel.enabled:
            cow = self.tel.metrics.counter(
                "serve_cow_copies_total",
                "shared frontier pages broken by copy-on-write")
            for ri, pool in enumerate(self.page_pools):
                pool.on_cow = (lambda uid, src, dst, _r=ri, _c=cow:
                               _c.inc())
        self._slot_seq = [0] * self.slots    # activation order (preempt LIFO)
        self._act_seq = 0
        self._shared_k: dict[int, int] = {}  # uid -> shared prefix pages
        self.stats.update(
            pages_total=(pool_pages - 1) * self.n_replicas,
            pages_used=0, preemptions=0, spills=0, spill_restores=0,
            prefix_hits=0, prefix_shared_pages=0, cow_copies=0)

    def _refresh_page_stats(self) -> None:
        if not self.paged:
            return
        self.stats["pages_used"] = sum(p.used_pages() for p in self.page_pools)
        self.stats["cow_copies"] = sum(p.stats["cow_copies"]
                                       for p in self.page_pools)
        self.stats["prefix_hits"] = sum(s.stats["prefix_hits"]
                                        for s in self.prefix_stores)
        self.stats["prefix_shared_pages"] = sum(
            s.stats["prefix_shared_pages"] for s in self.prefix_stores)

    # ------------------------------------------------------- telemetry taps
    def stats_snapshot(self) -> dict[str, Any]:
        """Deep-enough copy of ``stats`` taken under ``stats_lock``: the
        HTTP scrape thread serializes THIS, never the live dict the
        serving loop mutates (lists included - ``list(v)`` of a list being
        resized concurrently is the old /v1/stats race)."""
        with self.stats_lock:
            return {k: (list(v) if isinstance(v, list) else v)
                    for k, v in self.stats.items()}

    def events_snapshot(self) -> list[dict]:
        """Copy of the structured event ring (failures, evictions,
        preemptions, stragglers) for ``GET /v1/events``."""
        with self.stats_lock:
            return [dict(e) for e in self.failures.events]

    def _observe_pdq(self, tel_sum) -> None:
        """Fold one launch's device-side [fallbacks, clip_hits, clip_total]
        summary (rode the token gather as host numpy) into stats + the
        metrics registry."""
        if tel_sum is None or not self.tel.enabled:
            return
        fb, hits, total = (float(x) for x in np.asarray(tel_sum).reshape(-1)[:3])
        with self.stats_lock:
            self.stats["pdq_fallbacks"] += int(round(fb))
            self.stats["pdq_clip_hits"] += int(round(hits))
            self.stats["pdq_clip_total"] += int(round(total))
        self.tel.observe_pdq(fb, hits, total)

    # ------------------------------------------------------------ exec hooks
    def _exec_prefill(self, plan: PrefillPlan, extras):
        """Run ONE bucketed prefill + cache scatter; return ``(nxt, ok)``:
        the sampled next token per pool row and a per-row finite flag
        (False = that row's logits carried NaN/Inf and the request must be
        failed without touching its batch peers).  Dummy rows' entries are
        ignored."""
        raise NotImplementedError

    def _exec_chunked(self, plan: ChunkedPlan, extras):
        raise NotImplementedError

    def _exec_decode(self, plan: DecodePlan):
        raise NotImplementedError

    def _submit_one(self, req: Request, extras) -> bool:
        raise NotImplementedError(
            "the legacy per-request path is single-device only")

    # paged-pool hooks (engines with paged=True implement these)
    def _exec_page_copy(self, replica: int, pairs) -> None:
        """Device copy of pool pages [(src, dst), ...] on one replica (the
        COW arm of ``PagePool.ensure_writable``)."""
        raise NotImplementedError

    def _exec_spill(self, slot: int, uid: int, page_ids):
        """Capture a preempted request's pages + flat rows to host memory;
        returns a ``pages.SpillRecord`` (warm resume) or raises."""
        raise NotImplementedError

    def _exec_restore(self, slot: int, rec, page_ids) -> None:
        """Scatter a SpillRecord back into freshly allocated pages + the
        claimed slot's flat rows."""
        raise NotImplementedError

    def _fleet_abort(self, e: BaseException) -> None:
        """A non-isolated scheduling error killed the driver loop: engines
        with peers to release override this (multi-host broadcasts
        CMD_ABORT + snapshots).  Single-process engines have nothing to do."""

    def poll_ingress(self) -> list[Request]:
        """Requests submitted OUTSIDE this process (multi-host workers
        forward their local submits to the coordinator; see
        multihost.submit_remote).  Single-process engines have none."""
        return []

    # --------------------------------------------------- stream observers
    def _emit_token(self, req: Request, tok: int) -> None:
        idx = len(req.generated) - 1
        if idx < req.emitted:
            return      # preempt-regenerated token: already delivered
        req.emitted = idx + 1
        if self.tel.enabled:
            now = time.perf_counter()
            if req.first_token_at is None:
                req.first_token_at = now
                if req.submitted_at is not None:
                    self.tel.ttft.observe(now - req.submitted_at)
            elif req.last_token_at is not None:
                self.tel.per_token.observe(now - req.last_token_at)
            req.last_token_at = now
        if self.on_token is not None:
            self.on_token(req, tok)

    def _emit_finish(self, req: Request) -> None:
        tr = self.tel.tracer
        if tr.enabled and req.submitted_at is not None:
            # the request's lifecycle lands as two spans on the request
            # row: queued (submit -> admit) and active (admit -> finish)
            t0 = tr.to_us(req.submitted_at)
            t1 = tr.to_us(req.admitted_at) if req.admitted_at else tr.now_us()
            tr.add(f"req {req.uid} queued", cat="request", ts=t0,
                   dur=t1 - t0, tid=tmod.TID_REQUEST, args={"uid": req.uid})
            tr.add(f"req {req.uid} {req.finish_reason or 'active'}",
                   cat="request", ts=t1, dur=tr.now_us() - t1,
                   tid=tmod.TID_REQUEST,
                   args={"uid": req.uid, "tokens": len(req.generated),
                         "reason": req.finish_reason or ""})
        if self.on_finish is not None:
            self.on_finish(req)

    # ------------------------------------------------------ request failure
    def _fail(self, req: Request, err: str, kind: str) -> None:
        """Fail ONE request in place: mark done with an error, surface it
        through ``finished`` (so ``run`` drains normally) and the failure
        log.  The caller releases any claimed slot."""
        req.done = True
        req.error = str(err)
        req.finish_reason = kind if kind in (
            "cancel", "deadline", "disconnect", "slow_consumer") else "failed"
        self._spilled.pop(req.uid, None)    # drop any host-spilled pages
        self.finished.append(req)
        self.stats["failed"] += 1
        self.failures.record(self._round, kind, f"uid={req.uid}: {err}")
        self._emit_finish(req)

    # -------------------------------------------------------- cancellation
    def cancel(self, uid: int, *, kind: str = "cancel",
               reason: str = "cancelled by client") -> bool:
        """First-class cancellation: drop a pending request, or evict an
        in-flight one through the PR-6 ``_fail``/release path (per-slot
        cache state and (uid, step) sampling keys keep peers bit-exact).
        A uid claimed by an unapplied plan (e.g. mid-chunked-prefill) is
        marked and reclaimed when the launch's result applies - within the
        same round.  Cancelling an already-finished or unknown uid is a
        no-op returning False."""
        for r in self.pending:
            if r.uid == uid:
                self.pending.remove(r)
                self._count_cancel(kind)
                self._fail(r, reason, kind)
                return True
        for r in self._inflight:
            if r.uid == uid and not r.done:
                self._cancelled[uid] = (kind, reason)
                return True
        for slot, r in enumerate(self.active):
            if r is not None and r.uid == uid:
                self.active[slot] = None
                self._release_slot(slot)
                self._count_cancel(kind)
                self._fail(r, reason, kind)
                return True
        return False

    def _count_cancel(self, kind: str) -> None:
        self.stats["deadline_expired" if kind == "deadline"
                   else "cancelled"] += 1

    def _take_cancel(self, req: Request, slot: int) -> bool:
        """Apply-time arm of ``cancel``: if the uid was cancelled while its
        plan was in flight, release the claimed slot instead of activating."""
        ck = self._cancelled.pop(req.uid, None)
        if ck is None:
            return False
        self._release_slot(slot)
        self._count_cancel(ck[0])
        self._fail(req, ck[1], ck[0])
        return True

    def _expire_deadlines(self) -> int:
        """Round-boundary sweep: evict every pending/active request whose
        deadline passed on the engine clock.  Each eviction is isolated
        (same path as ``cancel``); returns the number evicted."""
        now = self._clock()
        n = 0
        for r in [r for r in self.pending
                  if r.deadline is not None and now >= r.deadline]:
            self.pending.remove(r)
            self._count_cancel("deadline")
            self._fail(r, f"deadline expired before admission "
                          f"(deadline={r.deadline:g})", "deadline")
            n += 1
        for slot, r in enumerate(self.active):
            if r is not None and r.deadline is not None and now >= r.deadline:
                self.active[slot] = None
                self._release_slot(slot)
                self._count_cancel("deadline")
                self._fail(r, f"deadline expired after {len(r.generated)} "
                              f"tokens (deadline={r.deadline:g})", "deadline")
                n += 1
        return n

    def _check_prompt(self, req: Request) -> None:
        """Structural validation at dequeue time: a malformed prompt must
        fail ALONE (raising inside ``_plan_prefill`` would poison the
        whole admission group)."""
        p = np.asarray(req.prompt)
        if p.ndim != 1 or p.size == 0 or not np.issubdtype(p.dtype, np.integer):
            raise ValueError(
                f"malformed prompt: shape {p.shape}, dtype {p.dtype} "
                "(need a non-empty 1-D integer array)")

    def _abort_launch(self, kind: str, slots_reqs, e: Exception) -> None:
        """A device launch raised: fail every request it carried, release
        their slots, keep the engine serving (request isolation)."""
        for slot, req in slots_reqs:
            if slot is not None:
                if self.active[slot] is req:
                    self.active[slot] = None
                self._release_slot(slot)
            self._fail(req, f"{kind} launch failed: {e!r}", "exec")
        self._inflight = []

    # ------------------------------------------------------- drain control
    def request_drain(self) -> None:
        """Stop scheduling at the next round boundary (SIGTERM handler /
        coordinator preemption); ``snapshot()`` then carries the queue and
        the in-flight work so a restarted engine can requeue it."""
        self._draining = True

    @property
    def drained(self) -> bool:
        return self._draining

    # ----------------------------------------------------------- snapshots
    def snapshot(self) -> dict:
        """The scheduler's drain record: a pure-numpy/python dict (shippable
        via ``distributed.fault.save_snapshot``) of finished, in-flight and
        pending requests plus counters.  In-flight covers both activated
        slots and requests claimed by a plan whose result never applied
        (e.g. the deadline watchdog fired mid-collective: host scheduler
        state is still consistent, the launch simply never landed)."""
        seen: set[int] = set()

        def pack(r: Request) -> dict:
            seen.add(id(r))
            return {"uid": int(r.uid), "prompt": np.asarray(r.prompt),
                    "max_new": int(r.max_new),
                    "generated": [int(t) for t in r.generated],
                    "error": r.error, "finish_reason": r.finish_reason}

        inflight = [pack(self.active[s]) for s in range(self.slots)
                    if self.active[s] is not None]
        inflight += [pack(r) for r in self._inflight if id(r) not in seen]
        return {
            "version": 1,
            "round": int(self._round),
            "inflight": inflight,
            "pending": [pack(r) for r in self.pending],
            "finished": [pack(r) for r in self.finished],
            "stats": {k: (list(v) if isinstance(v, list) else int(v))
                      for k, v in self.stats.items()},
            "failures": list(self.failures.events),
        }

    # ----------------------------------------------------------------- admin
    def _bucket(self, prompt_len: int) -> int:
        if prompt_len <= 0:
            raise ValueError("empty prompt: nothing to prefill")
        for b in self.buckets:
            if prompt_len <= b:
                return b
        raise ValueError(
            f"prompt of {prompt_len} tokens exceeds the largest prefill "
            f"bucket {self.buckets[-1]} (max_len={self.max_len}, "
            f"patch_tokens={self.patch_tokens})")

    def _validate(self, prompt_len: int) -> None:
        """Reject empty/oversized prompts up front (before any dequeue)."""
        if self.chunked_prefill and prompt_len > self.buckets[-1]:
            if prompt_len > self._capacity:
                raise ValueError(
                    f"prompt of {prompt_len} tokens exceeds the cache "
                    f"capacity {self._capacity} (max_len={self.max_len}, "
                    f"patch_tokens={self.patch_tokens})")
            return
        self._bucket(prompt_len)

    def _validate_extras(self, prompt_len: int, extras) -> None:
        """Entry-point companion of _validate: reject unsupported extras
        combinations BEFORE anything is queued or any plan claims a slot
        (raising mid-admission would drop dequeued peers / leak slots).
        The multi-host engine overrides this to reject all extras."""
        if extras and self.chunked_prefill and prompt_len > self.buckets[-1]:
            raise NotImplementedError(
                "chunked prefill is text-only (no vision/encdec extras)")

    def _free_total(self) -> int:
        return sum(len(f) for f in self._free_r)

    def _take_slot(self, replica: int) -> int:
        slot = self._free_r[replica].popleft()
        self.stats["replica_occupancy"][replica] += 1
        return slot

    def _release_slot(self, slot: int) -> None:
        r = slot // self.slots_per_replica
        self._free_r[r].append(slot)
        self.stats["replica_occupancy"][r] -= 1
        if self.paged:
            # THE page-freeing choke point: every slot-release path
            # (complete, fail, cancel, deadline, preempt) funnels here
            uid = self._slot_uids[slot]
            if uid is not None:
                self.page_pools[r].release(uid)
                self._shared_k.pop(uid, None)
                self._slot_uids[slot] = None

    def _assign(self, reqs: list[Request]) -> list[list[Request]]:
        """Route same-bucket admits to replicas, least-loaded first (most
        free slots net of this round's assignments; FIFO within the
        round).  Caller guarantees len(reqs) <= total free slots."""
        per: list[list[Request]] = [[] for _ in range(self.n_replicas)]
        for r in reqs:
            ri = max(range(self.n_replicas),
                     key=lambda i: (len(self._free_r[i]) - len(per[i]), -i))
            assert len(self._free_r[ri]) > len(per[ri]), "no free slot"
            per[ri].append(r)
        return per

    def _complete(self, req: Request) -> None:
        req.done = True
        req.finish_reason = "complete"
        self.finished.append(req)
        self.stats["completed"] += 1
        self._emit_finish(req)

    def _activate(self, slot: int, req: Request, prompt_len: int, tok: int):
        req.generated.append(tok)
        self._emit_token(req, tok)
        if len(req.generated) >= req.max_new:
            # prefill already produced the full budget: complete without
            # ever occupying a decode slot (max_new=1 = pure ingest)
            self._release_slot(slot)
            self._complete(req)
            return
        self.active[slot] = req
        self.lengths[slot] = prompt_len + self.patch_tokens
        self.last_tokens[slot] = tok

    # ------------------------------------------------------- prefill planning
    def _plan_prefill(self, per: list[list[Request]], bucket: int) -> PrefillPlan:
        """Lay replica r's admits into rows [r*spr, r*spr + len(per[r]))
        of a fixed ``slots``-row batch and claim their slots.  Rows beyond
        a replica's admits are dummies: seq_lens == 0 masks every one of
        their tokens out of attention writes, the SSM recurrence and MoE
        routing, and src_map == -1 makes the scatter drop them."""
        spr = self.slots_per_replica
        n = sum(len(g) for g in per)
        assert 0 < n <= self._free_total()
        tokens = np.zeros((self.slots, bucket), np.int32)
        seq_lens = np.zeros((self.slots,), np.int32)     # dummy rows: 0
        src_map = np.full((self.slots,), -1, np.int32)
        row_uids = np.full((self.slots,), -1, np.int32)
        row_steps = np.full((self.slots,), -1, np.int32)
        placed: list[tuple[int, int, Request]] = []
        for ri, reqs in enumerate(per):
            for i, r in enumerate(reqs):
                S = len(r.prompt)
                tokens[ri * spr + i, :S] = r.prompt
                seq_lens[ri * spr + i] = S
                row_uids[ri * spr + i] = r.uid
                row_steps[ri * spr + i] = len(r.generated)
                slot = self._take_slot(ri)
                src_map[slot] = i                        # replica-local row
                self._bind_slot(slot, r)
                placed.append((slot, ri * spr + i, r))
        land_rows, land_js = self._land_maps(placed, src_map)
        return PrefillPlan(bucket=bucket, tokens=tokens, seq_lens=seq_lens,
                           src_map=src_map, placed=placed,
                           per_counts=[len(g) for g in per],
                           real_tokens=int(seq_lens.sum()),
                           row_uids=row_uids, row_steps=row_steps,
                           land_rows=land_rows, land_js=land_js)

    def _bind_slot(self, slot: int, req: Request) -> None:
        """Bind the placed request's uid to its slot (page freeing rides
        ``_release_slot``) and stamp the activation sequence the
        preemption policy orders victims by (youngest first)."""
        if not self.paged:
            return
        self._slot_uids[slot] = req.uid
        self._act_seq += 1
        self._slot_seq[slot] = self._act_seq

    def _land_maps(self, placed, src_map):
        """Landing maps for a prefill/chunked plan: pool page p (replica-
        local id, laid out per replica block) takes page ``land_js[p]`` of
        replica-local scratch row ``land_rows[p]``.  ALL allocated pages
        land - including the tail beyond the prompt, whose scratch content
        is the pristine init fill, bit-exactly the never-written region of
        a slot-row cache.  Shared prefix pages are excluded (their content
        is already in the pool; first writer landed it)."""
        if not self.paged:
            return None, None
        spr = self.slots_per_replica
        N = self.pool_pages * self.n_replicas
        land_rows = np.full((N,), -1, np.int32)
        land_js = np.zeros((N,), np.int32)
        for slot, _, r in placed:
            ri = slot // spr
            base = ri * self.pool_pages
            row = int(src_map[slot])                     # local scratch row
            k = self._shared_k.get(r.uid, 0)
            for j, p in enumerate(self.page_pools[ri].pages(r.uid)):
                if j < k:
                    continue                             # shared prefix page
                land_rows[base + p] = row
                land_js[base + p] = j
        return land_rows, land_js

    def _register_prefix(self, plan, slot: int, req: Request) -> None:
        """Publish the landed prompt's full pages for COW sharing.  Since
        ``_claim_pages`` registers eagerly (intra-round sharing) this is
        normally a first-writer-wins no-op; it remains as the apply-time
        backstop so a prompt claimed with sharing disabled for the round
        (``plan.share_ok`` echoes the flush-time gate) never publishes,
        and because release fires ``on_free`` the store never outlives
        the pages either way."""
        if not (self.paged and plan.share_ok):
            return
        ri = slot // self.slots_per_replica
        self.prefix_stores[ri].register(
            np.asarray(req.prompt), self.page_pools[ri].pages(req.uid))

    def _claim_pages(self, ri: int, req: Request, extras) -> bool:
        """Claim this request's prompt pages on replica ``ri`` at PLAN
        time: longest registered prefix is aliased read-only (refcounted),
        the rest allocated fresh.  On PageError nothing is held (alloc is
        side-effect free + release drops the shared refs) and the caller
        defers the request instead of admitting it.

        A successful claim registers its own full pages IMMEDIATELY
        (first-writer-wins, so apply-time re-registration is a no-op):
        duplicates admitted in the SAME round - even the same launch -
        share pages instead of landing fresh copies.  Same-launch sharing
        is sound because the first writer's land maps cover the shared
        pages within that launch (``_land_maps`` skips only the SHARER's
        ``j < k`` entries), and an early entry never outlives its pages:
        if the claimer's launch aborts or its row is evicted, releasing
        the pages fires ``on_free`` and the store forgets them - unless a
        sharer still holds a reference, in which case the landed content
        (identical for identical prompts) is exactly what the sharer
        needs."""
        pool = self.page_pools[ri]
        need = pages_for(len(req.prompt) + self.patch_tokens, self.page_size)
        share = self.prefix_sharing and not extras
        k, shared = ((0, []) if not share
                     else self.prefix_stores[ri].lookup(np.asarray(req.prompt)))
        pool.attach(req.uid)
        pool.share(req.uid, shared)
        try:
            pool.alloc(req.uid, need - k)
        except PageError:
            pool.release(req.uid)
            return False
        if k:
            self._shared_k[req.uid] = k
        if share:
            self.prefix_stores[ri].register(np.asarray(req.prompt),
                                            pool.pages(req.uid))
        return True

    def _claim_per(self, per: list[list[Request]], extras):
        """Page-claim filter over an assigned admission group: requests
        whose pages do not fit are pushed BACK to the queue front (FIFO
        preserved) and retried next round - decode completions and
        preemptions free pages between rounds."""
        kept: list[list[Request]] = []
        deferred: list[Request] = []
        for ri, group in enumerate(per):
            kept.append([])
            for r in group:
                if self._claim_pages(ri, r, extras):
                    kept[ri].append(r)
                else:
                    deferred.append(r)
        for r in reversed(deferred):
            self.pending.appendleft(r)
        return kept, len(deferred)

    def _apply_prefill(self, plan: PrefillPlan, res) -> None:
        nxt, ok = res
        for ri, c in enumerate(plan.per_counts):
            self.stats["replica_admits"][ri] += c
        for slot, row, r in plan.placed:
            if self._take_cancel(r, slot):
                continue
            if not ok[row]:
                # poisoned row: fail + evict THIS request only; peers'
                # rows are untouched (per-slot attention/cache state)
                self._release_slot(slot)
                self._fail(r, "non-finite logits at prefill", "nonfinite")
                continue
            self._register_prefix(plan, slot, r)
            self._activate(slot, r, int(plan.seq_lens[row]), int(nxt[row]))
        self._inflight = []
        self.stats["prefill_batches"] += 1
        self.stats["prefill_requests"] += len(plan.placed)
        self.stats["prefill_tokens"] += plan.real_tokens
        self.stats["prefill_padded_tokens"] += self.slots * plan.bucket

    def _plan_chunked(self, reqs: list[Request],
                      per: list[list[Request]] | None = None) -> ChunkedPlan:
        """Split oversized prompts with EQUAL chunk counts into one shared
        launch sequence.  Each prompt rides its own row of the replica
        blocks (least-loaded routing, like ``_plan_prefill``); every chunk
        j < last is a full ``buckets[-1]`` window for every request, and
        the ragged last chunks pad together to one shared bucket.  Rows no
        request fills stay dummies (seq_lens == 0) - co-batching is what
        reclaims their FLOPs vs the old one-prompt-per-sequence planning."""
        spr = self.slots_per_replica
        Bp = self.slots
        chunk = self.buckets[-1]
        if per is None:
            per = self._assign(reqs)
        else:
            reqs = [r for g in per for r in g]
        n_chunks = -(-len(reqs[0].prompt) // chunk)
        assert all(-(-len(r.prompt) // chunk) == n_chunks for r in reqs)

        rows: list[tuple[int, np.ndarray]] = []   # (row, prompt) per request
        src_map = np.full((Bp,), -1, np.int32)
        row_uids = np.full((Bp,), -1, np.int32)
        row_steps = np.full((Bp,), -1, np.int32)
        placed: list[tuple[int, int, Request]] = []
        for ri, group in enumerate(per):
            for i, r in enumerate(group):
                row = ri * spr + i
                rows.append((row, np.asarray(r.prompt)))
                row_uids[row] = r.uid
                row_steps[row] = len(r.generated)
                slot = self._take_slot(ri)
                src_map[slot] = i                        # replica-local row
                self._bind_slot(slot, r)
                placed.append((slot, row, r))
        land_rows, land_js = self._land_maps(placed, src_map)

        # first chunk: with n_chunks >= 2 every prompt fills a whole window
        tokens = np.zeros((Bp, chunk), np.int32)
        seq_lens = np.zeros((Bp,), np.int32)
        for row, prompt in rows:
            tokens[row] = prompt[:chunk]
            seq_lens[row] = chunk
        first = (chunk, tokens, seq_lens)

        chunks: list[tuple[int, np.ndarray, np.ndarray, np.ndarray]] = []
        for j in range(1, n_chunks):
            off = j * chunk
            rems = [min(chunk, p.size - off) for _, p in rows]
            b = chunk if j < n_chunks - 1 else self._bucket(max(rems))
            tokens = np.zeros((Bp, b), np.int32)
            seq_lens = np.zeros((Bp,), np.int32)
            start_lens = np.zeros((Bp,), np.int32)
            for (row, prompt), rem in zip(rows, rems):
                tokens[row, :rem] = prompt[off:off + rem]
                seq_lens[row] = rem
                start_lens[row] = off
            chunks.append((b, tokens, seq_lens, start_lens))

        return ChunkedPlan(placed=placed, per_counts=[len(g) for g in per],
                           real_tokens=sum(p.size for _, p in rows),
                           first=first, chunks=chunks, src_map=src_map,
                           row_uids=row_uids, row_steps=row_steps,
                           land_rows=land_rows, land_js=land_js)

    def _apply_chunked(self, plan: ChunkedPlan, res) -> None:
        nxt, ok = res
        self.stats["prefill_batches"] += 1
        self.stats["chunk_batches"] += len(plan.chunks)
        self.stats["prefill_padded_tokens"] += self.slots * (
            plan.first[0] + sum(c[0] for c in plan.chunks))
        for ri, c in enumerate(plan.per_counts):
            self.stats["replica_admits"][ri] += c
        for slot, row, r in plan.placed:
            if self._take_cancel(r, slot):
                continue
            if not ok[row]:
                self._release_slot(slot)
                self._fail(r, "non-finite logits at chunked prefill",
                           "nonfinite")
                continue
            self._register_prefix(plan, slot, r)
            self._activate(slot, r, len(r.prompt), int(nxt[row]))
        self._inflight = []
        self.stats["prefill_requests"] += len(plan.placed)
        self.stats["chunked_requests"] += len(plan.placed)
        self.stats["prefill_tokens"] += plan.real_tokens

    # ------------------------------------------------------------- admission
    def submit(self, req: Request, extras: dict[str, Any] | None = None) -> bool:
        """Admit the request into a free slot now; False if engine is full.

        On the bucketed path this may opportunistically co-admit queued
        same-bucket requests into the same prefill launch.
        """
        if self._draining:
            raise EngineDraining(
                "engine is draining (request_drain() was called): new "
                "submissions are rejected; resume from the snapshot")
        if not self._free_total():
            return False
        if not self.batch_prefill:
            return self._submit_one(req, extras)
        self._validate(len(req.prompt))  # validate before touching the queue
        self._validate_extras(len(req.prompt), extras)
        if self.tel.enabled and req.submitted_at is None:
            req.submitted_at = time.perf_counter()
        self.pending.appendleft(req)
        self._admit(extras)
        return True

    def _admit(self, extras=None) -> int:
        """Bucket-grouped admission: ONE pass over the pending queue assigns
        the first len(free) requests (FIFO) to per-bucket groups, then each
        group prefills in ONE batched call spanning every replica (groups
        launch in first-arrival order).  Chunk-needing requests group by
        CHUNK COUNT the same way: equal-count prompts co-batch into one
        shared chunk sequence instead of each burning a whole
        dummy-row-padded launch sequence alone.  O(pending) per admission
        call, not per batch.  Returns the number of requests admitted."""
        free = self._free_total()
        groups: dict[tuple, list[Request]] = {}
        order: list[tuple] = []
        admitted = 0

        def launch(kind, plan, slots_reqs, exec_fn, apply_fn):
            # request isolation around ONE device launch: the fault hook
            # runs inside the guard (an injected launch fault exercises
            # the same path a real device error takes), and an exception
            # fails the launch's requests without taking the engine down
            self._inflight = [r for _, r in slots_reqs]
            if self.tel.enabled:
                now = time.perf_counter()
                for _, r in slots_reqs:
                    if r.admitted_at is None:
                        r.admitted_at = now
                        if r.submitted_at is not None:
                            self.tel.queue_wait.observe(now - r.submitted_at)
            t0 = time.perf_counter()
            try:
                self.fault.on_exec(kind, self._round)
                with self.tel.span(f"launch:{kind}", tid=tmod.TID_LAUNCH,
                                   reqs=len(slots_reqs), round=self._round):
                    res = exec_fn()
            except Exception as e:
                if not self._isolate_exec:
                    raise          # multi-host: abort + drain, never desync
                self._abort_launch(kind, slots_reqs, e)
            else:
                # prefill/chunked launches feed their OWN straggler EMA
                # (distinct event kind from the decode watchdog)
                dt = (time.perf_counter() - t0
                      + self.fault.exec_delay(kind, self._round))
                if self.prefill_straggler.observe(dt):
                    self.failures.record(
                        self._round, "straggler_prefill",
                        f"{kind} launch {dt:.4f}s > "
                        f"{self.prefill_straggler.factor:g}x EMA "
                        f"{self.prefill_straggler.ema:.4f}s")
                self.stats["prefill_straggler_flags"] = \
                    self.prefill_straggler.flagged
                if self.tel.enabled:
                    self.tel.launch_histogram(kind).observe(dt)
                with self.tel.span(f"apply:{kind}", tid=tmod.TID_APPLY):
                    apply_fn(plan, res)

        def flush():
            nonlocal admitted
            share = self.paged and self.prefix_sharing and not extras
            for key in order:
                per = self._assign(groups[key])
                if self.paged:
                    # claim pages at plan time; requests that don't fit go
                    # back to the queue front and wait for frees/preempts
                    per, n_deferred = self._claim_per(per, extras)
                    admitted -= n_deferred
                    if not any(per):
                        continue
                if key[0] == "chunk":
                    with self.tel.span("plan:chunked", tid=tmod.TID_PLAN):
                        plan = self._plan_chunked(groups[key], per=per)
                    plan.share_ok = share
                    launch("chunked", plan,
                           [(s, r) for s, _, r in plan.placed],
                           lambda p=plan: self._exec_chunked(p, extras),
                           self._apply_chunked)
                else:
                    with self.tel.span("plan:prefill", tid=tmod.TID_PLAN):
                        plan = self._plan_prefill(per, key[1])
                    plan.share_ok = share
                    launch("prefill", plan,
                           [(s, r) for s, _, r in plan.placed],
                           lambda p=plan: self._exec_prefill(p, extras),
                           self._apply_prefill)
            groups.clear()
            order.clear()

        holdback: list[Request] = []   # spilled uids that couldn't restore
        while self.pending and admitted < free:   # consumes a queue prefix
            r = self.pending.popleft()
            if self.paged and r.uid in self._spilled:
                # preempted-and-spilled: warm resume from the host copy
                # instead of re-prefilling (no pages -> wait at the front)
                if self._try_restore(r):
                    admitted += 1
                else:
                    holdback.append(r)
                continue
            try:
                self._check_prompt(r)
            except Exception as e:
                # malformed request: fails ALONE, peers stay queued/grouped
                self._fail(r, str(e), "plan")
                continue
            S = len(r.prompt)
            if self.chunked_prefill and S > self.buckets[-1]:
                # extras were rejected at submit()/run() entry
                # (_validate_extras) - raising here would drop the
                # dequeued peers and leak the planned slot
                key = ("chunk", -(-S // self.buckets[-1]))
            else:
                key = ("bucket", self._bucket(S))
            if key not in groups:
                groups[key] = []
                order.append(key)
            groups[key].append(r)
            admitted += 1
        for r in reversed(holdback):
            self.pending.appendleft(r)
        flush()
        self._refresh_page_stats()
        return admitted

    # ---------------------------------------------------------------- decode
    def _decode_budget(self, slot: int) -> int:
        """Tokens this live slot consumes from the next decode dispatch:
        the engine block size capped by the row's remaining ``max_new``
        budget and its cache headroom (the last writable position is
        ``max_len - 2``; the completion check below fires at
        ``max_len - 1``).  Always >= 1 for an active slot."""
        r = self.active[slot]
        return max(1, min(self.decode_steps,
                          r.max_new - len(r.generated),
                          self.max_len - 1 - int(self.lengths[slot])))

    def _poison_ok(self, kind: str, plan, ok: np.ndarray) -> np.ndarray:
        """Host-side arm of fault injection: flip the ok flag of every
        batch row the injector poisons this round (whole row: the request
        is evicted at the dispatch boundary, exactly like a device-side
        non-finite row)."""
        rows = self.fault.poison_rows(kind, plan)
        if rows:
            ok = np.array(ok, copy=True)
            ok[np.asarray(rows, np.int64)] = False
        return ok

    def _plan_decode(self) -> DecodePlan | None:
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return None
        row_uids = np.full((self.slots,), -1, np.int32)
        row_steps = np.full((self.slots,), -1, np.int32)
        n_steps = np.zeros((self.slots,), np.int32)
        for i in live:
            row_uids[i] = self.active[i].uid
            row_steps[i] = len(self.active[i].generated)
            n_steps[i] = self._decode_budget(i)
        page_tables = None
        if self.paged:
            spr = self.slots_per_replica
            page_tables = np.full((self.slots, self.n_pp), -1, np.int32)
            for s in live:
                uid = self._slot_uids[s]
                if uid is not None:
                    page_tables[s] = self.page_pools[s // spr].table_row(uid)
        return DecodePlan(live=live,
                          tokens=self.last_tokens[:, None].astype(np.int32),
                          positions=self.lengths[:, None].astype(np.int32),
                          row_uids=row_uids, row_steps=row_steps,
                          page_tables=page_tables, n_steps=n_steps)

    def _apply_decode(self, plan: DecodePlan, res) -> None:
        """Consume one dispatch's (slots, N) token block.  Each live row
        takes its planned ``n_steps`` tokens in order; a non-finite step
        evicts that request alone AT THE DISPATCH BOUNDARY (tokens the row
        produced before the poisoned step are kept - they were computed
        from finite state).  ``decode_steps`` counts DISPATCHES and
        ``decode_tokens`` consumed tokens, so host dispatches per token is
        deterministically 1/N when rows run full blocks."""
        nxt, ok = res
        nxt = np.asarray(nxt).reshape(self.slots, -1)
        ok = np.asarray(ok).reshape(self.slots, -1)
        self.stats["decode_steps"] += 1
        consumed = 0
        for i in plan.live:
            req = self.active[i]
            if req is None:
                continue              # evicted between plan and apply
            for t in range(int(plan.n_steps[i])):
                if not ok[i, t]:
                    # poisoned step: evict this request alone; peers' rows
                    # in the cache pool are untouched (per-slot state)
                    self.active[i] = None
                    self._release_slot(i)
                    self._fail(req, "non-finite logits at decode",
                               "nonfinite")
                    break
                tok = int(nxt[i, t])
                consumed += 1
                req.generated.append(tok)
                self.lengths[i] += 1
                self.last_tokens[i] = tok
                self._emit_token(req, tok)
                if (len(req.generated) >= req.max_new
                        or self.lengths[i] >= self.max_len - 1):
                    self.active[i] = None
                    self._release_slot(i)   # freed for the next admission
                    self._complete(req)
                    break
        self.stats["decode_tokens"] += consumed

    # ----------------------------------------------- paged decode growth
    def _ensure_decode_pages(self) -> None:
        """Make every live slot own (writably) every page the next decode
        dispatch writes - positions ``lengths[slot]`` through
        ``lengths[slot] + n_steps - 1`` (the whole N-step block is
        pre-allocated, so preemption only ever happens BETWEEN dispatches)
        - BEFORE the page tables are snapshotted into the decode plan.
        Growth allocations happen exactly when the block crosses a page
        boundary; a COW copy fires when a written page is prefix-shared
        (only the frontier page ``lengths // page`` can be - later pages
        are freshly allocated).  Under pool pressure the YOUNGEST request
        on the replica is preempted (LIFO: oldest-first iteration +
        youngest victim keeps head-of-line work moving);
        ``pool_pages >= n_pp + 1`` guarantees a sole survivor can always
        grow, so the victim loop terminates."""
        spr = self.slots_per_replica
        copies: dict[int, list[tuple[int, int]]] = {}
        order = sorted((s for s in range(self.slots)
                        if self.active[s] is not None),
                       key=lambda s: self._slot_seq[s])
        for slot in order:
            if self.active[slot] is None:
                continue                  # preempted earlier in this sweep
            ri = slot // spr
            pool = self.page_pools[ri]
            uid = self._slot_uids[slot]
            j0 = int(self.lengths[slot]) // self.page_size
            last = int(self.lengths[slot]) + self._decode_budget(slot) - 1
            need = last // self.page_size + 1
            while True:
                try:
                    while pool.n_owned(uid) < need:
                        pool.alloc(uid, 1)
                    for j in range(j0, need):
                        cp = pool.ensure_writable(uid, j)
                        if cp is not None:
                            copies.setdefault(ri, []).append(cp)
                    break
                except PageError:
                    victim = max((s for s in range(ri * spr, (ri + 1) * spr)
                                  if self.active[s] is not None),
                                 key=lambda s: self._slot_seq[s])
                    self._preempt(victim)
                    if victim == slot:
                        break             # preempted ourselves: give up
        for ri, pairs in copies.items():
            with self.tel.span("page_copy", tid=tmod.TID_LAUNCH,
                               replica=ri, pairs=len(pairs)):
                self._exec_page_copy(ri, pairs)

    def _preempt(self, slot: int) -> None:
        """Evict a request under pool pressure: pages free, the request
        goes back to the queue FRONT.  Without spill it restarts from
        prefill and regenerates its tokens bit-exactly ((uid, step)
        sampling keys; the ``emitted`` watermark stops double delivery);
        with spill the pages are captured to host memory first and resume
        is a device scatter instead of recompute."""
        req = self.active[slot]
        uid = self._slot_uids[slot]
        ri = slot // self.slots_per_replica
        self.stats["preemptions"] += 1
        if self.spill_enabled:
            try:
                rec = self._exec_spill(slot, uid,
                                       self.page_pools[ri].pages(uid))
            except Exception as e:     # spill is best-effort: fall back to
                self.failures.record(  # cold regeneration, stay bit-exact
                    self._round, "spill", f"uid={uid}: {e!r}")
            else:
                self._spilled[uid] = rec
                self.stats["spills"] += 1
        if uid not in self._spilled:
            del req.generated[:]       # keep list identity (stream holds it)
        self.active[slot] = None
        self._release_slot(slot)
        self.pending.appendleft(req)
        self.failures.record(self._round, "preempt", f"uid={uid} slot={slot}")

    def _try_restore(self, req: Request) -> bool:
        """Warm-resume a spilled request into a free slot + fresh pages.
        Returns True when the request was consumed (restored OR failed in
        isolation); False defers it at the queue front."""
        rec = self._spilled[req.uid]
        ri = max(range(self.n_replicas), key=lambda i: len(self._free_r[i]))
        if not self._free_r[ri]:
            return False
        pool = self.page_pools[ri]
        pool.attach(req.uid)
        try:
            ids = pool.alloc(req.uid, rec.n_pages)
        except PageError:
            pool.release(req.uid)
            return False
        slot = self._take_slot(ri)
        self._bind_slot(slot, req)
        try:
            self._exec_restore(slot, rec, ids)
        except Exception as e:
            if not self._isolate_exec:
                raise
            self._release_slot(slot)
            del self._spilled[req.uid]
            self._fail(req, f"spill restore failed: {e!r}", "exec")
            return True
        del self._spilled[req.uid]
        self.active[slot] = req
        self.lengths[slot] = rec.length
        self.last_tokens[slot] = rec.last_token
        self.stats["spill_restores"] += 1
        return True

    def step(self) -> int:
        """One batched decode step over all active slots; returns #active.

        The launch is timed into the straggler EMA (plus any injected
        virtual delay) and guarded by request isolation: a raising decode
        launch fails the live requests and keeps the engine serving."""
        if self.paged:
            # every live slot must own the page its next write hits BEFORE
            # the page tables are snapshotted into the plan
            self._ensure_decode_pages()
        with self.tel.span("plan:decode", tid=tmod.TID_PLAN):
            plan = self._plan_decode()
        if plan is None:
            return 0
        if self.tel.enabled:
            self.tel.round_occupancy.observe(len(plan.live))
        t0 = time.perf_counter()
        try:
            self.fault.on_exec("decode", self._round)
            with self.tel.span("launch:decode", tid=tmod.TID_LAUNCH,
                               live=len(plan.live), round=self._round):
                res = self._exec_decode(plan)
        except Exception as e:
            if not self._isolate_exec:
                raise
            self._abort_launch("decode",
                               [(i, self.active[i]) for i in plan.live
                                if self.active[i] is not None], e)
        else:
            dt = (time.perf_counter() - t0
                  + self.fault.exec_delay("decode", self._round))
            if self.straggler.observe(dt):
                self.failures.record(
                    self._round, "straggler",
                    f"decode launch {dt:.4f}s > {self.straggler.factor:g}x "
                    f"EMA {self.straggler.ema:.4f}s")
            self.stats["straggler_flags"] = self.straggler.flagged
            if self.tel.enabled:
                self.tel.launch_histogram("decode").observe(dt)
            with self.tel.span("apply:decode", tid=tmod.TID_APPLY):
                self._apply_decode(plan, res)
        self._refresh_page_stats()
        return len([r for r in self.active if r is not None])

    def run(self, requests: list[Request], extras=None) -> list[Request]:
        """Drain a request list through the engine (continuous batching).

        Admission is bucket-grouped and batched (``_admit``); completion is
        tracked incrementally: ``step`` appends each finished request to
        ``self.finished`` as its slot frees, so draining is O(1) per
        completion instead of rescanning the whole request list every
        decode step.
        """
        if self._draining:
            raise EngineDraining(
                "engine is draining (request_drain() was called): new "
                "submissions are rejected; resume from the snapshot")
        for r in requests:                 # validate upfront: an oversized
            self._validate(len(r.prompt))  # prompt must not dequeue peers
            self._validate_extras(len(r.prompt), extras)
        if self.tel.enabled:
            now = time.perf_counter()
            for r in requests:
                if r.submitted_at is None:
                    r.submitted_at = now
        self.pending.extend(requests)
        n_active = sum(r is not None for r in self.active)   # pre-submitted
        while self.pending or n_active:
            if self._draining:
                break                 # preempted: snapshot() carries the rest
            self.fault.on_round(self._round)
            if self._draining:
                break
            if self._expire_deadlines():
                n_active = sum(r is not None for r in self.active)
                if not (self.pending or n_active):
                    break
            if self.batch_prefill:
                self._admit(extras)
            else:
                while self.pending and self._free_total():
                    self._submit_one(self.pending.popleft(), extras)
            n_active = self.step()
            self._round += 1
        if self._draining and self.snapshot_path:
            # persist the drain record as part of the preemption path: the
            # relaunch rebuilds its queue via ``resume_requests``
            with self.tel.span("snapshot", tid=tmod.TID_SNAPSHOT):
                save_snapshot(self.snapshot_path, self.snapshot())
        return requests


def resume_requests(snap: dict) -> tuple[list[Request], list[Request]]:
    """Rebuild requests from a drain snapshot: ``(finished, todo)``.

    ``todo`` (in-flight in slot order first, then pending in queue order)
    carries each unfinished request with its progress CLEARED: on resume
    the engine regenerates from the original prompt, and because sampling
    keys derive from (uid, step) - not from engine launch history - token
    n of a request is the identical computation whether or not the run was
    interrupted, on whatever mesh the restarted engine got.  That is what
    makes a killed-and-resumed run token-for-token equal to an
    uninterrupted one without shipping cache pages in the snapshot (a lost
    worker's pages could not be shipped anyway).
    """
    assert snap.get("version") == 1, snap.get("version")

    def unpack(rec: dict, *, clear: bool) -> Request:
        return Request(uid=int(rec["uid"]),
                       prompt=np.asarray(rec["prompt"]),
                       max_new=int(rec["max_new"]),
                       generated=[] if clear else list(rec["generated"]),
                       done=not clear, error=rec.get("error"),
                       finish_reason=None if clear
                       else rec.get("finish_reason"))

    finished = [unpack(rec, clear=False) for rec in snap["finished"]]
    todo = [unpack(rec, clear=True)
            for rec in list(snap["inflight"]) + list(snap["pending"])]
    return finished, todo
