"""HTTP front door for ``ServeService``: OpenAI-style completions over a
raw-asyncio HTTP/1.1 server (stdlib only - no framework dependency).

Routes:
  * ``POST /v1/completions`` - body ``{"prompt": [token ids...],
    "max_tokens": n, "stream": bool, "deadline_s": seconds}``.  With
    ``stream=true`` the response is ``text/event-stream``: one
    ``data: {"token": t, "index": i}`` event per generated token (fed from
    the scheduler's own apply path - the streamed tokens ARE the engine's
    tokens), then ``data: {"finish_reason": ...}`` and ``data: [DONE]``.
    Without streaming, one JSON body after the request finishes.
  * ``GET /healthz`` - liveness + drain state.
  * ``GET /v1/stats`` - the scheduler counters + service watermarks
    (snapshot under the engine's stats lock - the loop thread keeps
    mutating while we serialize).
  * ``GET /metrics`` - Prometheus text exposition (version 0.0.4) of the
    engine's metric registry: TTFT / per-token / queue-wait / launch
    histograms, pdq_fallbacks / pdq_clip_rate quantization health, shed
    and occupancy series (serve/telemetry.py).
  * ``GET /v1/events`` - the structured failure/eviction/preemption/
    straggler event ring as JSONL, one event object per line.

Robustness mapping (the whole point of the front door):
  * overload   -> 429 with ``Retry-After`` (typed ``OverloadedError`` from
    the bounded admission queue; never unbounded growth),
  * draining   -> 503 (typed ``EngineDraining`` after SIGTERM/SIGINT),
  * bad input  -> 400 (malformed/oversized prompt, unsupported combo),
  * client disconnect mid-stream -> the connection watcher cancels the
    request in the scheduler (``cancel(uid)``), freeing its slot within a
    round while batch peers stay bit-exact,
  * stalled reader -> the bounded per-stream buffer overflows, the service
    cancels with a ``slow_consumer`` finish, and the SSE writer also arms
    a write timeout - a dead TCP peer cannot pin a slot.

Each connection serves one request (``Connection: close``): simple,
correct, and SSE holds its connection for the stream's lifetime anyway.
"""
from __future__ import annotations

import asyncio
import json

from .core import EngineDraining
from .service import OverloadedError, ServeService

__all__ = ["HttpFrontend"]

_MAX_BODY = 8 << 20          # 8 MiB: far beyond any token-id prompt


def _resp_bytes(code: int, reason: str, ctype: str, body: bytes,
                extra: dict | None = None) -> bytes:
    head = [f"HTTP/1.1 {code} {reason}", f"Content-Type: {ctype}",
            f"Content-Length: {len(body)}", "Connection: close"]
    for k, v in (extra or {}).items():
        head.append(f"{k}: {v}")
    return ("\r\n".join(head) + "\r\n\r\n").encode() + body


def _json_bytes(code: int, reason: str, obj: dict,
                extra: dict | None = None) -> bytes:
    return _resp_bytes(code, reason, "application/json",
                       (json.dumps(obj) + "\n").encode(), extra)


class HttpFrontend:
    """Asyncio HTTP server bound to one ``ServeService``."""

    def __init__(self, service: ServeService, host: str = "127.0.0.1",
                 port: int = 0, *, write_timeout: float = 30.0):
        self.service = service
        self.host = host
        self.port = port
        self.write_timeout = float(write_timeout)
        self._server: asyncio.AbstractServer | None = None
        self._conns: set = set()

    # ------------------------------------------------------------ lifecycle
    async def start(self) -> "HttpFrontend":
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        conns = set(self._conns)
        if conns:
            # let in-flight handlers flush their final events (a drained
            # SSE stream's typed finish + [DONE]) instead of cancelling
            # them mid-write; bounded - a dead peer cannot pin shutdown
            await asyncio.wait(conns, timeout=self.write_timeout)

    async def serve_until(self, stop: asyncio.Event) -> None:
        """Run until ``stop`` is set (the launch driver sets it from the
        SIGTERM/SIGINT handler after requesting the service drain)."""
        if self._server is None:
            await self.start()
        await stop.wait()
        await self.stop()

    # ----------------------------------------------------------- connection
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        task = asyncio.current_task()
        self._conns.add(task)
        try:
            parsed = await self._read_request(reader)
            if parsed is None:
                writer.write(_json_bytes(400, "Bad Request",
                                         {"error": "malformed request"}))
                await writer.drain()
                return
            method, path, headers, body = parsed
            if method == "GET" and path == "/healthz":
                writer.write(_json_bytes(200, "OK", {
                    "status": "draining" if self.service.draining
                    else "serving"}))
                await writer.drain()
            elif method == "GET" and path == "/v1/stats":
                writer.write(_json_bytes(200, "OK", self.service.stats()))
                await writer.drain()
            elif method == "GET" and path == "/metrics":
                writer.write(_resp_bytes(
                    200, "OK", "text/plain; version=0.0.4; charset=utf-8",
                    self.service.metrics_text().encode()))
                await writer.drain()
            elif method == "GET" and path == "/v1/events":
                lines = "".join(json.dumps(e) + "\n"
                                for e in self.service.events())
                writer.write(_resp_bytes(200, "OK", "application/jsonl",
                                         lines.encode()))
                await writer.drain()
            elif method == "POST" and path == "/v1/completions":
                await self._completions(reader, writer, body)
            else:
                writer.write(_json_bytes(404, "Not Found",
                                         {"error": f"no route {path}"}))
                await writer.drain()
        except (ConnectionResetError, BrokenPipeError,
                asyncio.IncompleteReadError):
            pass                        # client went away mid-exchange
        finally:
            self._conns.discard(task)
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    async def _read_request(self, reader):
        line = (await reader.readline()).decode("latin-1").strip()
        if not line:
            return None
        parts = line.split()
        if len(parts) != 3:
            return None
        method, path = parts[0].upper(), parts[1]
        headers: dict[str, str] = {}
        while True:
            h = (await reader.readline()).decode("latin-1").rstrip("\r\n")
            if not h:
                break
            if ":" in h:
                k, v = h.split(":", 1)
                headers[k.strip().lower()] = v.strip()
        n = int(headers.get("content-length", 0) or 0)
        if n < 0 or n > _MAX_BODY:
            return None
        body = await reader.readexactly(n) if n else b""
        return method, path, headers, body

    # ---------------------------------------------------------- completions
    async def _completions(self, reader, writer, body: bytes) -> None:
        try:
            obj = json.loads(body or b"{}")
            prompt = obj["prompt"]
            max_tokens = int(obj.get("max_tokens", 16))
            stream_mode = bool(obj.get("stream", False))
            deadline_s = obj.get("deadline_s")
            deadline_s = None if deadline_s is None else float(deadline_s)
        except (KeyError, TypeError, ValueError, json.JSONDecodeError) as e:
            writer.write(_json_bytes(400, "Bad Request",
                                     {"error": f"bad request body: {e}"}))
            await writer.drain()
            return
        try:
            stream = self.service.submit(prompt, max_new=max_tokens,
                                         deadline_s=deadline_s)
        except OverloadedError as e:
            writer.write(_json_bytes(
                429, "Too Many Requests", {"error": str(e)},
                extra={"Retry-After": f"{e.retry_after:g}"}))
            await writer.drain()
            return
        except EngineDraining as e:
            writer.write(_json_bytes(503, "Service Unavailable",
                                     {"error": str(e)}))
            await writer.drain()
            return
        except (ValueError, NotImplementedError) as e:
            writer.write(_json_bytes(400, "Bad Request", {"error": str(e)}))
            await writer.drain()
            return

        loop = asyncio.get_running_loop()
        ev = asyncio.Event()
        stream.add_waker(lambda: loop.call_soon_threadsafe(ev.set))
        done = False

        async def watch_disconnect():
            # the client never sends more data on this connection; EOF (or
            # a reset) before the response finishes = it hung up -> cancel
            try:
                while await reader.read(4096):
                    pass
            except Exception:
                pass
            if not done:
                self.service.cancel(stream.uid, kind="disconnect",
                                    reason="client disconnected")

        watcher = asyncio.create_task(watch_disconnect())
        try:
            if stream_mode:
                await self._stream_sse(writer, stream, ev)
            else:
                await self._respond_once(writer, stream, ev)
            done = True
        except (ConnectionResetError, BrokenPipeError, TimeoutError,
                asyncio.TimeoutError):
            self.service.cancel(stream.uid, kind="disconnect",
                                reason="client connection lost mid-response")
        finally:
            watcher.cancel()

    async def _stream_sse(self, writer, stream, ev) -> None:
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        idx = 0
        while True:
            ev.clear()
            toks, fin = stream.drain()
            for t in toks:
                writer.write(b"data: " + json.dumps(
                    {"token": t, "index": idx}).encode() + b"\n\n")
                idx += 1
            if toks:
                # a peer that stopped reading stalls drain(): bound it so a
                # dead TCP connection cannot pin the handler (the bounded
                # TokenStream buffer is the primary guard; this is the
                # transport-level backstop)
                await asyncio.wait_for(writer.drain(), self.write_timeout)
            if fin is not None:
                reason, error = fin
                writer.write(b"data: " + json.dumps(
                    {"finish_reason": reason, "error": error,
                     "id": stream.uid}).encode() + b"\n\n")
                writer.write(b"data: [DONE]\n\n")
                await writer.drain()
                return
            await ev.wait()

    async def _respond_once(self, writer, stream, ev) -> None:
        toks: list[int] = []
        while True:
            ev.clear()
            got, fin = stream.drain()
            toks.extend(got)
            if fin is not None:
                reason, error = fin
                break
            await ev.wait()
        writer.write(_json_bytes(200, "OK", {
            "id": stream.uid, "tokens": toks, "finish_reason": reason,
            "error": error}))
        await writer.drain()
