"""Batched serving engine: bucketed batched prefill + continuous batching.

Production features:
  * fixed-slot KV cache pool with per-slot lengths (continuous batching -
    new requests claim freed slots without recompiling);
  * bucketed, batched prefill: prompts are right-padded to a small static
    set of length buckets, so an engine lifetime compiles at most
    ``len(buckets)`` prefill executables (the per-request path recompiled
    per distinct prompt length), and every admission round prefills ALL
    admissible same-bucket requests in ONE ``bundle.prefill_many`` call -
    the grouped PDQ prologue/matmul pipeline then runs at real batch sizes
    during prefill too.  The finished rows land in the pooled cache via one
    fused ``bundle.cache_scatter`` (kernels/kv_cache.cache_scatter_p);
  * an explicit admission scheduler: a deque-based pending queue, bucket-
    grouped admits in FIFO order, a free-slot deque (no O(slots) rescans
    per admission), and per-step accounting in ``engine.stats``;
  * greedy or temperature sampling;
  * optional PDQ-int8 weight path (``quantize_weights=True``; see
    models/linops.py and DESIGN.md Sec. 2) and optional int8 KV cache
    (cfg.quant_kv='dynamic', kernels/kv_cache.py).

Padding never leaks: pad tokens are masked out of attention by causality,
skipped exactly by the SSM recurrence (dt=0), and their cache writes are
redirected onto the row's last real token (models/attention._clamp_padded),
so a bucketed prefill is bit-identical to an unpadded one.  Sole caveat:
MoE routing, where pad/dummy rows consume expert capacity - exact only
while capacity_factor absorbs them (DESIGN.md Sec. 4).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.models.linops import quantize_param_tree

DEFAULT_BUCKETS = (32, 64, 128, 256)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 16
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 quantize_weights: bool = False, temperature: float = 0.0,
                 rng: jax.Array | None = None,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 batch_prefill: bool = True):
        self.cfg = cfg
        self.bundle = build_model(cfg)
        self.params = (quantize_param_tree(params) if quantize_weights
                       else params)
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        mem_len = 8 if cfg.family == "encdec" else 0
        self.mem_len = mem_len
        self.patch_tokens = (cfg.frontend_tokens if cfg.frontend == "vision"
                             else 0)
        self.caches = self.bundle.init_caches(slots, max_len, mem_len)
        self.lengths = np.zeros((slots,), np.int64)
        self.active: list[Request | None] = [None] * slots
        self.last_tokens = np.zeros((slots,), np.int64)
        self.finished: list[Request] = []    # completion order, appended O(1)
        self.batch_prefill = batch_prefill
        # clamp buckets so prompt + patches + the first decode token always
        # fit the cache (a prompt filling the cache exactly would ring-wrap
        # the first decode write onto slot 0), dedupe and sort ascending;
        # _bucket() picks the smallest bucket >= prompt len.  The capacity
        # limit always rides as the last bucket, so any prompt the legacy
        # per-request path served safely is still servable (at most one
        # extra executable).
        limit = max_len - self.patch_tokens - 1
        if limit <= 0:
            raise ValueError(
                f"max_len ({max_len}) leaves no room for a prompt: need "
                f"patch_tokens ({self.patch_tokens}) + prompt + 1 decode slot")
        self.buckets = tuple(sorted({min(int(b), limit) for b in buckets
                                     if int(b) > 0} | {limit}))
        # admission scheduler state: FIFO pending queue + free-slot pool
        # (both deques: O(1) admit, no rescans of self.active per admission)
        self.pending: collections.deque[Request] = collections.deque()
        self._free: collections.deque[int] = collections.deque(range(slots))
        self.stats: dict[str, int] = {
            "prefill_compiles": 0,     # distinct prefill executables traced
            "decode_compiles": 0,
            "prefill_batches": 0,      # prefill launches (bucketed: one per
                                       # bucket group; legacy: one per request)
            "prefill_requests": 0,     # requests admitted through prefill
            "prefill_tokens": 0,       # real prompt tokens prefetched
            "prefill_padded_tokens": 0,  # tokens actually executed (pads incl)
            "decode_steps": 0,
            "decode_tokens": 0,
            "completed": 0,
        }
        # one spare cache pool fed to every prefill_many call: prefill is
        # functional, so the same zero pool is reused forever and the
        # written rows are landed into self.caches by cache_scatter.
        if batch_prefill:
            self._prefill_pool = self.bundle.init_caches(slots, max_len,
                                                         mem_len)
        else:
            # legacy path: a single zero row - a new request must prefill
            # from an EMPTY cache row, not the freed slot's stale one (the
            # int8 decode kernel masks by cache['len'], and _cache_write
            # keeps max(stale_len, new_len), so stale tokens would attend)
            self._fresh_row = self.bundle.init_caches(1, max_len, mem_len)
        self._decode = self._traced_jit(self.bundle.decode_step,
                                        "decode_compiles")
        self._prefill_one = self._traced_jit(self.bundle.prefill,
                                             "prefill_compiles")
        self._prefill_many = self._traced_jit(self.bundle.prefill_many,
                                              "prefill_compiles")
        # the pooled cache is rebound to the scatter result immediately, so
        # donate it: the update lands in place instead of copying the whole
        # pool per admission (no-op off-TPU, where donation is unsupported)
        self._scatter = jax.jit(self.bundle.cache_scatter, donate_argnums=(0,))

    def _traced_jit(self, fn, counter: str):
        """jit(fn) that bumps ``stats[counter]`` once per (re)trace - i.e.
        once per compiled executable, the quantity the bucket design caps."""
        stats = self.stats

        def wrapped(*args):
            stats[counter] += 1      # trace-time side effect
            return fn(*args)

        return jax.jit(wrapped)

    # ----------------------------------------------------------------- admin
    def _bucket(self, prompt_len: int) -> int:
        if prompt_len <= 0:
            raise ValueError("empty prompt: nothing to prefill")
        for b in self.buckets:
            if prompt_len <= b:
                return b
        raise ValueError(
            f"prompt of {prompt_len} tokens exceeds the largest prefill "
            f"bucket {self.buckets[-1]} (max_len={self.max_len}, "
            f"patch_tokens={self.patch_tokens})")

    def submit(self, req: Request, extras: dict[str, Any] | None = None) -> bool:
        """Admit the request into a free slot now; False if engine is full.

        On the bucketed path this may opportunistically co-admit queued
        same-bucket requests into the same prefill launch.
        """
        if not self._free:
            return False
        if not self.batch_prefill:
            return self._submit_one(req, extras)
        self._bucket(len(req.prompt))    # validate before touching the queue
        self.pending.appendleft(req)
        self._admit(extras)
        return True

    def _submit_one(self, req: Request, extras) -> bool:
        """Legacy per-request prefill (benchmark baseline): slice one slot,
        prefill a batch of 1 at the EXACT prompt length (so XLA compiles a
        fresh executable per distinct length), merge back."""
        if not self._free:
            return False
        S = len(req.prompt)
        self._bucket(S)       # same cache-capacity guard as the bucketed path
        slot = self._free.popleft()
        sub_caches = self._fresh_row      # zero row, never mutated (pure fns)
        batch = {"tokens": jnp.asarray(np.asarray(req.prompt)[None], jnp.int32)}
        if extras:
            batch.update(extras)
        logits, sub_caches = self._prefill_one(self.params, batch, sub_caches)
        self.caches = self.bundle.cache_merge(self.caches, sub_caches, slot)
        tok = self._sample(logits)[0]
        self._activate(slot, req, S, int(tok))
        self.stats["prefill_batches"] += 1
        self.stats["prefill_requests"] += 1
        self.stats["prefill_tokens"] += S
        self.stats["prefill_padded_tokens"] += S
        return True

    def _activate(self, slot: int, req: Request, prompt_len: int, tok: int):
        req.generated.append(tok)
        if len(req.generated) >= req.max_new:
            # prefill already produced the full budget: complete without
            # ever occupying a decode slot (max_new=1 = pure ingest)
            req.done = True
            self.finished.append(req)
            self._free.append(slot)
            self.stats["completed"] += 1
            return
        self.active[slot] = req
        self.lengths[slot] = prompt_len + self.patch_tokens
        self.last_tokens[slot] = tok

    def _admit(self, extras=None) -> int:
        """Bucket-grouped admission: ONE pass over the pending queue assigns
        the first len(free) requests (FIFO) to per-bucket groups, then each
        group prefills in ONE batched call (groups launch in first-arrival
        order).  O(pending) per admission call, not per batch.  Returns the
        number of requests admitted."""
        free = len(self._free)
        groups: dict[int, list[Request]] = {}
        order: list[int] = []
        admitted = 0
        while self.pending and admitted < free:   # consumes a queue prefix
            r = self.pending.popleft()
            b = self._bucket(len(r.prompt))
            if b not in groups:
                groups[b] = []
                order.append(b)
            groups[b].append(r)
            admitted += 1
        for b in order:
            self._prefill_batch(groups[b], b, extras)
        return admitted

    def _prefill_batch(self, reqs: list[Request], bucket: int, extras=None):
        """ONE multi-slot prefill: right-pad the prompts to ``bucket``, run
        prefill_many over a fixed batch of ``slots`` rows (rows beyond
        len(reqs) are dummies the scatter drops), then land the rows into
        the pooled cache with one cache_scatter."""
        Bp = self.slots
        n = len(reqs)
        assert 0 < n <= len(self._free)
        tokens = np.zeros((Bp, bucket), np.int32)
        seq_lens = np.ones((Bp,), np.int32)          # dummy rows: 1 token
        for i, r in enumerate(reqs):
            S = len(r.prompt)
            tokens[i, :S] = r.prompt
            seq_lens[i] = S
        batch = {"tokens": jnp.asarray(tokens)}
        if extras:
            # extras are shared across requests (seed semantics): broadcast
            # their leading batch dim across the prefill rows
            batch.update(jax.tree.map(
                lambda a: jnp.broadcast_to(jnp.asarray(a)[:1],
                                           (Bp,) + jnp.asarray(a).shape[1:]),
                dict(extras)))
        logits, sub = self._prefill_many(self.params, batch,
                                         self._prefill_pool,
                                         jnp.asarray(seq_lens))
        src_map = np.full((self.slots,), -1, np.int32)
        slots_taken = [self._free.popleft() for _ in range(n)]
        for i, slot in enumerate(slots_taken):
            src_map[slot] = i
        self.caches = self._scatter(self.caches, sub, jnp.asarray(src_map))
        nxt = self._sample(logits)                   # (Bp,), dummies ignored
        for i, (slot, r) in enumerate(zip(slots_taken, reqs)):
            self._activate(slot, r, int(seq_lens[i]), int(nxt[i]))
        self.stats["prefill_batches"] += 1
        self.stats["prefill_requests"] += n
        self.stats["prefill_tokens"] += int(seq_lens[:n].sum())
        self.stats["prefill_padded_tokens"] += Bp * bucket

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, -1))
        self.rng, k = jax.random.split(self.rng)
        return np.asarray(jax.random.categorical(k, logits / self.temperature))

    # ---------------------------------------------------------------- decode
    def step(self) -> int:
        """One batched decode step over all active slots; returns #active."""
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        tokens = jnp.asarray(self.last_tokens[:, None], jnp.int32)
        positions = jnp.asarray(self.lengths[:, None], jnp.int32)
        logits, self.caches = self._decode(self.params, self.caches, tokens,
                                           positions)
        nxt = self._sample(logits)
        self.stats["decode_steps"] += 1
        self.stats["decode_tokens"] += len(live)
        for i in live:
            req = self.active[i]
            req.generated.append(int(nxt[i]))
            self.lengths[i] += 1
            self.last_tokens[i] = int(nxt[i])
            if len(req.generated) >= req.max_new or self.lengths[i] >= self.max_len - 1:
                req.done = True
                self.finished.append(req)
                self.active[i] = None
                self._free.append(i)     # slot freed for the next admission
                self.stats["completed"] += 1
        return len([r for r in self.active if r is not None])

    def run(self, requests: list[Request], extras=None) -> list[Request]:
        """Drain a request list through the engine (continuous batching).

        Admission is bucket-grouped and batched (``_admit``); completion is
        tracked incrementally: ``step`` appends each finished request to
        ``self.finished`` as its slot frees, so draining is O(1) per
        completion instead of rescanning the whole request list every
        decode step.
        """
        for r in requests:               # validate upfront: an oversized
            self._bucket(len(r.prompt))  # prompt must not dequeue peers
        self.pending.extend(requests)
        n_active = sum(r is not None for r in self.active)   # pre-submitted
        while self.pending or n_active:
            if self.batch_prefill:
                self._admit(extras)
            else:
                while self.pending and self._free:
                    self._submit_one(self.pending.popleft(), extras)
            n_active = self.step()
        return requests
