"""Batched serving engine: bucketed batched prefill + continuous batching.

Production features:
  * fixed-slot KV cache pool with per-slot lengths (continuous batching -
    new requests claim freed slots without recompiling);
  * bucketed, batched prefill: prompts are right-padded to a small static
    set of length buckets, so an engine lifetime compiles at most
    ``len(buckets)`` prefill executables (the per-request path recompiled
    per distinct prompt length), and every admission round prefills ALL
    admissible same-bucket requests in ONE ``bundle.prefill_many`` call -
    the grouped PDQ prologue/matmul pipeline then runs at real batch sizes
    during prefill too.  The finished rows land in the pooled cache via one
    fused ``bundle.cache_scatter`` (kernels/kv_cache.cache_scatter_p);
  * an explicit admission scheduler: a deque-based pending queue, bucket-
    grouped admits in FIFO order, per-replica free-slot deques (no
    O(slots) rescans per admission), least-loaded replica routing, and
    per-step accounting in ``engine.stats``;
  * chunked prefill (``chunked_prefill=True``): prompts longer than the
    largest bucket are split into bucket-sized chunks instead of compiling
    a cache-capacity-sized executable - the first chunk runs the normal
    bucketed prefill, later chunks run ``bundle.prefill_chunk`` against the
    accumulating cache rows, and the finished rows land through the same
    ``cache_scatter``;
  * greedy or temperature sampling;
  * optional PDQ-int8 weight path (``quantize_weights=True``; see
    models/linops.py and DESIGN.md Sec. 2) and optional int8 KV cache
    (cfg.quant_kv='dynamic', kernels/kv_cache.py).

The scheduler core is replica-aware: slots are grouped into ``n_replicas``
equal blocks and every admission assigns same-bucket requests to the
least-loaded replicas.  With ``n_replicas=1`` (this class) the engine is
the single-device engine; ``serve/sharded.py`` subclasses it to run the
same schedule over a ('data', 'model') device mesh, one slot block per
data-parallel replica.

Padding never leaks: pad tokens are masked out of attention by causality,
skipped exactly by the SSM recurrence (dt=0), masked out of MoE routing
(models/moe.route token_mask), and their cache writes are redirected onto
the row's last real token (models/attention._clamp_padded), so a bucketed
prefill is bit-identical to an unpadded one.  Remaining caveat: each DUMMY
row of a partially-filled prefill batch still routes its single real-token
row through the MoE router (bounded by one token per dummy row).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.models.linops import quantize_param_tree

DEFAULT_BUCKETS = (32, 64, 128, 256)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 16
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 quantize_weights: bool = False, temperature: float = 0.0,
                 rng: jax.Array | None = None,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 batch_prefill: bool = True,
                 chunked_prefill: bool = False,
                 n_replicas: int = 1):
        assert slots % n_replicas == 0, (slots, n_replicas)
        assert batch_prefill or n_replicas == 1, (
            "the legacy per-request prefill baseline is single-replica only")
        assert batch_prefill or not chunked_prefill, (
            "chunked prefill requires the bucketed batched-prefill path")
        self.cfg = cfg
        self.bundle = build_model(cfg)
        self.params = (quantize_param_tree(params) if quantize_weights
                       else params)
        self.slots = slots
        self.n_replicas = n_replicas
        self.slots_per_replica = slots // n_replicas
        self.max_len = max_len
        self.temperature = temperature
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        mem_len = 8 if cfg.family == "encdec" else 0
        self.mem_len = mem_len
        self.patch_tokens = (cfg.frontend_tokens if cfg.frontend == "vision"
                             else 0)
        self.caches = self.bundle.init_caches(slots, max_len, mem_len)
        self.lengths = np.zeros((slots,), np.int64)
        self.active: list[Request | None] = [None] * slots
        self.last_tokens = np.zeros((slots,), np.int64)
        self.finished: list[Request] = []    # completion order, appended O(1)
        self.batch_prefill = batch_prefill
        self.chunked_prefill = chunked_prefill
        # clamp buckets so prompt + patches + the first decode token always
        # fit the cache (a prompt filling the cache exactly would ring-wrap
        # the first decode write onto slot 0), dedupe and sort ascending;
        # _bucket() picks the smallest bucket >= prompt len.  Without
        # chunking the capacity limit always rides as the last bucket, so
        # any prompt the legacy per-request path served safely is still
        # servable (at most one extra executable); with chunking the
        # largest CONFIGURED bucket is the chunk size and longer prompts
        # (up to capacity) are split instead.
        limit = max_len - self.patch_tokens - 1
        if limit <= 0:
            raise ValueError(
                f"max_len ({max_len}) leaves no room for a prompt: need "
                f"patch_tokens ({self.patch_tokens}) + prompt + 1 decode slot")
        self._capacity = limit
        bset = {min(int(b), limit) for b in buckets if int(b) > 0}
        if not chunked_prefill:
            bset |= {limit}
        if not bset:
            raise ValueError("chunked prefill needs at least one bucket")
        self.buckets = tuple(sorted(bset))
        # admission scheduler state: FIFO pending queue + one free-slot
        # deque per replica (O(1) admit, no rescans of self.active; the
        # per-replica split is what least-loaded routing reads)
        self.pending: collections.deque[Request] = collections.deque()
        spr = self.slots_per_replica
        self._free_r: list[collections.deque[int]] = [
            collections.deque(range(r * spr, (r + 1) * spr))
            for r in range(n_replicas)]
        self.stats: dict[str, Any] = {
            "prefill_compiles": 0,     # distinct prefill executables traced
            "chunk_compiles": 0,       # distinct prefill_chunk executables
            "decode_compiles": 0,
            "prefill_batches": 0,      # prefill launches (bucketed: one per
                                       # bucket group; legacy: one per request)
            "chunk_batches": 0,        # prefill_chunk launches
            "prefill_requests": 0,     # requests admitted through prefill
            "chunked_requests": 0,     # ... of which needed chunking
            "prefill_tokens": 0,       # real prompt tokens prefetched
            "prefill_padded_tokens": 0,  # tokens actually executed (pads incl)
            "decode_steps": 0,
            "decode_tokens": 0,
            "completed": 0,
            # per-replica occupancy/admit accounting (single-replica engines
            # report one-element lists)
            "replica_admits": [0] * n_replicas,
            "replica_occupancy": [0] * n_replicas,
        }
        # one spare cache pool fed to every prefill_many call: prefill is
        # functional, so the same zero pool is reused forever and the
        # written rows are landed into self.caches by cache_scatter.
        if batch_prefill:
            self._prefill_pool = self.bundle.init_caches(slots, max_len,
                                                         mem_len)
        else:
            # legacy path: a single zero row - a new request must prefill
            # from an EMPTY cache row, not the freed slot's stale one (the
            # int8 decode kernel masks by cache['len'], and _cache_write
            # keeps max(stale_len, new_len), so stale tokens would attend)
            self._fresh_row = self.bundle.init_caches(1, max_len, mem_len)
        self._build_jitted()

    # ------------------------------------------------------- device programs
    def _build_jitted(self):
        """Compile wrappers for the device-facing programs.  The sharded
        engine overrides this with shard_map-ed equivalents; everything
        above this line (scheduling, slot accounting, sampling) is shared.
        """
        # the scheduler core emits replica-LOCAL src_map rows, which only a
        # replica-aware (shard_map-ed) scatter resolves - the single-device
        # scatter here would silently land the wrong batch rows
        assert self.n_replicas == 1, (
            "n_replicas > 1 requires replica-aware device programs; "
            "use serve.sharded.ShardedServeEngine")
        self._decode = self._traced_jit(self.bundle.decode_step,
                                        "decode_compiles")
        # the per-request prefill survives ONLY as the legacy baseline
        # (batch_prefill=False); the scheduler core never reaches it on the
        # bucketed path
        self._prefill_one = (None if self.batch_prefill else
                             self._traced_jit(self.bundle.prefill,
                                              "prefill_compiles"))
        self._prefill_many = self._traced_jit(self.bundle.prefill_many,
                                              "prefill_compiles")
        self._prefill_chunk = self._traced_jit(self.bundle.prefill_chunk,
                                               "chunk_compiles")
        # the pooled cache is rebound to the scatter result immediately, so
        # donate it: the update lands in place instead of copying the whole
        # pool per admission (no-op off-TPU, where donation is unsupported)
        self._scatter = jax.jit(self.bundle.cache_scatter, donate_argnums=(0,))

    def _traced_jit(self, fn, counter: str):
        """jit(fn) that bumps ``stats[counter]`` once per (re)trace - i.e.
        once per compiled executable, the quantity the bucket design caps."""
        stats = self.stats

        def wrapped(*args):
            stats[counter] += 1      # trace-time side effect
            return fn(*args)

        return jax.jit(wrapped)

    # ----------------------------------------------------------------- admin
    def _bucket(self, prompt_len: int) -> int:
        if prompt_len <= 0:
            raise ValueError("empty prompt: nothing to prefill")
        for b in self.buckets:
            if prompt_len <= b:
                return b
        raise ValueError(
            f"prompt of {prompt_len} tokens exceeds the largest prefill "
            f"bucket {self.buckets[-1]} (max_len={self.max_len}, "
            f"patch_tokens={self.patch_tokens})")

    def _validate(self, prompt_len: int) -> None:
        """Reject empty/oversized prompts up front (before any dequeue)."""
        if self.chunked_prefill and prompt_len > self.buckets[-1]:
            if prompt_len > self._capacity:
                raise ValueError(
                    f"prompt of {prompt_len} tokens exceeds the cache "
                    f"capacity {self._capacity} (max_len={self.max_len}, "
                    f"patch_tokens={self.patch_tokens})")
            return
        self._bucket(prompt_len)

    def _free_total(self) -> int:
        return sum(len(f) for f in self._free_r)

    def _take_slot(self, replica: int) -> int:
        slot = self._free_r[replica].popleft()
        self.stats["replica_occupancy"][replica] += 1
        return slot

    def _release_slot(self, slot: int) -> None:
        r = slot // self.slots_per_replica
        self._free_r[r].append(slot)
        self.stats["replica_occupancy"][r] -= 1

    def submit(self, req: Request, extras: dict[str, Any] | None = None) -> bool:
        """Admit the request into a free slot now; False if engine is full.

        On the bucketed path this may opportunistically co-admit queued
        same-bucket requests into the same prefill launch.
        """
        if not self._free_total():
            return False
        if not self.batch_prefill:
            return self._submit_one(req, extras)
        self._validate(len(req.prompt))  # validate before touching the queue
        self.pending.appendleft(req)
        self._admit(extras)
        return True

    def _submit_one(self, req: Request, extras) -> bool:
        """Legacy per-request prefill (benchmark baseline): slice one slot,
        prefill a batch of 1 at the EXACT prompt length (so XLA compiles a
        fresh executable per distinct length), merge back."""
        if not self._free_total():
            return False
        S = len(req.prompt)
        self._bucket(S)       # same cache-capacity guard as the bucketed path
        slot = self._take_slot(0)
        sub_caches = self._fresh_row      # zero row, never mutated (pure fns)
        batch = {"tokens": jnp.asarray(np.asarray(req.prompt)[None], jnp.int32)}
        if extras:
            batch.update(extras)
        logits, sub_caches = self._prefill_one(self.params, batch, sub_caches)
        self.caches = self.bundle.cache_merge(self.caches, sub_caches, slot)
        tok = self._sample(logits)[0]
        self.stats["replica_admits"][0] += 1
        self._activate(slot, req, S, int(tok))
        self.stats["prefill_batches"] += 1
        self.stats["prefill_requests"] += 1
        self.stats["prefill_tokens"] += S
        self.stats["prefill_padded_tokens"] += S
        return True

    def _activate(self, slot: int, req: Request, prompt_len: int, tok: int):
        req.generated.append(tok)
        if len(req.generated) >= req.max_new:
            # prefill already produced the full budget: complete without
            # ever occupying a decode slot (max_new=1 = pure ingest)
            req.done = True
            self.finished.append(req)
            self._release_slot(slot)
            self.stats["completed"] += 1
            return
        self.active[slot] = req
        self.lengths[slot] = prompt_len + self.patch_tokens
        self.last_tokens[slot] = tok

    def _assign(self, reqs: list[Request]) -> list[list[Request]]:
        """Route same-bucket admits to replicas, least-loaded first (most
        free slots net of this round's assignments; FIFO within the
        round).  Caller guarantees len(reqs) <= total free slots."""
        per: list[list[Request]] = [[] for _ in range(self.n_replicas)]
        for r in reqs:
            ri = max(range(self.n_replicas),
                     key=lambda i: (len(self._free_r[i]) - len(per[i]), -i))
            assert len(self._free_r[ri]) > len(per[ri]), "no free slot"
            per[ri].append(r)
        return per

    def _admit(self, extras=None) -> int:
        """Bucket-grouped admission: ONE pass over the pending queue assigns
        the first len(free) requests (FIFO) to per-bucket groups, then each
        group prefills in ONE batched call spanning every replica (groups
        launch in first-arrival order; a chunk-needing request flushes the
        groups gathered so far and runs its chunk sequence solo).
        O(pending) per admission call, not per batch.  Returns the number
        of requests admitted."""
        free = self._free_total()
        groups: dict[int, list[Request]] = {}
        order: list[int] = []
        admitted = 0

        def flush():
            for b in order:
                self._prefill_batch(self._assign(groups[b]), b, extras)
            groups.clear()
            order.clear()

        while self.pending and admitted < free:   # consumes a queue prefix
            r = self.pending.popleft()
            S = len(r.prompt)
            if self.chunked_prefill and S > self.buckets[-1]:
                flush()                  # keep arrival order across launches
                self._prefill_chunked(r, extras)
                admitted += 1
                continue
            b = self._bucket(S)
            if b not in groups:
                groups[b] = []
                order.append(b)
            groups[b].append(r)
            admitted += 1
        flush()
        return admitted

    def _prefill_batch(self, per: list[list[Request]], bucket: int,
                       extras=None):
        """ONE multi-slot prefill spanning all replicas: right-pad the
        prompts to ``bucket``, lay replica r's admits into rows [r*spr,
        r*spr + len(per[r])) of a fixed ``slots``-row batch (rows beyond a
        replica's admits are dummies the scatter drops), run ONE
        prefill_many, then land the rows into the pooled cache with one
        cache_scatter.  ``src_map`` carries replica-LOCAL source rows so
        the sharded engine's per-replica scatter blocks see local indices
        (identical to global rows when n_replicas == 1)."""
        spr = self.slots_per_replica
        Bp = self.slots
        n = sum(len(g) for g in per)
        assert 0 < n <= self._free_total()
        tokens = np.zeros((Bp, bucket), np.int32)
        seq_lens = np.ones((Bp,), np.int32)          # dummy rows: 1 token
        for ri, reqs in enumerate(per):
            for i, r in enumerate(reqs):
                S = len(r.prompt)
                tokens[ri * spr + i, :S] = r.prompt
                seq_lens[ri * spr + i] = S
        batch = {"tokens": jnp.asarray(tokens)}
        if extras:
            # extras are shared across requests (seed semantics): broadcast
            # their leading batch dim across the prefill rows
            batch.update(jax.tree.map(
                lambda a: jnp.broadcast_to(jnp.asarray(a)[:1],
                                           (Bp,) + jnp.asarray(a).shape[1:]),
                dict(extras)))
        logits, sub = self._prefill_many(self.params, batch,
                                         self._prefill_pool,
                                         jnp.asarray(seq_lens))
        src_map = np.full((self.slots,), -1, np.int32)
        placed: list[tuple[int, int, Request]] = []   # (slot, row, request)
        for ri, reqs in enumerate(per):
            self.stats["replica_admits"][ri] += len(reqs)
            for i, r in enumerate(reqs):
                slot = self._take_slot(ri)
                src_map[slot] = i                     # replica-local row
                placed.append((slot, ri * spr + i, r))
        self.caches = self._scatter(self.caches, sub, jnp.asarray(src_map))
        nxt = self._sample(logits)                   # (Bp,), dummies ignored
        for slot, row, r in placed:
            self._activate(slot, r, int(seq_lens[row]), int(nxt[row]))
        self.stats["prefill_batches"] += 1
        self.stats["prefill_requests"] += n
        self.stats["prefill_tokens"] += int(
            sum(len(r.prompt) for g in per for r in g))
        self.stats["prefill_padded_tokens"] += Bp * bucket

    def _prefill_chunked(self, req: Request, extras=None):
        """Chunked prefill of ONE oversized prompt: bucket-sized chunks run
        sequentially (chunk 1 via the normal ``prefill_many``, later chunks
        via ``prefill_chunk`` against the accumulating rows of the spare
        pool), then the finished row lands through the same
        ``cache_scatter`` as a bucketed admit.  The prompt rides row 0 of
        the least-loaded replica's block; other rows are dummies."""
        if extras:
            raise NotImplementedError(
                "chunked prefill is text-only (no vision/encdec extras)")
        spr = self.slots_per_replica
        Bp = self.slots
        chunk = self.buckets[-1]
        S = len(req.prompt)
        ri = max(range(self.n_replicas), key=lambda i: (len(self._free_r[i]), -i))
        row = ri * spr
        prompt = np.asarray(req.prompt)

        tokens = np.zeros((Bp, chunk), np.int32)
        seq_lens = np.ones((Bp,), np.int32)
        tokens[row] = prompt[:chunk]
        seq_lens[row] = chunk
        logits, sub = self._prefill_many(self.params,
                                         {"tokens": jnp.asarray(tokens)},
                                         self._prefill_pool,
                                         jnp.asarray(seq_lens))
        self.stats["prefill_batches"] += 1
        self.stats["prefill_padded_tokens"] += Bp * chunk
        off = chunk
        while off < S:
            rem = min(chunk, S - off)
            b = self._bucket(rem)        # ragged last chunk pads to a bucket
            tokens = np.zeros((Bp, b), np.int32)
            seq_lens = np.ones((Bp,), np.int32)
            start_lens = np.zeros((Bp,), np.int32)
            tokens[row, :rem] = prompt[off:off + rem]
            seq_lens[row] = rem
            start_lens[row] = off
            logits, sub = self._prefill_chunk(self.params,
                                              {"tokens": jnp.asarray(tokens)},
                                              sub, jnp.asarray(seq_lens),
                                              jnp.asarray(start_lens))
            self.stats["chunk_batches"] += 1
            self.stats["prefill_padded_tokens"] += Bp * b
            off += rem

        slot = self._take_slot(ri)
        src_map = np.full((self.slots,), -1, np.int32)
        src_map[slot] = 0                             # replica-local row 0
        self.caches = self._scatter(self.caches, sub, jnp.asarray(src_map))
        tok = int(self._sample(logits)[row])
        self.stats["replica_admits"][ri] += 1
        self._activate(slot, req, S, tok)
        self.stats["prefill_requests"] += 1
        self.stats["chunked_requests"] += 1
        self.stats["prefill_tokens"] += S

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, -1))
        self.rng, k = jax.random.split(self.rng)
        return np.asarray(jax.random.categorical(k, logits / self.temperature))

    # ---------------------------------------------------------------- decode
    def step(self) -> int:
        """One batched decode step over all active slots; returns #active."""
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        tokens = jnp.asarray(self.last_tokens[:, None], jnp.int32)
        positions = jnp.asarray(self.lengths[:, None], jnp.int32)
        logits, self.caches = self._decode(self.params, self.caches, tokens,
                                           positions)
        nxt = self._sample(logits)
        self.stats["decode_steps"] += 1
        self.stats["decode_tokens"] += len(live)
        for i in live:
            req = self.active[i]
            req.generated.append(int(nxt[i]))
            self.lengths[i] += 1
            self.last_tokens[i] = int(nxt[i])
            if len(req.generated) >= req.max_new or self.lengths[i] >= self.max_len - 1:
                req.done = True
                self.finished.append(req)
                self.active[i] = None
                self._release_slot(i)    # slot freed for the next admission
                self.stats["completed"] += 1
        return len([r for r in self.active if r is not None])

    def run(self, requests: list[Request], extras=None) -> list[Request]:
        """Drain a request list through the engine (continuous batching).

        Admission is bucket-grouped and batched (``_admit``); completion is
        tracked incrementally: ``step`` appends each finished request to
        ``self.finished`` as its slot frees, so draining is O(1) per
        completion instead of rescanning the whole request list every
        decode step.
        """
        for r in requests:                 # validate upfront: an oversized
            self._validate(len(r.prompt))  # prompt must not dequeue peers
        self.pending.extend(requests)
        n_active = sum(r is not None for r in self.active)   # pre-submitted
        while self.pending or n_active:
            if self.batch_prefill:
                self._admit(extras)
            else:
                while self.pending and self._free_total():
                    self._submit_one(self.pending.popleft(), extras)
            n_active = self.step()
        return requests
