"""Batched serving engine: continuous batching over fixed cache slots.

Production features:
  * fixed-slot KV cache pool with per-slot lengths (continuous batching -
    new requests claim freed slots without recompiling);
  * greedy or temperature sampling;
  * optional PDQ-int8 weight path (``quantize_weights=True`` replaces every
    large projection with an int8 record; each projection then runs the
    fused serving pipeline - ONE prologue kernel over the activations plus
    ONE W8A8 matmul whose fp-out epilogue applies the surrogate-predicted
    interval, see models/linops.py and DESIGN.md Sec. 2);
  * optional int8 KV cache (cfg.quant_kv='dynamic'), the decode kernel
    dequantizes in-VMEM (kernels/kv_cache.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.models.linops import quantize_param_tree


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 16
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 quantize_weights: bool = False, temperature: float = 0.0,
                 rng: jax.Array | None = None):
        self.cfg = cfg
        self.bundle = build_model(cfg)
        self.params = (quantize_param_tree(params) if quantize_weights
                       else params)
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        mem_len = 8 if cfg.family == "encdec" else 0
        self.mem_len = mem_len
        self.caches = self.bundle.init_caches(slots, max_len, mem_len)
        self.lengths = np.zeros((slots,), np.int64)
        self.active: list[Request | None] = [None] * slots
        self.last_tokens = np.zeros((slots,), np.int64)
        self.finished: list[Request] = []    # completion order, appended O(1)
        self._decode = jax.jit(self.bundle.decode_step)

    # ----------------------------------------------------------------- admin
    def _free_slot(self) -> int | None:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def submit(self, req: Request, extras: dict[str, Any] | None = None) -> bool:
        """Prefill the request into a free slot; False if engine is full."""
        slot = self._free_slot()
        if slot is None:
            return False
        S = len(req.prompt)
        # per-slot prefill (batch of 1) into the pooled cache
        sub_caches = self.bundle.cache_slice(self.caches, slot, slot + 1)
        batch = {"tokens": jnp.asarray(req.prompt[None], jnp.int32)}
        if extras:
            batch.update(extras)
        logits, sub_caches = self.bundle.prefill(self.params, batch, sub_caches)
        self.caches = self.bundle.cache_merge(self.caches, sub_caches, slot)
        tok = self._sample(logits)[0]
        req.generated.append(int(tok))
        self.active[slot] = req
        P = self.cfg.frontend_tokens if self.cfg.frontend == "vision" else 0
        self.lengths[slot] = S + P
        self.last_tokens[slot] = int(tok)
        return True

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, -1))
        self.rng, k = jax.random.split(self.rng)
        return np.asarray(jax.random.categorical(k, logits / self.temperature))

    # ---------------------------------------------------------------- decode
    def step(self) -> int:
        """One batched decode step over all active slots; returns #active."""
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        tokens = jnp.asarray(self.last_tokens[:, None], jnp.int32)
        positions = jnp.asarray(self.lengths[:, None], jnp.int32)
        logits, self.caches = self._decode(self.params, self.caches, tokens,
                                           positions)
        nxt = self._sample(logits)
        for i in live:
            req = self.active[i]
            req.generated.append(int(nxt[i]))
            self.lengths[i] += 1
            self.last_tokens[i] = int(nxt[i])
            if len(req.generated) >= req.max_new or self.lengths[i] >= self.max_len - 1:
                req.done = True
                self.finished.append(req)
                self.active[i] = None     # slot freed for the next request
        return len([r for r in self.active if r is not None])

    def run(self, requests: list[Request], extras=None) -> list[Request]:
        """Drain a request list through the engine (continuous batching).

        Completion is tracked incrementally: ``step`` appends each finished
        request to ``self.finished`` as its slot frees, so draining is O(1)
        per completion instead of rescanning the whole request list (an
        O(n^2) list-membership loop) every decode step.
        """
        pending = list(requests)
        n_active = sum(r is not None for r in self.active)   # pre-submitted
        while pending or n_active:
            while pending and self._free_slot() is not None:
                if not self.submit(pending[0], extras):
                    break
                pending.pop(0)
            n_active = self.step()
        return requests
