"""Batched serving engine: bucketed batched prefill + continuous batching.

Production features:
  * fixed-slot KV cache pool with per-slot lengths (continuous batching -
    new requests claim freed slots without recompiling);
  * bucketed, batched prefill: prompts are right-padded to a small static
    set of length buckets, so an engine lifetime compiles at most
    ``len(buckets)`` prefill executables (the per-request path recompiled
    per distinct prompt length), and every admission round prefills ALL
    admissible same-bucket requests in ONE ``bundle.prefill_many`` call -
    the grouped PDQ prologue/matmul pipeline then runs at real batch sizes
    during prefill too.  The finished rows land in the pooled cache via one
    fused ``bundle.cache_scatter`` (kernels/kv_cache.cache_scatter_p);
  * an explicit admission scheduler (serve/core.py SchedulerCore): a
    deque-based pending queue, bucket-grouped admits in FIFO order,
    per-replica free-slot deques, least-loaded replica routing, and
    per-step accounting in ``engine.stats``;
  * chunked prefill (``chunked_prefill=True``): prompts longer than the
    largest bucket are split into bucket-sized chunks instead of compiling
    a cache-capacity-sized executable;
  * greedy or temperature sampling;
  * optional PDQ-int8 weight path (``quantize_weights=True``; see
    models/linops.py and DESIGN.md Sec. 2) and optional int8 KV cache
    (cfg.quant_kv='dynamic', kernels/kv_cache.py).

The scheduler lives in ``serve/core.py`` as plan builders + result
appliers; this class binds the plans to single-device jit programs.  With
``n_replicas=1`` (this class) the engine is the single-device engine;
``serve/sharded.py`` runs the same schedule over a ('data', 'model')
device mesh and ``serve/multihost.py`` over a ``jax.distributed``
multi-process mesh.

Padding never leaks: pad tokens are masked out of attention by causality,
skipped exactly by the SSM recurrence (dt=0), masked out of MoE routing
(models/moe.route token_mask), and their cache writes are redirected onto
the row's last real token (models/attention._clamp_padded), so a bucketed
prefill is bit-identical to an unpadded one.  Dummy rows of a
partially-filled prefill batch carry seq_lens == 0 and are masked out the
same way end to end - they claim no MoE expert capacity (PR-5 fix; the
scatter drops their cache rows regardless).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.fault import FaultInjector
from repro.kernels import ops
from repro.models import build_model
from repro.models.linops import quantize_param_tree

from . import telemetry as tmod
from .core import (DEFAULT_BUCKETS, ChunkedPlan, DecodePlan, PrefillPlan,
                   Request, SchedulerCore)
from .pages import SpillRecord

__all__ = ["DECODE_PAD", "DEFAULT_BUCKETS", "Request", "ServeEngine"]

# token-block sentinel: steps a row did not consume (its per-row budget ran
# out before the block did) come back as this instead of a sampled id.
# Token ids are non-negative, so -1 is unambiguous; the scheduler's apply
# loop never reads padded steps, and the multi-host token tracker treats it
# as end-of-row
DECODE_PAD = -1


def decode_scan(step_fn, sample_fn, n_block: int, collect: bool):
    """Build the N-step fused decode body shared by every engine: a
    ``lax.scan`` of ``n_block`` model steps carrying (cache state, token,
    position) ON DEVICE, sampling each step in-program with the per-(uid,
    step) keys, so one host dispatch consumes N decode rounds.

    Per-row budgets ride in ``n_steps``: a row past its budget FREEZES -
    it re-feeds its last token at its last position (rewriting one cache
    position with identical content, a bit-exact no-op) and emits
    ``DECODE_PAD``/ok=True, so every row costs the same FLOPs and the
    block stays one static executable.  PDQ telemetry is collected INSIDE
    the body (the collector's scalars must be traced per iteration) and
    summed over the block; ``pdq_guard``/``tp_shard`` are trace-time only
    and wrap the whole scan at the call site.

    Returns ``run(rng, params, state, tokens, positions, uids, steps,
    n_steps) -> (toks (B, N), ok (B, N), state, tel (3,))``.
    """
    def run(rng, params, state, tokens, positions, uids, steps, n_steps):
        def body(carry, t):
            state, tok, pos = carry
            with ops.pdq_telemetry(collect) as col:
                logits, state = step_fn(params, state, tok, pos)
                tel = col.summary()
            nxt, okt = sample_fn(rng, logits, uids, steps + t)
            act = t < n_steps
            otok = jnp.where(act, nxt, DECODE_PAD).astype(jnp.int32)
            ook = jnp.where(act, okt, True)
            ntok = jnp.where(act, nxt, tok[:, 0]).astype(tok.dtype)[:, None]
            npos = jnp.where(act, pos[:, 0] + 1, pos[:, 0])[:, None]
            return (state, ntok, npos), (otok, ook, tel)

        (state, _, _), (toks, oks, tels) = jax.lax.scan(
            body, (state, tokens, positions),
            jnp.arange(n_block, dtype=jnp.int32))
        return (jnp.moveaxis(toks, 0, 1), jnp.moveaxis(oks, 0, 1), state,
                jnp.sum(tels, axis=0))

    return run


class ServeEngine(SchedulerCore):
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 quantize_weights: bool = False, temperature: float = 0.0,
                 rng: jax.Array | None = None,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 batch_prefill: bool = True,
                 chunked_prefill: bool = False,
                 decode_steps: int = 1,
                 n_replicas: int = 1,
                 fault: FaultInjector | None = None,
                 pdq_fallback: bool = False,
                 paged: bool = False,
                 page_size: int = 64,
                 pool_pages: int | None = None,
                 prefix_sharing: bool = True,
                 spill: bool = False,
                 telemetry: bool = True,
                 trace: bool = False,
                 tel: "tmod.Telemetry | None" = None):
        self.cfg = cfg
        self.bundle = build_model(cfg)
        self.params = (quantize_param_tree(params) if quantize_weights
                       else params)
        self.temperature = temperature
        # the BASE sampling key: never split or advanced.  Every sampled
        # token derives its key as fold_in(fold_in(rng, uid), step), so a
        # request's token stream depends only on (rng, uid, prompt, step) -
        # not on batch composition, chunking, engine restarts, or which
        # other requests shared its launches.  That is what makes chunked
        # == unchunked temperature streams and drain-resume regeneration
        # token-exact.
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.pdq_fallback = bool(pdq_fallback)
        mem_len = 8 if cfg.family == "encdec" else 0
        self.mem_len = mem_len
        if tel is None:
            tel = tmod.Telemetry(enabled=telemetry, trace=trace)
        self._init_scheduler(
            slots=slots, n_replicas=n_replicas, max_len=max_len,
            patch_tokens=(cfg.frontend_tokens if cfg.frontend == "vision"
                          else 0),
            buckets=buckets, batch_prefill=batch_prefill,
            chunked_prefill=chunked_prefill, decode_steps=decode_steps,
            fault=fault, tel=tel)
        if paged:
            assert batch_prefill, "the paged pool needs the bucketed path"
            self._paged_ops = self.bundle.paged_cache(
                slots, max_len, mem_len, page_size)
            n_pp = self._paged_ops.n_pp
            if pool_pages is None:
                # headroom parity with the slot-row pool (+1 dump page):
                # every slot can hold a full sequence simultaneously
                pool_pages = self.slots_per_replica * n_pp + 1
            self._init_paging(page_size=page_size, pool_pages=pool_pages,
                              n_pp=n_pp, prefix_sharing=prefix_sharing,
                              spill=spill)
        self._init_pools()
        self._build_sampler()
        self._build_jitted()

    def _init_pools(self):
        """Allocate the serving cache pools.  The multi-host engine
        overrides this with shape-only stand-ins (its pools are created
        directly on the global mesh, so host allocations would be waste).
        """
        if self.paged:
            # physical page pool: (pool_pages, ..., page, ...) per paged
            # leaf, (slots, ...) rows for flat leaves (see models/api.py)
            self.caches = self._paged_ops.init(
                self.pool_pages * self.n_replicas)
        else:
            self.caches = self.bundle.init_caches(self.slots, self.max_len,
                                                  self.mem_len)
        # one spare cache pool fed to every prefill_many call: prefill is
        # functional, so the same zero pool is reused forever and the
        # written rows are landed into self.caches by cache_scatter.
        if self.batch_prefill:
            self._prefill_pool = self.bundle.init_caches(
                self.slots, self.max_len, self.mem_len)
        else:
            # legacy path: a single zero row - a new request must prefill
            # from an EMPTY cache row, not the freed slot's stale one (the
            # int8 decode kernel masks by cache['len'], and _cache_write
            # keeps max(stale_len, new_len), so stale tokens would attend)
            self._fresh_row = self.bundle.init_caches(1, self.max_len,
                                                      self.mem_len)

    # ------------------------------------------------------- device programs
    def _build_jitted(self):
        """Compile wrappers for the device-facing programs.  The sharded
        engine overrides this with shard_map-ed equivalents; the scheduler
        (serve/core.py) is shared.
        """
        # the scheduler core emits replica-LOCAL src_map rows, which only a
        # replica-aware (shard_map-ed) scatter resolves - the single-device
        # scatter here would silently land the wrong batch rows
        assert self.n_replicas == 1, (
            "n_replicas > 1 requires replica-aware device programs; "
            "use serve.sharded.ShardedServeEngine")
        # the decode fast path: N model steps + in-program sampling fused
        # into ONE dispatch (see decode_scan); host round-trips per token
        # drop to 1/N and the block compiles once
        self._decode = self._traced_decode(decode_scan(
            self.bundle.decode_step, self._sample_fn(),
            self.decode_steps, self.tel.enabled))
        # the per-request prefill survives ONLY as the legacy baseline
        # (batch_prefill=False); the scheduler core never reaches it on the
        # bucketed path
        self._prefill_one = (None if self.batch_prefill else
                             self._traced_jit(self.bundle.prefill,
                                              "prefill_compiles"))
        self._prefill_many = self._traced_jit(self.bundle.prefill_many,
                                              "prefill_compiles")
        self._prefill_chunk = self._traced_jit(self.bundle.prefill_chunk,
                                               "chunk_compiles")
        # the pooled cache is rebound to the scatter result immediately, so
        # donate it: the update lands in place instead of copying the whole
        # pool per admission (no-op off-TPU, where donation is unsupported)
        self._scatter = jax.jit(self.bundle.cache_scatter, donate_argnums=(0,))
        if self.paged:
            self._build_paged_jitted()

    def _paged_decode_fn(self):
        """The paged-pool fused decode body, shared by every engine: gather
        the live rows' pages into the logical layout ONCE, run the N-step
        scan on it, write each row's page WINDOW back (the block may cross
        a page boundary; writeback masks by per-row budget).  Same
        decode_scan return shape: (toks (B, N), ok, pool, tel)."""
        po = self._paged_ops
        N = self.decode_steps
        scan = decode_scan(self.bundle.decode_step, self._sample_fn(),
                           N, self.tel.enabled)

        def decode_paged(rng, params, pool, pt, tokens, positions, uids,
                         steps, n_steps):
            logical = po.gather(pool, pt, positions[:, 0])
            toks, ok, logical, tel = scan(rng, params, logical, tokens,
                                          positions, uids, steps, n_steps)
            pool = po.writeback(pool, logical, pt, positions,
                                n_steps=n_steps, max_steps=N)
            return toks, ok, pool, tel

        return decode_paged

    def _build_paged_jitted(self):
        """Paged-pool device programs: ONE fused decode launch per N-step
        block - no host round-trips beyond the numpy page tables the plan
        already ships."""
        po = self._paged_ops
        self._decode_paged = self._traced_decode(self._paged_decode_fn(),
                                                 donate=(2,))
        self._land = jax.jit(po.land, donate_argnums=(0,))
        self._page_copy = jax.jit(po.copy, donate_argnums=(0,))
        self._restore_prog = jax.jit(po.restore, donate_argnums=(0,))

    def _traced_jit(self, fn, counter: str, donate: tuple = ()):
        """jit(fn) that bumps ``stats[counter]`` once per (re)trace - i.e.
        once per compiled executable, the quantity the bucket design caps.

        Every launch also returns the pdq health summary ((3,) float32:
        guard fallbacks, int8 clip hits, clipped-output count) folded
        device-side by ops.pdq_telemetry - pure jnp reductions, so the
        pallas_call census is unchanged and the scalars ride the existing
        token gather instead of adding a host round-trip.  With telemetry
        off the summary is a constant zeros vector."""
        stats = self.stats
        guard = self.pdq_fallback
        collect = self.tel.enabled

        def wrapped(*args):
            stats[counter] += 1      # trace-time side effect
            with ops.pdq_guard(guard), ops.pdq_telemetry(collect) as col:
                out = fn(*args)
                return out, col.summary()

        return jax.jit(wrapped, donate_argnums=donate)

    def _traced_decode(self, fn, donate: tuple = ()):
        """jit for the fused decode block.  Unlike _traced_jit it does NOT
        open pdq_telemetry here: the scan body collects per-iteration (the
        summary must be traced inside the body) and ``fn`` already returns
        the block-summed (3,) vector as its last element.  pdq_guard is
        trace-time only, so wrapping the whole scan is safe."""
        stats = self.stats
        guard = self.pdq_fallback

        def wrapped(*args):
            stats["decode_compiles"] += 1      # trace-time side effect
            with ops.pdq_guard(guard):
                return fn(*args)

        return jax.jit(wrapped, donate_argnums=donate)

    # -------------------------------------------------------------- sampling
    def _sample_fn(self):
        """The pure (rng, logits, uids, steps) -> (tokens, ok) sampling
        body: per-row sampled token + per-row all-finite flag.

        Keys are derived per ROW from (base rng, uid, step) so a token's
        randomness is a pure function of the request identity and its
        position in the stream - not of which launch sampled it.  That is
        what lets the SAME function serve the host-dispatched prefill
        sampler, the fused decode scan, and the per-replica shard_map
        bodies with token-exact outputs."""
        temp = float(self.temperature)

        def sample(rng, logits, uids, steps):
            ok = jnp.isfinite(logits).all(axis=-1)
            if temp <= 0.0:
                toks = jnp.argmax(logits, -1)
            else:
                def one(lg, uid, step):
                    k = jax.random.fold_in(jax.random.fold_in(rng, uid), step)
                    return jax.random.categorical(k, lg / temp)
                toks = jax.vmap(one)(logits, uids, steps)
            return toks, ok

        return sample

    def _build_sampler(self):
        """Jit the shared sampling body for the host-side prefill path (the
        base key is passed in, not closed over, so engines sharing
        temperature share the executable)."""
        self._sampler = jax.jit(self._sample_fn())

    def _sample_rows(self, kind: str, plan, logits) -> tuple[np.ndarray,
                                                             np.ndarray]:
        """Sample every batch row of a launch; returns numpy
        (tokens (slots,), ok (slots,)).  Applies the fault injector's
        logits poisoning first (no-op outside fault tests)."""
        rows = self.fault.poison_rows(kind, plan)
        if rows:
            logits = jnp.asarray(logits).at[np.asarray(rows)].set(jnp.nan)
        toks, ok = self._sampler(self.rng, logits,
                                 jnp.asarray(plan.row_uids, jnp.int32),
                                 jnp.asarray(plan.row_steps, jnp.int32))
        return np.asarray(toks), np.asarray(ok)

    def _extras_batch(self, batch: dict, extras) -> dict:
        if extras:
            # extras are shared across requests (seed semantics): broadcast
            # their leading batch dim across the prefill rows
            Bp = self.slots
            batch.update(jax.tree.map(
                lambda a: jnp.broadcast_to(jnp.asarray(a)[:1],
                                           (Bp,) + jnp.asarray(a).shape[1:]),
                dict(extras)))
        return batch

    # ------------------------------------------------------------ exec hooks
    def _exec_prefill(self, plan: PrefillPlan, extras):
        batch = self._extras_batch({"tokens": jnp.asarray(plan.tokens)},
                                   extras)
        (logits, sub), tel = self._prefill_many(self.params, batch,
                                                self._prefill_pool,
                                                jnp.asarray(plan.seq_lens))
        self._land_sub(plan, sub)
        out = self._sample_rows("prefill", plan, logits)
        self._observe_pdq(tel)     # already computed: rides the token gather
        return out

    def _land_sub(self, plan, sub) -> None:
        """Land a finished prefill batch in the pool: page-wise through the
        plan's land maps (paged), or whole slot rows (slot-row pool)."""
        if self.paged:
            self.caches = self._land(self.caches, sub,
                                     jnp.asarray(plan.src_map),
                                     jnp.asarray(plan.land_rows),
                                     jnp.asarray(plan.land_js))
        else:
            self.caches = self._scatter(self.caches, sub,
                                        jnp.asarray(plan.src_map))

    def _exec_chunked(self, plan: ChunkedPlan, extras):
        if extras:
            raise NotImplementedError(
                "chunked prefill is text-only (no vision/encdec extras)")
        _, tokens, seq_lens = plan.first
        (logits, sub), tel = self._prefill_many(
            self.params, {"tokens": jnp.asarray(tokens)},
            self._prefill_pool, jnp.asarray(seq_lens))
        for _, tokens, seq_lens, start_lens in plan.chunks:
            (logits, sub), t2 = self._prefill_chunk(
                self.params, {"tokens": jnp.asarray(tokens)}, sub,
                jnp.asarray(seq_lens), jnp.asarray(start_lens))
            tel = tel + t2        # lazy device add: one fetch per launch set
        self._land_sub(plan, sub)
        out = self._sample_rows("chunked", plan, logits)
        self._observe_pdq(tel)
        return out

    def _exec_decode(self, plan: DecodePlan):
        row_args = (jnp.asarray(plan.row_uids, jnp.int32),
                    jnp.asarray(plan.row_steps, jnp.int32),
                    jnp.asarray(plan.n_steps, jnp.int32))
        if self.paged:
            toks, ok, self.caches, tel = self._decode_paged(
                self.rng, self.params, self.caches,
                jnp.asarray(plan.page_tables), jnp.asarray(plan.tokens),
                jnp.asarray(plan.positions), *row_args)
        else:
            toks, ok, self.caches, tel = self._decode(
                self.rng, self.params, self.caches,
                jnp.asarray(plan.tokens), jnp.asarray(plan.positions),
                *row_args)
        self._observe_pdq(tel)
        # fault poisoning moved host-side: sampling now runs in-program, so
        # the injector marks rows bad AFTER the launch instead of NaN-ing
        # logits before it (same observable effect: the row evicts)
        ok = self._poison_ok("decode", plan, np.asarray(ok))
        return np.asarray(toks), ok

    # ------------------------------------------------------ paged-pool hooks
    def _copy_map(self, replica: int, pairs) -> np.ndarray:
        # positions are global (the 'data' shard split localizes them);
        # VALUES stay replica-local page ids - the copy body indexes the
        # replica's own pool shard
        cmap = np.full((self.pool_pages * self.n_replicas,), -1, np.int32)
        base = replica * self.pool_pages
        for src, dst in pairs:
            cmap[base + dst] = src
        return cmap

    def _exec_page_copy(self, replica: int, pairs) -> None:
        cmap = self._copy_map(replica, pairs)
        self.caches = self._page_copy(self.caches, jnp.asarray(cmap))

    def _exec_spill(self, slot: int, uid: int, page_ids) -> SpillRecord:
        return SpillRecord(uid=uid, n_pages=len(page_ids),
                           length=int(self.lengths[slot]),
                           last_token=int(self.last_tokens[slot]),
                           data=self._paged_ops.capture(self.caches, slot,
                                                        page_ids))

    def _exec_restore(self, slot: int, rec: SpillRecord, page_ids) -> None:
        pmap = np.full((self.pool_pages * self.n_replicas,), -1, np.int32)
        for i, p in enumerate(page_ids):
            pmap[p] = i                       # pool page p <- record page i
        smap = np.full((self.slots,), -1, np.int32)
        smap[slot] = 0                        # flat leaves: record row 0
        self.caches = self._restore_prog(self.caches, rec.data,
                                         jnp.asarray(pmap),
                                         jnp.asarray(smap))

    # ------------------------------------------------- legacy per-request path
    def _submit_one(self, req: Request, extras) -> bool:
        """Legacy per-request prefill (benchmark baseline): slice one slot,
        prefill a batch of 1 at the EXACT prompt length (so XLA compiles a
        fresh executable per distinct length), merge back."""
        if not self._free_total():
            return False
        S = len(req.prompt)
        self._bucket(S)       # same cache-capacity guard as the bucketed path
        slot = self._take_slot(0)
        sub_caches = self._fresh_row      # zero row, never mutated (pure fns)
        batch = {"tokens": jnp.asarray(np.asarray(req.prompt)[None], jnp.int32)}
        if extras:
            batch.update(extras)
        (logits, sub_caches), tel = self._prefill_one(self.params, batch,
                                                      sub_caches)
        self.caches = self.bundle.cache_merge(self.caches, sub_caches, slot)
        toks, ok = self._sampler(self.rng, logits,
                                 jnp.asarray([req.uid], jnp.int32),
                                 jnp.asarray([0], jnp.int32))
        self._observe_pdq(tel)
        if not bool(np.asarray(ok)[0]):
            self._release_slot(slot)
            self._fail(req, "non-finite logits at prefill", "nonfinite")
            return True
        tok = int(np.asarray(toks)[0])
        self.stats["replica_admits"][0] += 1
        self._activate(slot, req, S, int(tok))
        self.stats["prefill_batches"] += 1
        self.stats["prefill_requests"] += 1
        self.stats["prefill_tokens"] += S
        self.stats["prefill_padded_tokens"] += S
        return True
