"""One construction surface for every serving engine: ``ServeConfig``.

The three engines (single-device, sharded, multi-host) historically grew
near-identical keyword lists, and every call site - the launcher, the
benchmarks, the tests - re-spelled them.  ``ServeConfig`` is the single
declarative record of a serving deployment; ``build_engine(config)``
resolves it to the right engine class:

  * no ``mesh``                  -> ``ServeEngine`` (single device)
  * ``mesh``                     -> ``ShardedServeEngine``
  * ``mesh`` + ``multihost=True``-> ``MultiHostServeEngine`` (the caller
    must already have joined the ``jax.distributed`` job)

The model config/params can be passed explicitly (the common case when a
caller sweeps engines over one warm param tree), or resolved from
``arch``/``reduced``/``int8_kv`` when omitted - the launcher's flags map
1:1 onto these fields.

``ServeConfig`` is a frozen dataclass: a value, not a builder.  Use
``dataclasses.replace`` to derive variants (the benchmarks derive the
paged/unpaged cells from one base config this way).
"""
from __future__ import annotations

import dataclasses
from typing import Any

from .core import DEFAULT_BUCKETS


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Declarative description of one serving deployment."""

    # ---- model selection (used only when build_engine gets no cfg/params)
    arch: str = "stablelm-1.6b"
    reduced: bool = True            # reduced_config() vs full get_config()
    int8_kv: bool = False           # quant_kv="dynamic" on the model config

    # ---- engine knobs (shared by all engines)
    slots: int = 4                  # total slots (single-device engines)
    max_len: int = 256
    quantize_weights: bool = False  # PDQ int8 weights
    temperature: float = 0.0
    seed: int | None = None         # sampling PRNGKey seed (None -> engine default)
    buckets: tuple[int, ...] = DEFAULT_BUCKETS
    batch_prefill: bool = True
    chunked_prefill: bool = False
    decode_steps: int = 1           # decode tokens fused per host dispatch
    fault: Any = None               # FaultInjector (tests only)
    pdq_fallback: bool = False

    # ---- topology
    mesh: Any = None                # a jax ('data','model') Mesh -> sharded
    slots_per_replica: int | None = None   # mesh engines (default: slots)
    multihost: bool = False         # mesh + jax.distributed -> MultiHost
    launch_timeout: float | None = None    # multihost collective watchdog
    snapshot_path: str | None = None

    # ---- paged KV pool
    paged: bool = False
    page_size: int = 64
    pool_pages: int | None = None   # per-replica physical pages (None: parity)
    prefix_sharing: bool = True
    spill: bool = False             # host spill (single-device only)

    # ---- telemetry (serve/telemetry.py)
    telemetry: bool = True          # metrics registry + lifecycle timing;
                                    # the <=2% overhead A/B switch
    trace: bool = False             # span capture for --trace-out (opt-in:
                                    # ring memory + clock reads per phase)

    def validate(self) -> "ServeConfig":
        if self.multihost and self.mesh is None:
            raise ValueError("multihost=True needs a mesh")
        if self.mesh is not None and self.spill:
            raise ValueError("host spill is single-device only")
        if self.paged and not self.batch_prefill:
            raise ValueError("the paged pool needs batch_prefill=True")
        return self


def resolve_model(config: ServeConfig):
    """(cfg, params) for ``config``'s model selection fields."""
    import jax

    from repro.configs import get_config, reduced_config
    from repro.models import build_model

    cfg = (reduced_config(config.arch) if config.reduced
           else get_config(config.arch))
    if config.int8_kv:
        cfg = dataclasses.replace(cfg, quant_kv="dynamic")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def build_engine(config: ServeConfig, *, cfg=None, params=None):
    """Construct the engine ``config`` describes.

    ``cfg``/``params`` override the model-selection fields when given
    (both or neither): sweeping engine variants over one warm param tree
    is the common case in benchmarks and tests.
    """
    config.validate()
    if (cfg is None) != (params is None):
        raise ValueError("pass both cfg and params, or neither")
    if cfg is None:
        cfg, params = resolve_model(config)

    import jax

    rng = None if config.seed is None else jax.random.PRNGKey(config.seed)
    common = dict(max_len=config.max_len,
                  quantize_weights=config.quantize_weights,
                  temperature=config.temperature, rng=rng,
                  buckets=config.buckets,
                  chunked_prefill=config.chunked_prefill,
                  decode_steps=config.decode_steps,
                  fault=config.fault, pdq_fallback=config.pdq_fallback,
                  paged=config.paged, page_size=config.page_size,
                  pool_pages=config.pool_pages,
                  prefix_sharing=config.prefix_sharing,
                  telemetry=config.telemetry, trace=config.trace)

    if config.mesh is None:
        from .engine import ServeEngine
        eng = ServeEngine(cfg, params, slots=config.slots,
                          batch_prefill=config.batch_prefill,
                          spill=config.spill, **common)
    else:
        spr = (config.slots_per_replica if config.slots_per_replica
               is not None else config.slots)
        if config.multihost:
            from .multihost import MultiHostServeEngine
            eng = MultiHostServeEngine(
                cfg, params, mesh=config.mesh, slots_per_replica=spr,
                launch_timeout=config.launch_timeout,
                snapshot_path=config.snapshot_path, **common)
        else:
            from .sharded import ShardedServeEngine
            eng = ShardedServeEngine(cfg, params, mesh=config.mesh,
                                     slots_per_replica=spr, **common)
    if config.snapshot_path and not config.multihost:
        eng.snapshot_path = config.snapshot_path
    return eng
