"""Mesh-distributed serving: data+tensor-parallel ``ShardedServeEngine``.

The engine extends the single-device ``ServeEngine`` scheduler core to a
jax device mesh with axes ``('data', 'model')``:

  * **data axis - replicas.**  The pooled KV/conv/SSM cache's slot axis is
    sharded over 'data' (``distributed/sharding.serve_pool_specs``): each
    of the ``data`` replicas owns a contiguous block of
    ``slots_per_replica`` cache rows.  ``prefill_many``, ``prefill_chunk``,
    ``cache_scatter`` and the decode step run as ONE shard_map-ed SPMD
    program spanning every replica - inside the body each replica executes
    the single-device program on its own slot block, so replica numerics
    match the single-device engine computing that block.  One qualifier:
    MoE expert capacity is sized from the LOCAL token count (spr rows, not
    the pool), so under a capacity_factor tight enough to drop tokens the
    drops can differ from a pool-wide batch - the same caveat class as
    batch-size-dependent capacity on one device (DESIGN.md Sec. 4);
    parity is exact while capacity absorbs the routing, which the default
    factors guarantee.
  * **model axis - tensor parallelism.**  Inside the shard_map body,
    ``kernels/ops.tp_shard`` column-splits every PDQ / fp projection over
    'model': the PDQ prologue's per-row scales (and surrogate moments) are
    computed locally on each shard (they are O(K) per row and every shard
    needs them), each shard runs the grouped W8A8 matmul over its N-column
    block with its slice of the per-(row, N-block) interval epilogue, and
    a tiled all-gather reassembles the columns.  Every output column is
    the identical full-K int8 accumulation + epilogue the single-device
    kernel runs, so quantized numerics stay bit-exact.
  * **coordinator.**  Admission stays a host-side singleton (the scheduler
    core): one pending queue, bucket-grouped FIFO admits, and per-bucket
    routing of admits to the least-loaded replicas (``_assign``).  One
    admission round = one SPMD prefill launch that lands every replica's
    admits at once; replicas with fewer admits carry dummy rows the
    scatter drops.  ``src_map`` is replica-local by the scheduler-core
    convention, so the per-replica scatter blocks resolve correctly.

CPU CI exercises the whole engine on a virtual mesh via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (see
tests/test_serve_sharded.py).

The scheduler itself lives in ``serve/core.py`` (plan builders + result
appliers); this class only rebinds the three exec hooks' device programs
to shard_map-ed equivalents.  ``serve/multihost.py`` extends THIS engine
to real ``jax.distributed`` multi-process meshes by shipping the plans
to worker processes.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import pool_shardings, serve_pool_specs
from repro.kernels import ops
from repro.models.context import shard_map

from .engine import DEFAULT_BUCKETS, ServeEngine


class ShardedServeEngine(ServeEngine):
    """ServeEngine over a ('data', 'model') mesh.

    ``slots_per_replica`` rows per data-parallel replica (total pool =
    ``data * slots_per_replica`` slots); params are replicated over the
    mesh and tensor-parallel execution splits projection columns over
    'model' at trace time, so one weight buffer layout serves any mesh
    shape.
    """

    def __init__(self, cfg, params, *, mesh, slots_per_replica: int = 4,
                 max_len: int = 256, quantize_weights: bool = False,
                 temperature: float = 0.0, rng: jax.Array | None = None,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 chunked_prefill: bool = False, fault=None,
                 pdq_fallback: bool = False, paged: bool = False,
                 page_size: int = 64, pool_pages: int | None = None,
                 prefix_sharing: bool = True, spill: bool = False,
                 telemetry: bool = True, trace: bool = False, tel=None):
        assert {"data", "model"} <= set(mesh.axis_names), mesh.axis_names
        assert not spill, (
            "host spill is single-device only: the capture/restore hooks "
            "address the pool globally, not through the mesh sharding")
        self.mesh = mesh
        self.data_size = int(mesh.shape["data"])
        self.model_size = int(mesh.shape["model"])
        super().__init__(cfg, params, slots=self.data_size * slots_per_replica,
                         max_len=max_len, quantize_weights=quantize_weights,
                         temperature=temperature, rng=rng, buckets=buckets,
                         batch_prefill=True, chunked_prefill=chunked_prefill,
                         n_replicas=self.data_size, fault=fault,
                         pdq_fallback=pdq_fallback, paged=paged,
                         page_size=page_size, pool_pages=pool_pages,
                         prefix_sharing=prefix_sharing,
                         telemetry=telemetry, trace=trace, tel=tel)

    # ------------------------------------------------------- device programs
    def _sharded(self, fn, in_specs, out_specs, tel: bool = False):
        """shard_map(fn) over the mesh with TP (and, when enabled, the
        per-shard PDQ->fp fallback guard) active inside the body.

        ``tel=True`` additionally opens the pdq telemetry collector INSIDE
        the body (the TP/guard context is per-shard, so the collector must
        be too) and psums the (3,) health summary over both mesh axes: the
        launch returns ``(out, summary)`` with the summary replicated, so
        the coordinator reads fleet totals off the same device sync as the
        sampled tokens."""
        T = self.model_size
        guard = self.pdq_fallback
        collect = bool(tel) and self.tel.enabled

        def body(*args):
            with ops.tp_shard("model", T), ops.pdq_guard(guard), \
                    ops.pdq_telemetry(collect) as col:
                out = fn(*args)
                if not tel:
                    return out
                return out, jax.lax.psum(col.summary(), ("data", "model"))

        specs = (out_specs, P()) if tel else out_specs
        return shard_map(body, mesh=self.mesh, in_specs=in_specs,
                         out_specs=specs, check_vma=False)

    def _traced_sharded_jit(self, fn, counter: str, in_specs, out_specs,
                            donate: tuple[int, ...] = (), tel: bool = False):
        stats = self.stats
        mapped = self._sharded(fn, in_specs, out_specs, tel=tel)

        def wrapped(*args):
            if counter:
                stats[counter] += 1      # trace-time side effect
            return mapped(*args)

        return jax.jit(wrapped, donate_argnums=donate)

    def _build_jitted(self):
        cs = serve_pool_specs(self.caches)
        dp = P("data")                       # slot/batch axis over replicas
        self._decode = self._traced_sharded_jit(
            self.bundle.decode_step, "decode_compiles",
            in_specs=(P(), cs, dp, dp), out_specs=(dp, cs), tel=True)
        self._prefill_many = self._traced_sharded_jit(
            self.bundle.prefill_many, "prefill_compiles",
            in_specs=(P(), dp, cs, dp), out_specs=(dp, cs), tel=True)
        self._prefill_chunk = self._traced_sharded_jit(
            self.bundle.prefill_chunk, "chunk_compiles",
            in_specs=(P(), dp, cs, dp, dp), out_specs=(dp, cs), tel=True)
        self._scatter = self._traced_sharded_jit(
            self.bundle.cache_scatter, None,
            in_specs=(cs, cs, dp), out_specs=cs, donate=(0,))
        # the legacy per-request path is single-replica only (asserted in
        # the scheduler core); no _prefill_one on the mesh.
        self._prefill_one = None
        if self.paged:
            self._build_paged_jitted()

        # place the long-lived buffers once: params replicated over the
        # whole mesh, cache pools with their slot axis over 'data' (later
        # launches then never re-transfer them from the host).  The paged
        # pool's leading axis is PAGES, not slots, but serve_pool_specs
        # shards that same axis over 'data' - each replica owns its
        # pool_pages block, matching the scheduler's replica-local page ids.
        self.params = jax.device_put(self.params,
                                     NamedSharding(self.mesh, P()))
        pool_sh = pool_shardings(self.mesh, self.caches)
        self.caches = jax.device_put(self.caches, pool_sh)
        self._prefill_pool = jax.device_put(
            self._prefill_pool,
            pool_shardings(self.mesh, self._prefill_pool))

    def _build_paged_jitted(self):
        """Paged-pool programs as ONE shard_map-ed SPMD launch each: the
        plan ships replica-LOCAL page ids, the 'data' split hands every
        replica its own pool-page block + its rows of the maps, and the
        body runs the identical single-device gather/step/writeback (or
        land / copy) on local indices."""
        po = self._paged_ops
        step = self.bundle.decode_step
        cs = serve_pool_specs(self.caches)
        dp = P("data")
        pts = P("data", None)                # (slots, n_pp) page tables

        def decode_paged(params, pool, pt, tokens, positions):
            logical = po.gather(pool, pt, positions[:, 0])
            logits, logical = step(params, logical, tokens, positions)
            return logits, po.writeback(pool, logical, pt, positions)

        self._decode_paged = self._traced_sharded_jit(
            decode_paged, "decode_compiles",
            in_specs=(P(), cs, pts, dp, dp), out_specs=(dp, cs),
            donate=(1,), tel=True)
        self._land = self._traced_sharded_jit(
            po.land, None, in_specs=(cs, cs, dp, dp, dp), out_specs=cs,
            donate=(0,))
        self._page_copy = self._traced_sharded_jit(
            po.copy, None, in_specs=(cs, dp), out_specs=cs, donate=(0,))
