"""Mesh-distributed serving: data+tensor-parallel ``ShardedServeEngine``.

The engine extends the single-device ``ServeEngine`` scheduler core to a
jax device mesh with axes ``('data', 'model')``:

  * **data axis - replicas.**  The pooled KV/conv/SSM cache's slot axis is
    sharded over 'data' (``distributed/sharding.serve_pool_specs``): each
    of the ``data`` replicas owns a contiguous block of
    ``slots_per_replica`` cache rows.  ``prefill_many``, ``prefill_chunk``,
    ``cache_scatter`` and the decode step run as ONE shard_map-ed SPMD
    program spanning every replica - inside the body each replica executes
    the single-device program on its own slot block, so replica numerics
    match the single-device engine computing that block.  One qualifier:
    MoE expert capacity is sized from the LOCAL token count (spr rows, not
    the pool), so under a capacity_factor tight enough to drop tokens the
    drops can differ from a pool-wide batch - the same caveat class as
    batch-size-dependent capacity on one device (DESIGN.md Sec. 4);
    parity is exact while capacity absorbs the routing, which the default
    factors guarantee.
  * **model axis - tensor parallelism.**  Inside the shard_map body,
    ``kernels/ops.tp_shard`` column-splits every PDQ / fp projection over
    'model': the PDQ prologue's per-row scales (and surrogate moments) are
    computed locally on each shard (they are O(K) per row and every shard
    needs them), each shard runs the grouped W8A8 matmul over its N-column
    block with its slice of the per-(row, N-block) interval epilogue, and
    a tiled all-gather reassembles the columns.  Every output column is
    the identical full-K int8 accumulation + epilogue the single-device
    kernel runs, so quantized numerics stay bit-exact.
  * **coordinator.**  Admission stays a host-side singleton (the scheduler
    core): one pending queue, bucket-grouped FIFO admits, and per-bucket
    routing of admits to the least-loaded replicas (``_assign``).  One
    admission round = one SPMD prefill launch that lands every replica's
    admits at once; replicas with fewer admits carry dummy rows the
    scatter drops.  ``src_map`` is replica-local by the scheduler-core
    convention, so the per-replica scatter blocks resolve correctly.

CPU CI exercises the whole engine on a virtual mesh via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (see
tests/test_serve_sharded.py).

The scheduler itself lives in ``serve/core.py`` (plan builders + result
appliers); this class only rebinds the three exec hooks' device programs
to shard_map-ed equivalents.  ``serve/multihost.py`` extends THIS engine
to real ``jax.distributed`` multi-process meshes by shipping the plans
to worker processes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import pool_shardings, serve_pool_specs
from repro.kernels import ops
from repro.models.context import shard_map

from .engine import DEFAULT_BUCKETS, ServeEngine, decode_scan


class ShardedServeEngine(ServeEngine):
    """ServeEngine over a ('data', 'model') mesh.

    ``slots_per_replica`` rows per data-parallel replica (total pool =
    ``data * slots_per_replica`` slots); params are replicated over the
    mesh and tensor-parallel execution splits projection columns over
    'model' at trace time, so one weight buffer layout serves any mesh
    shape.
    """

    def __init__(self, cfg, params, *, mesh, slots_per_replica: int = 4,
                 max_len: int = 256, quantize_weights: bool = False,
                 temperature: float = 0.0, rng: jax.Array | None = None,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 chunked_prefill: bool = False, decode_steps: int = 1,
                 fault=None,
                 pdq_fallback: bool = False, paged: bool = False,
                 page_size: int = 64, pool_pages: int | None = None,
                 prefix_sharing: bool = True, spill: bool = False,
                 telemetry: bool = True, trace: bool = False, tel=None):
        assert {"data", "model"} <= set(mesh.axis_names), mesh.axis_names
        assert not spill, (
            "host spill is single-device only: the capture/restore hooks "
            "address the pool globally, not through the mesh sharding")
        self.mesh = mesh
        self.data_size = int(mesh.shape["data"])
        self.model_size = int(mesh.shape["model"])
        super().__init__(cfg, params, slots=self.data_size * slots_per_replica,
                         max_len=max_len, quantize_weights=quantize_weights,
                         temperature=temperature, rng=rng, buckets=buckets,
                         batch_prefill=True, chunked_prefill=chunked_prefill,
                         decode_steps=decode_steps,
                         n_replicas=self.data_size, fault=fault,
                         pdq_fallback=pdq_fallback, paged=paged,
                         page_size=page_size, pool_pages=pool_pages,
                         prefix_sharing=prefix_sharing,
                         telemetry=telemetry, trace=trace, tel=tel)

    # ------------------------------------------------------- device programs
    def _sharded(self, fn, in_specs, out_specs, tel: bool = False):
        """shard_map(fn) over the mesh with TP (and, when enabled, the
        per-shard PDQ->fp fallback guard) active inside the body.

        ``tel=True`` additionally opens the pdq telemetry collector INSIDE
        the body (the TP/guard context is per-shard, so the collector must
        be too) and psums the (3,) health summary over both mesh axes: the
        launch returns ``(out, summary)`` with the summary replicated, so
        the coordinator reads fleet totals off the same device sync as the
        sampled tokens."""
        T = self.model_size
        guard = self.pdq_fallback
        collect = bool(tel) and self.tel.enabled

        def body(*args):
            with ops.tp_shard("model", T), ops.pdq_guard(guard), \
                    ops.pdq_telemetry(collect) as col:
                out = fn(*args)
                if not tel:
                    return out
                return out, jax.lax.psum(col.summary(), ("data", "model"))

        specs = (out_specs, P()) if tel else out_specs
        return shard_map(body, mesh=self.mesh, in_specs=in_specs,
                         out_specs=specs, check_vma=False)

    def _traced_sharded_jit(self, fn, counter: str, in_specs, out_specs,
                            donate: tuple[int, ...] = (), tel: bool = False,
                            out_shardings=None):
        stats = self.stats
        mapped = self._sharded(fn, in_specs, out_specs, tel=tel)

        def wrapped(*args):
            if counter:
                stats[counter] += 1      # trace-time side effect
            return mapped(*args)

        kw = {} if out_shardings is None else {"out_shardings": out_shardings}
        return jax.jit(wrapped, donate_argnums=donate, **kw)

    def _traced_decode_sharded(self, fn, in_specs, donate: tuple[int, ...],
                               out_shardings=None):
        """shard_map + jit for the fused decode block (the sharded analogue
        of ServeEngine._traced_decode).  ``fn`` is a decode_scan-shaped
        body returning (toks, ok, state, tel): telemetry is collected
        INSIDE the scan (per iteration, per shard), so this wrapper only
        opens tp_shard/pdq_guard around it and psums the block-summed
        (3,) health vector over both mesh axes.  Sampling runs in-body:
        each replica samples its OWN slot block with the per-(uid, step)
        keys - the per-row keys make that bit-identical to global
        sampling, and the launch returns (slots, N) int32 tokens instead
        of gathering a replicated (slots, vocab) logits batch."""
        T = self.model_size
        guard = self.pdq_fallback
        dp = P("data")

        def body(*args):
            with ops.tp_shard("model", T), ops.pdq_guard(guard):
                toks, ok, state, tel = fn(*args)
            return toks, ok, state, jax.lax.psum(tel, ("data", "model"))

        mapped = shard_map(body, mesh=self.mesh, in_specs=in_specs,
                           out_specs=(dp, dp, serve_pool_specs(self.caches),
                                      P()),
                           check_vma=False)
        stats = self.stats

        def wrapped(*args):
            stats["decode_compiles"] += 1      # trace-time side effect
            return mapped(*args)

        kw = {} if out_shardings is None else {"out_shardings": out_shardings}
        return jax.jit(wrapped, donate_argnums=donate, **kw)

    def _sampled_prefill(self, fn):
        """Wrap a prefill-shaped body so it samples in-body: each replica
        samples its own rows right where the logits live, so the launch
        ships (slots,) tokens + ok flags instead of (slots, vocab) logits.
        fn(params, *args) -> (logits, sub) becomes
        wrapped(rng, params, *args, uids, steps) -> (toks, ok, sub)."""
        sample = self._sample_fn()

        def wrapped(rng, params, *rest):
            *args, uids, steps = rest
            logits, sub = fn(params, *args)
            toks, ok = sample(rng, logits, uids, steps)
            return toks, ok, sub

        return wrapped

    def _build_jitted(self):
        cs = serve_pool_specs(self.caches)
        dp = P("data")                       # slot/batch axis over replicas
        # N-step fused decode: scan + in-body sampling, one dispatch per
        # token BLOCK (see engine.decode_scan); state/tokens/positions/row
        # metadata all split over 'data', rng + params replicated
        self._decode = self._traced_decode_sharded(
            decode_scan(self.bundle.decode_step, self._sample_fn(),
                        self.decode_steps, self.tel.enabled),
            in_specs=(P(), P(), cs, dp, dp, dp, dp, dp), donate=())
        self._prefill_many = self._traced_sharded_jit(
            self._sampled_prefill(self.bundle.prefill_many),
            "prefill_compiles",
            in_specs=(P(), P(), dp, cs, dp, dp, dp), out_specs=(dp, dp, cs),
            tel=True)
        self._prefill_chunk = self._traced_sharded_jit(
            self._sampled_prefill(self.bundle.prefill_chunk),
            "chunk_compiles",
            in_specs=(P(), P(), dp, cs, dp, dp, dp, dp),
            out_specs=(dp, dp, cs), tel=True)
        self._scatter = self._traced_sharded_jit(
            self.bundle.cache_scatter, None,
            in_specs=(cs, cs, dp), out_specs=cs, donate=(0,))
        # the legacy per-request path is single-replica only (asserted in
        # the scheduler core); no _prefill_one on the mesh.
        self._prefill_one = None
        if self.paged:
            self._build_paged_jitted()

        # place the long-lived buffers once: params replicated over the
        # whole mesh, cache pools with their slot axis over 'data' (later
        # launches then never re-transfer them from the host).  The paged
        # pool's leading axis is PAGES, not slots, but serve_pool_specs
        # shards that same axis over 'data' - each replica owns its
        # pool_pages block, matching the scheduler's replica-local page ids.
        self.params = jax.device_put(self.params,
                                     NamedSharding(self.mesh, P()))
        pool_sh = pool_shardings(self.mesh, self.caches)
        self.caches = jax.device_put(self.caches, pool_sh)
        self._prefill_pool = jax.device_put(
            self._prefill_pool,
            pool_shardings(self.mesh, self._prefill_pool))

    def _build_paged_jitted(self):
        """Paged-pool programs as ONE shard_map-ed SPMD launch each: the
        plan ships replica-LOCAL page ids, the 'data' split hands every
        replica its own pool-page block + its rows of the maps, and the
        body runs the identical single-device gather/step/writeback (or
        land / copy) on local indices."""
        po = self._paged_ops
        cs = serve_pool_specs(self.caches)
        dp = P("data")
        pts = P("data", None)                # (slots, n_pp) page tables
        self._decode_paged = self._traced_decode_sharded(
            self._paged_decode_fn(),
            in_specs=(P(), P(), cs, pts, dp, dp, dp, dp, dp),
            donate=(2,))
        self._land = self._traced_sharded_jit(
            po.land, None, in_specs=(cs, cs, dp, dp, dp), out_specs=cs,
            donate=(0,))
        self._page_copy = self._traced_sharded_jit(
            po.copy, None, in_specs=(cs, dp), out_specs=cs, donate=(0,))

    # ------------------------------------------------------------ exec hooks
    # prefill sampling runs in-body on the mesh (each replica samples its
    # own rows), so the launch protocol differs from the single-device
    # engine's host-side _sample_rows: tokens/ok come back directly and
    # fault poisoning flips the ok rows host-side instead of NaN-ing logits
    def _exec_prefill(self, plan, extras):
        batch = self._extras_batch({"tokens": jnp.asarray(plan.tokens)},
                                   extras)
        (toks, ok, sub), tel = self._prefill_many(
            self.rng, self.params, batch, self._prefill_pool,
            jnp.asarray(plan.seq_lens),
            jnp.asarray(plan.row_uids, jnp.int32),
            jnp.asarray(plan.row_steps, jnp.int32))
        self._land_sub(plan, sub)
        self._observe_pdq(tel)
        ok = self._poison_ok("prefill", plan, np.asarray(ok))
        return np.asarray(toks), ok

    def _exec_chunked(self, plan, extras):
        if extras:
            raise NotImplementedError(
                "chunked prefill is text-only (no vision/encdec extras)")
        uids = jnp.asarray(plan.row_uids, jnp.int32)
        steps = jnp.asarray(plan.row_steps, jnp.int32)
        _, tokens, seq_lens = plan.first
        (toks, ok, sub), tel = self._prefill_many(
            self.rng, self.params, {"tokens": jnp.asarray(tokens)},
            self._prefill_pool, jnp.asarray(seq_lens), uids, steps)
        for _, tokens, seq_lens, start_lens in plan.chunks:
            # intermediate chunks sample throwaway tokens (same per-row
            # keys, discarded logits) - only the final chunk's row matters
            (toks, ok, sub), t2 = self._prefill_chunk(
                self.rng, self.params, {"tokens": jnp.asarray(tokens)}, sub,
                jnp.asarray(seq_lens), jnp.asarray(start_lens), uids, steps)
            tel = tel + t2        # lazy device add: one fetch per launch set
        self._land_sub(plan, sub)
        self._observe_pdq(tel)
        ok = self._poison_ok("chunked", plan, np.asarray(ok))
        return np.asarray(toks), ok
