"""Streaming serving service: continuous admission over a SchedulerCore.

``run()`` is a run-to-drain library loop - fine for batch jobs, useless as
a front door: requests arrive continuously, clients hang up, queues grow
without bound.  ``ServeService`` wraps ANY serving engine (single-device,
sharded, or the multi-host coordinator) in a background step-loop thread
that admits from the pending queue EVERY round, with a thread-safe
submit/result handoff:

  * ``submit()`` validates, applies the overload watermark (a bounded
    admission queue: past ``max_pending`` queued requests the submit is
    SHED with a typed ``OverloadedError`` -> HTTP 429 + Retry-After,
    counted in ``engine.stats['shed']`` - pending never grows without
    bound), then hands the request to the loop thread.  The caller gets a
    ``TokenStream``.
  * per-uid token streams are fed from the scheduler's own apply path
    (``SchedulerCore.on_token``/``on_finish`` observers fire inside
    ``_apply_prefill``/``_apply_chunked``/``_apply_decode``), so the
    streamed tokens are EXACTLY the engine's tokens: sampling keys are
    per-(uid, step), which makes a continuously-admitted stream
    token-for-token equal to the same request through batch ``run()``.
  * cancellation (client disconnect, per-request deadline, slow consumer)
    propagates into the scheduler as the first-class ``cancel(uid)``:
    queued cancels apply at the next round boundary, evicting only their
    own request through the PR-6 isolation path - peers stay bit-exact.
    With the N-step decode fast path (``decode_steps > 1``) a "round" is
    one DISPATCH of up to N tokens per row: cancels, deadline sweeps and
    stream flushes quantize to dispatch boundaries (a mid-block cancel
    still delivers the block's already-sampled tokens first, exactly the
    tokens an N=1 engine would have produced), and peer streams stay
    token-identical because sampling keys are per-(uid, step).
  * a stalled consumer cannot wedge the fleet: stream buffers are bounded
    (``max_stream_buffer``) and an overflowing stream cancels ITS request
    with a ``slow_consumer`` finish, nothing else.
  * ``request_drain()`` (SIGTERM/SIGINT path) stops the loop at a round
    boundary: every unfinished request's stream gets a typed ``drain``
    finish event, the scheduler snapshot is written (``snapshot_path``),
    and ``--resume`` requeues the work token-exactly.

Ingress faults (burst, mid-stream disconnect, slow reader) are injectable
through the engine's ``FaultInjector`` so overload behaviour is
deterministically testable (distributed/fault.py).
"""
from __future__ import annotations

import collections
import threading
import time

import numpy as np

from repro.distributed.fault import save_snapshot

from .core import EngineDraining, Request

__all__ = ["OverloadedError", "ServeService", "TokenStream"]


class OverloadedError(RuntimeError):
    """Admission watermark exceeded: the request was shed (HTTP 429)."""

    def __init__(self, pending: int, watermark: int, retry_after: float):
        self.retry_after = float(retry_after)
        super().__init__(
            f"admission queue at {pending} >= watermark {watermark}: "
            f"request shed, retry after {retry_after:g}s")


class TokenStream:
    """Thread-safe per-request token/finish buffer bridging the scheduler
    thread to a consumer (HTTP handler, test, or nobody).

    The producer side (``push_*``) is called on the scheduler loop thread
    and never blocks: a consumer that stops draining past ``max_buffer``
    undelivered tokens marks the stream overflowed, and the service
    cancels the request (``slow_consumer``) instead of stalling the fleet.
    Consumers either poll ``drain()`` with a waker (the SSE path) or block
    on ``result()``."""

    def __init__(self, uid: int, max_buffer: int = 512):
        self.uid = uid
        self.max_buffer = int(max_buffer)
        self._lock = threading.Lock()
        self._buf: list[int] = []
        self._finish: tuple[str, str | None] | None = None
        self._wakers: list = []
        self.overflowed = False
        self.submitted_at = time.perf_counter()
        self.first_token_at: float | None = None

    # ------------------------------------------------------------- producer
    def _notify(self, wakers) -> None:
        # wakers are advisory: a consumer whose event loop already closed
        # (an SSE handler racing shutdown) must not crash the scheduler
        # thread - its request finishes or drains regardless
        for w in wakers:
            try:
                w()
            except Exception:
                pass

    def push_token(self, tok: int) -> bool:
        """Append one token; False = the bounded buffer overflowed (the
        token is dropped and the stream is marked; the service cancels)."""
        with self._lock:
            if self._finish is not None or self.overflowed:
                return True                     # already closed: ignore
            if len(self._buf) >= self.max_buffer:
                self.overflowed = True
                return False
            if self.first_token_at is None:
                self.first_token_at = time.perf_counter()
            self._buf.append(int(tok))
            wakers = list(self._wakers)
        self._notify(wakers)
        return True

    def push_finish(self, reason: str, error: str | None) -> None:
        with self._lock:
            if self._finish is None:
                self._finish = (reason, error)
            wakers = list(self._wakers)
        self._notify(wakers)

    # ------------------------------------------------------------- consumer
    def add_waker(self, fn) -> None:
        """Register a zero-arg callable fired (outside the lock) after
        every push; pair with ``drain()``: clear-then-drain-then-wait."""
        with self._lock:
            self._wakers.append(fn)

    def drain(self) -> tuple[list[int], tuple[str, str | None] | None]:
        """Take every undelivered token; the finish tuple (reason, error)
        rides along once the request left the engine, else None."""
        with self._lock:
            toks, self._buf = self._buf, []
            return toks, self._finish

    @property
    def finished(self) -> bool:
        with self._lock:
            return self._finish is not None

    def result(self, timeout: float | None = None
               ) -> tuple[list[int], str, str | None]:
        """Block until the request finishes; returns
        ``(tokens, finish_reason, error)``."""
        ev = threading.Event()
        self.add_waker(ev.set)
        deadline = None if timeout is None else time.monotonic() + timeout
        toks: list[int] = []
        while True:
            ev.clear()
            got, fin = self.drain()
            toks.extend(got)
            if fin is not None:
                return toks, fin[0], fin[1]
            left = None if deadline is None else deadline - time.monotonic()
            if left is not None and left <= 0:
                raise TimeoutError(
                    f"request uid={self.uid} unfinished after {timeout:g}s")
            ev.wait(left)


class ServeService:
    """Continuous-admission driver: one background thread owns the
    scheduler; submits, cancels and drain requests cross over thread-safe
    queues applied at round boundaries (the scheduler itself stays
    single-threaded, exactly as under ``run()``)."""

    def __init__(self, engine, *, max_pending: int = 32,
                 retry_after: float = 0.5, max_stream_buffer: int = 512,
                 idle_wait: float = 0.05, extras=None):
        self.engine = engine
        self.max_pending = int(max_pending)
        self.retry_after = float(retry_after)
        self.max_stream_buffer = int(max_stream_buffer)
        self.idle_wait = float(idle_wait)
        self.extras = extras
        engine.on_token = self._on_token
        engine.on_finish = self._on_finish
        self._mutex = threading.Lock()      # ingress/cancel/stream tables
        self._ingress: collections.deque[Request] = collections.deque()
        self._cancels: collections.deque[tuple[int, str, str]] = \
            collections.deque()
        self._streams: dict[int, TokenStream] = {}
        self._next_uid = 0
        self._wake = threading.Event()
        self._stopped = threading.Event()
        self._thread: threading.Thread | None = None
        self.error: BaseException | None = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ServeService":
        assert self._thread is None, "service already started"
        self._thread = threading.Thread(target=self._loop_guarded,
                                        name="serve-loop", daemon=True)
        self._thread.start()
        return self

    def request_drain(self) -> None:
        """SIGTERM/SIGINT path: stop at the next round boundary; unfinished
        streams get a typed ``drain`` finish and the snapshot is written."""
        self.engine.request_drain()
        self._wake.set()

    def join(self, timeout: float | None = None) -> None:
        self._stopped.wait(timeout)
        if self._thread is not None:
            self._thread.join(timeout)

    def stop(self, timeout: float | None = 60.0) -> None:
        self.request_drain()
        self.join(timeout)
        if self.error is not None:
            raise self.error

    @property
    def draining(self) -> bool:
        return self.engine.drained

    # ------------------------------------------------------------ admission
    def _pending_total(self) -> int:
        return len(self._ingress) + len(self.engine.pending)

    def submit(self, prompt, *, max_new: int = 16,
               deadline_s: float | None = None, uid: int | None = None,
               stream: bool = True) -> TokenStream | Request:
        """Thread-safe submit from any thread.  Raises ``EngineDraining``
        once a drain was requested (HTTP 503), ``OverloadedError`` past the
        admission watermark (HTTP 429), ``ValueError`` for malformed or
        oversized prompts (HTTP 400).  Returns the request's
        ``TokenStream`` (or, with ``stream=False``, the bare ``Request`` -
        a headless submit nobody consumes, used by burst injection)."""
        eng = self.engine
        p = np.asarray(prompt)
        if p.ndim != 1 or p.size == 0 or not np.issubdtype(p.dtype,
                                                           np.integer):
            raise ValueError(
                f"malformed prompt: shape {p.shape}, dtype {p.dtype} "
                "(need a non-empty 1-D integer array)")
        eng._validate(int(p.size))          # oversized prompts: reject here
        eng._validate_extras(int(p.size), self.extras)
        deadline = (None if deadline_s is None
                    else eng._clock() + float(deadline_s))
        with self._mutex:
            if eng.drained or self._stopped.is_set():
                raise EngineDraining(
                    "service is draining: new submissions are rejected")
            if self._pending_total() >= self.max_pending:
                with eng.stats_lock:
                    eng.stats["shed"] += 1
                if eng.tel.enabled:
                    eng.tel.shed.inc()
                raise OverloadedError(self._pending_total(),
                                      self.max_pending, self.retry_after)
            if uid is None:
                uid = self._next_uid
            self._next_uid = max(self._next_uid, uid + 1)
            req = Request(uid=uid, prompt=p.astype(np.int32),
                          max_new=int(max_new), deadline=deadline)
            if eng.tel.enabled:
                # queue-wait/TTFT clock starts at ACCEPTANCE, not at the
                # loop thread's pickup - the client is waiting from here
                req.submitted_at = time.perf_counter()
            if stream:
                cap = eng.fault.stream_cap(uid)
                tstream = TokenStream(
                    uid, cap if cap is not None else self.max_stream_buffer)
                self._streams[uid] = tstream
            self._ingress.append(req)
        self._wake.set()
        return tstream if stream else req

    def cancel(self, uid: int, *, kind: str = "cancel",
               reason: str = "cancelled by client") -> None:
        """Queue a cancellation; the loop applies it at the next round
        boundary (pending: dropped; in-flight: evicted alone)."""
        with self._mutex:
            self._cancels.append((uid, kind, reason))
        self._wake.set()

    def stats(self) -> dict:
        # stats_snapshot copies under the engine's stats lock: the loop
        # thread mutates counters (and list cells) while HTTP handlers
        # serialize, so an unlocked dict/list walk could see a partially
        # updated structure mid-scrape
        eng = self.engine
        out = eng.stats_snapshot()
        out.update(round=eng._round, pending=self._pending_total(),
                   active=sum(r is not None for r in eng.active),
                   free_slots=eng._free_total(), slots=eng.slots,
                   draining=eng.drained, watermark=self.max_pending)
        return out

    def events(self) -> list[dict]:
        """The structured failure/eviction/preemption/straggler event
        ring, snapshot under the stats lock (JSONL via /v1/events)."""
        return self.engine.events_snapshot()

    def metrics_text(self) -> str:
        """Prometheus text exposition of the engine's metric registry."""
        return self.engine.tel.metrics.render()

    def trace(self) -> dict:
        """The Chrome-trace-event object collected so far."""
        return self.engine.tel.tracer.export()

    # ------------------------------------------------------ engine observers
    # called ON the scheduler loop thread, inside the _apply_* paths
    def _on_token(self, req: Request, tok: int) -> None:
        eng = self.engine
        if eng.fault.drop_stream(req.uid, len(req.generated)):
            # injected mid-stream client disconnect (deterministic tests)
            self._cancels.append((req.uid, "disconnect",
                                  "injected mid-stream disconnect"))
            return
        stream = self._streams.get(req.uid)
        if stream is None:
            return                      # headless request (burst / resume)
        if not stream.push_token(tok):
            self._cancels.append(
                (req.uid, "slow_consumer",
                 f"stream buffer overflowed ({stream.max_buffer} "
                 "undelivered tokens): consumer stalled"))

    def _on_finish(self, req: Request) -> None:
        with self._mutex:
            stream = self._streams.pop(req.uid, None)
        if stream is not None:
            stream.push_finish(req.finish_reason or "complete", req.error)

    # ------------------------------------------------------------- the loop
    def _loop_guarded(self) -> None:
        try:
            try:
                self._loop()
            except BaseException as e:  # noqa: B036 - must release consumers
                self.error = e
                self.engine._fleet_abort(e)
                self._close_streams("failed", f"service loop died: {e!r}")
                raise
        finally:
            # unconditionally: a raise INSIDE the release path above must
            # still unblock join()ers, or shutdown hangs forever
            self._stopped.set()

    def _loop(self) -> None:
        eng = self.engine
        while True:
            if eng.drained:
                break
            eng.fault.on_round(eng._round)
            for prompt, max_new in eng.fault.ingress_burst(eng._round):
                try:                    # injected bursts go through the
                    self.submit(prompt, max_new=max_new, stream=False)
                except OverloadedError:
                    pass                # watermark like everything else
            if eng.drained:
                break
            # multi-host residual: worker-side submits ride the ack exchange
            # as queue counts; pull any announced requests into the queue
            # (no-op [] on single-process engines)
            for req in eng.poll_ingress():
                eng.pending.append(req)
            with self._mutex:
                while self._ingress:
                    eng.pending.append(self._ingress.popleft())
                cancels = list(self._cancels)
                self._cancels.clear()
            for uid, kind, reason in cancels:
                eng.cancel(uid, kind=kind, reason=reason)
            eng._expire_deadlines()
            admitted = 0
            if eng.pending and eng._free_total():
                admitted = eng._admit(self.extras)
            n_active = eng.step()
            if admitted or n_active:
                eng._round += 1
                continue
            # idle: block until a submit/cancel/drain wakes the loop
            # (clear-then-check: a submit between the clear and the wait
            # has already appended to ingress, so the check catches it)
            self._wake.clear()
            with self._mutex:
                busy = bool(self._ingress or self._cancels)
            if not busy and not eng.drained:
                self._wake.wait(self.idle_wait)
        self._drain_epilogue()

    def _drain_epilogue(self) -> None:
        eng = self.engine
        with self._mutex:
            # accepted-but-not-yet-queued ingress rides the snapshot too:
            # those submits were acknowledged, they must not vanish
            while self._ingress:
                eng.pending.append(self._ingress.popleft())
        self._close_streams("drain", None)
        if eng.snapshot_path:
            save_snapshot(eng.snapshot_path, eng.snapshot())

    def _close_streams(self, reason: str, error: str | None) -> None:
        with self._mutex:
            streams, self._streams = self._streams, {}
        for stream in streams.values():
            stream.push_finish(reason, error)
