"""Jit'd / pjit'd train step construction.

``build_train_step(bundle, mesh, opt_cfg)`` returns a step function compiled
with full in/out shardings: params 2-D (FSDP x TP) sharded, optimizer states
inheriting param specs (int8 moment states shard their flat block dim over
the whole mesh), batch over the DP axes.  The state buffer is donated.

The same builder (mesh=None) yields a plain single-device jit step for CPU
tests and small examples.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import context as mctx
from repro.optim import adamw, schedule


def make_state(bundle, opt_cfg: adamw.AdamWConfig, rng):
    params = bundle.init(rng)
    opt = adamw.init(params, opt_cfg)
    return {"params": params, "opt": opt}


def abstract_state(bundle, opt_cfg: adamw.AdamWConfig):
    """ShapeDtypeStruct state for the dry-run (no allocation)."""
    return jax.eval_shape(lambda: make_state(bundle, opt_cfg, jax.random.PRNGKey(0)))


def state_specs(state, mesh):
    """PartitionSpecs for the train state: params by rule; fp32 moments
    inherit param specs; int8 moment codes/scales are last-axis blocked
    (param_spec on the leading dims, replicated block dims) so the
    quantized optimizer never moves data across devices."""
    pspecs = shd.param_specs(state["params"], mesh)

    all_sizes = {a: int(mesh.shape[a]) for a in mesh.axis_names}

    def _ax_size(e):
        if e is None:
            return 1
        if isinstance(e, tuple):
            n = 1
            for a in e:
                n *= all_sizes[a]
            return n
        return all_sizes[e]

    def q8_spec(pspec, leaf):
        # param (..., D) -> codes (..., G, B) / scales (..., G, 1): keep the
        # leading entries; the last param dim's sharding moves to the block-
        # group dim G (valid when G divides - per-shard slices of D are
        # multiples of _BLOCK across the zoo).
        entries = list(pspec) if len(pspec) else []
        last = entries[-1] if entries else None
        entries = entries[:-1] if entries else []
        G = leaf.shape[-2] if leaf.ndim >= 2 else 1
        if last is not None and G % _ax_size(last) == 0:
            entries = entries + [last, None]
        entries += [None] * (leaf.ndim - len(entries))
        return P(*entries[: leaf.ndim])

    opt = state["opt"]
    if opt.m_scale is None:
        mspec, vspec = pspecs, pspecs
        ms_spec = vs_spec = None
    else:
        mspec = jax.tree.map(q8_spec, pspecs, opt.m)
        vspec = jax.tree.map(q8_spec, pspecs, opt.v)
        ms_spec = jax.tree.map(q8_spec, pspecs, opt.m_scale)
        vs_spec = jax.tree.map(q8_spec, pspecs, opt.v_scale)
    opt_spec = adamw.OptState(step=P(), m=mspec, v=vspec,
                              m_scale=ms_spec, v_scale=vs_spec)
    return {"params": pspecs, "opt": opt_spec}


def loss_and_grads(bundle, params, batch):
    def lf(p):
        loss, metrics = bundle.train_loss(p, batch)
        return loss, metrics

    (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
    return loss, metrics, grads


def make_step_fn(bundle, opt_cfg: adamw.AdamWConfig, sched, microbatch=None):
    """The raw (un-jitted) train step; dryrun lowers it with explicit
    shardings, build_train_step wraps it in jit."""

    def step(state, batch):
        params = state["params"]
        if microbatch and microbatch > 1:
            def mb(carry, sub):
                loss, metrics, grads = loss_and_grads(bundle, params, sub)
                acc = jax.tree.map(jnp.add, carry, grads)
                return acc, (loss, metrics)

            sub_batches = jax.tree.map(
                lambda x: x.reshape(microbatch, x.shape[0] // microbatch,
                                    *x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metricss) = jax.lax.scan(mb, zeros, sub_batches)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricss)
        else:
            loss, metrics, grads = loss_and_grads(bundle, params, batch)
        lr_scale = sched(state["opt"].step)
        new_params, new_opt = adamw.apply_updates(params, grads, state["opt"],
                                                  opt_cfg, lr_scale)
        metrics = dict(metrics, loss=loss,
                       grad_norm=adamw.global_norm(grads), lr_scale=lr_scale)
        return {"params": new_params, "opt": new_opt}, metrics

    return step


def build_train_step(
    bundle,
    opt_cfg: adamw.AdamWConfig,
    mesh=None,
    *,
    lr_schedule: Callable = None,
    microbatch: int | None = None,
    donate: bool = True,
):
    """Returns (step_fn, state_sharding_tree | None).

    step_fn(state, batch) -> (state, metrics).  With ``microbatch`` set, the
    batch is split and gradients accumulate over a lax.scan (overlapping the
    DP gradient reduction with the next microbatch's compute).
    """
    sched = lr_schedule or (lambda s: schedule.warmup_cosine(s))
    step = make_step_fn(bundle, opt_cfg, sched, microbatch)

    if mesh is None:
        return jax.jit(step, donate_argnums=(0,) if donate else ()), None

    st = abstract_state(bundle, opt_cfg)
    sspec = state_specs(st, mesh)
    state_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), sspec,
                                   is_leaf=lambda x: isinstance(x, P))

    def batch_shardings(batch_tree):
        spec = shd.batch_spec(mesh, batch_tree)
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                            is_leaf=lambda x: isinstance(x, P))

    step_fn = jax.jit(
        step,
        in_shardings=(state_shardings, None),
        out_shardings=(state_shardings, NamedSharding(mesh, P())),
        donate_argnums=(0,) if donate else (),
    )
    return step_fn, state_shardings


def dist_context_for(mesh) -> mctx.DistContext:
    """MoE EP context matching the production mesh."""
    dp = shd.dp_axes(mesh)
    return mctx.DistContext(mesh=mesh, token_axes=dp + ("model",),
                            expert_axis="model", data_axes=dp,
                            model_axis="model")
