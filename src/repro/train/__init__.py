from . import train_step, trainer
from .train_step import build_train_step, dist_context_for, make_state
from .trainer import Trainer, TrainerConfig
