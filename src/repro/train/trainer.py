"""Fault-tolerant training loop.

Production behaviors (all exercised by tests):
  * checkpoint/restart: periodic async checkpoints; on ANY step failure the
    loop restores the latest checkpoint and replays - the data pipeline is a
    pure function of the step index, so replay is exact.
  * failure injection: ``failure_hook(step)`` may raise to simulate
    preemption/node loss.
  * straggler watchdog: a step-time EMA; steps slower than
    ``straggler_factor`` x EMA are counted and surfaced in metrics (on a real
    fleet this feeds the scheduler's drain/replace decision; see
    distributed/fault.py for the resharding half).
  * elastic restart: checkpoints store full logical arrays + step, so a
    restart may use a different mesh (re-layout happens at load).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, make_source
from repro.optim import adamw
from .train_step import build_train_step, make_state


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    max_restarts: int = 5


class Trainer:
    def __init__(self, bundle, opt_cfg: adamw.AdamWConfig, data_cfg: DataConfig,
                 tcfg: TrainerConfig, mesh=None, rng=None,
                 failure_hook: Callable[[int], None] | None = None):
        self.bundle = bundle
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.failure_hook = failure_hook
        self.step_fn, self.state_shardings = build_train_step(
            bundle, opt_cfg, mesh)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.history: list[dict[str, Any]] = []
        self.restarts = 0
        self.straggler_steps = 0

    # ----------------------------------------------------------------- state
    def _fresh_state(self):
        return make_state(self.bundle, self.opt_cfg, self.rng)

    def _restore_or_init(self):
        template = jax.eval_shape(self._fresh_state)
        tree, meta = self.ckpt.restore(jax.tree.map(
            lambda s: np.zeros(s.shape, s.dtype), template))
        if tree is None:
            return self._fresh_state(), 0
        state = jax.tree.map(jax.numpy.asarray, tree)
        return state, int(meta["step"])

    # ------------------------------------------------------------------ loop
    def train(self) -> dict[str, Any]:
        source = make_source(self.data_cfg)
        state, start_step = self._restore_or_init()
        step = start_step
        ema = None
        while step < self.tcfg.total_steps:
            try:
                batch_np = source.batch_at(step)
                batch = jax.tree.map(jax.numpy.asarray, batch_np)
                if self.failure_hook is not None:
                    self.failure_hook(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                ema = dt if ema is None else 0.9 * ema + 0.1 * dt
                if dt > self.tcfg.straggler_factor * ema:
                    self.straggler_steps += 1
                step += 1
                if step % self.tcfg.log_every == 0 or step == self.tcfg.total_steps:
                    rec = {k: float(v) for k, v in metrics.items()}
                    rec.update(step=step, sec_per_step=dt)
                    self.history.append(rec)
                if step % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(step, state, {"step": step})
            except (KeyboardInterrupt,):
                raise
            except Exception as e:   # preemption / injected failure / OOM
                self.restarts += 1
                if self.restarts > self.tcfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.tcfg.max_restarts}") from e
                state, step = self._restore_or_init()
        self.ckpt.save(self.tcfg.total_steps, state, {"step": step}, block=True)
        self.ckpt.wait()
        return {"state": state, "history": self.history,
                "restarts": self.restarts,
                "straggler_steps": self.straggler_steps,
                "final_loss": self.history[-1]["loss"] if self.history else None}
