"""Checkpoint IO: pytree <-> sharded .npz with atomic rename + integrity.

Layout per checkpoint directory:
    step_<N>/
      meta.json            - step, tree structure, sharding metadata, digest
      shard_<host>.npz     - this host's param shards (addressable data only)

Multi-host posture: each host writes only the leaves (or leaf slices) it is
addressable for; on restore, hosts read their shard and the runtime
re-assembles global arrays via the target sharding (elastic resharding: the
target mesh may differ from the source mesh - see distributed/fault.py).
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Any

import jax
import numpy as np
from jax.tree_util import tree_flatten_with_path, tree_unflatten


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def flatten_with_names(tree) -> tuple[list[tuple[str, Any]], Any]:
    leaves, treedef = tree_flatten_with_path(tree)
    return [(_path_str(p), v) for p, v in leaves], treedef


def save_pytree(tree, directory: str, *, host_id: int = 0, extra_meta: dict | None = None):
    """Atomic save: write to tmp dir, fsync, rename."""
    os.makedirs(os.path.dirname(directory) or ".", exist_ok=True)
    tmp = tempfile.mkdtemp(dir=os.path.dirname(directory) or ".",
                           prefix=".tmp_ckpt_")
    named, _ = flatten_with_names(tree)
    arrays = {}
    digest = hashlib.sha256()
    for name, leaf in named:
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            arr = arr.astype(np.float32)      # npz can't round-trip bf16
        arrays[name] = arr
        digest.update(name.encode())
        digest.update(arr.tobytes()[:4096])   # prefix digest: cheap integrity
    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **arrays)
    meta = {
        "names": [n for n, _ in named],
        "digest": digest.hexdigest(),
        **(extra_meta or {}),
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(directory):
        os.rename(directory, directory + ".old")
    os.rename(tmp, directory)
    if os.path.exists(directory + ".old"):
        import shutil
        shutil.rmtree(directory + ".old", ignore_errors=True)


def load_pytree(template, directory: str, *, host_id: int = 0):
    """Restore into the structure of ``template`` (shapes may be resharded
    downstream); verifies the integrity digest."""
    with open(os.path.join(directory, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(directory, f"shard_{host_id}.npz"))
    named, treedef = flatten_with_names(template)
    digest = hashlib.sha256()
    out = []
    for name, leaf in named:
        arr = data[name]
        digest.update(name.encode())
        digest.update(arr.tobytes()[:4096])
        out.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    if digest.hexdigest() != meta["digest"]:
        raise IOError(f"checkpoint digest mismatch in {directory}")
    return tree_unflatten(jax.tree.structure(template), out), meta
