from . import io, manager
from .manager import CheckpointManager
