"""Checkpoint manager: rotation, latest-resume, async background writes."""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any

import jax

from .io import load_pytree, save_pytree

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3, async_write: bool = True,
                 host_id: int = 0):
        self.root = root
        self.keep = keep
        self.async_write = async_write
        self.host_id = host_id
        self._pending: threading.Thread | None = None
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------- queries
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.root, name, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step}")

    # --------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra_meta: dict | None = None,
             block: bool = False):
        """Device arrays are fetched synchronously (cheap vs. train step);
        serialization + fsync happen on a background thread."""
        self.wait()
        fetched = jax.tree.map(lambda x: jax.device_get(x), tree)
        meta = dict(extra_meta or {}, step=step)

        def work():
            save_pytree(fetched, self._dir(step), host_id=self.host_id,
                        extra_meta=meta)
            self._gc()

        if self.async_write and not block:
            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()
        else:
            work()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    # ------------------------------------------------------------- restore
    def restore(self, template: Any, step: int | None = None):
        """Returns (tree, meta) from ``step`` or the latest checkpoint."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        return load_pytree(template, self._dir(step), host_id=self.host_id)
