"""Calibration driver (paper Sec. 4.1 / 5.2).

Runs the model in *observe* mode over a small calibration set (the paper uses
16 images), capturing every quantized layer's pre-activations and PDQ moment
predictions, then fits:

* the static output ranges  (static-quantization baseline), and
* the PDQ interval parameters (alpha, beta) via coverage quantiles (Eq. 13).

Both baselines and our method deliberately share the same calibration data,
exactly as in the paper.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from . import interval as interval_mod
from .policy import QuantSpec, as_observe

# Cap on pooled deviation samples per layer (memory bound, deterministic).
_MAX_DEV_SAMPLES = 1 << 16

ApplyFn = Callable[..., Any]  # apply(params, batch, *, spec, qstate, tape) -> out


def _subsample(a: np.ndarray, limit: int) -> np.ndarray:
    if a.shape[0] <= limit:
        return a
    stride = int(np.ceil(a.shape[0] / limit))
    return a[::stride]


def calibrate(
    apply_fn: ApplyFn,
    params: Any,
    batches: Iterable[Any],
    spec: QuantSpec,
) -> dict[str, dict[str, jax.Array]]:
    """Returns the per-layer quantization state pytree used at inference."""
    obs_spec = as_observe(spec)
    ranges: dict[str, list[tuple[np.ndarray, np.ndarray]]] = {}
    devs: dict[str, list[np.ndarray]] = {}
    kinds: dict[str, str] = {}

    for batch in batches:
        tape: dict[str, Any] = {}
        apply_fn(params, batch, spec=obs_spec, qstate={}, tape=tape)
        for name, rec in tape.items():
            y = np.asarray(rec["y"], np.float32)
            kinds[name] = rec["kind"]
            pol = spec.resolve(name)
            # --- static range (min/max over everything but channels) ---
            if pol.per_channel and rec["kind"] != "input":
                axes = tuple(range(y.ndim - 1))
                lo, hi = y.min(axis=axes), y.max(axis=axes)
            else:
                lo, hi = np.float32(y.min()), np.float32(y.max())
            ranges.setdefault(name, []).append((lo, hi))
            # --- PDQ deviations ---
            m = rec.get("moments")
            if m is not None:
                mean = np.asarray(m.mean, np.float32)
                sigma = np.sqrt(np.maximum(np.asarray(m.var, np.float32), 0.0)) + 1e-8
                if pol.per_channel:
                    # mean/sigma: (B, C); y: (B, pos..., C)
                    bshape = (y.shape[0],) + (1,) * (y.ndim - 2) + (y.shape[-1],)
                    u = (y - mean.reshape(bshape)) / sigma.reshape(bshape)
                    u = u.reshape(-1, y.shape[-1])
                else:
                    bshape = (y.shape[0],) + (1,) * (y.ndim - 1)
                    u = (y - mean.reshape(bshape)) / sigma.reshape(bshape)
                    u = u.reshape(-1, 1)
                devs.setdefault(name, []).append(_subsample(u, _MAX_DEV_SAMPLES))

    qstate: dict[str, dict[str, jax.Array]] = {}
    for name, rr in ranges.items():
        los = np.stack([r[0] for r in rr])
        his = np.stack([r[1] for r in rr])
        entry: dict[str, jax.Array] = {
            "static_lo": jnp.asarray(los.min(axis=0)),
            "static_hi": jnp.asarray(his.max(axis=0)),
        }
        if name in devs:
            pol = spec.resolve(name)
            u = np.concatenate(devs[name], axis=0)
            u = _subsample(u, 4 * _MAX_DEV_SAMPLES)
            ip = interval_mod.calibrate_alpha_beta(
                u, target_coverage=pol.coverage,
                channel_axis=1 if pol.per_channel else None,
            )
            if not pol.per_channel:
                ip = interval_mod.IntervalParams(ip.alpha.reshape(()), ip.beta.reshape(()))
            else:
                # small-sample guard: a channel's quantile from few pooled
                # positions (e.g. dense layers: 1/row/image) undershoots the
                # range and clips; floor each channel at the per-tensor fit.
                ip_t = interval_mod.calibrate_alpha_beta(
                    u, target_coverage=pol.coverage, channel_axis=None)
                ip = interval_mod.IntervalParams(
                    alpha=jnp.maximum(ip.alpha, ip_t.alpha),
                    beta=jnp.maximum(ip.beta, ip_t.beta))
            entry["alpha"] = ip.alpha
            entry["beta"] = ip.beta
        qstate[name] = entry
    return qstate
