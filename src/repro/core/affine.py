"""Uniform affine quantization primitives (paper Eqs. 1-4).

Convention note: the paper's Eq. (3) computes ``z = -round(m/s) - 2^{b-1}``
(a *signed*-grid zero point, matching the CMSIS-NN ``_s8`` kernels the paper
wraps) while Eq. (1) clamps to the unsigned range ``[0, 2^b - 1]``.  The two
are inconsistent as written; we follow the signed-grid convention throughout
(grid ``[-2^{b-1}, 2^{b-1} - 1]``), which makes Q(m) = -2^{b-1} and
Q(M) = 2^{b-1} - 1 exact.  Symmetric quantization is the special case z = 0.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

_EPS = 1e-12


class QParams(NamedTuple):
    """Affine quantization parameters.

    ``scale`` / ``zero_point`` are either scalars (per-tensor) or arrays
    broadcastable against the tensor being quantized (per-channel).
    ``bits`` is static (python int) so it never triggers retracing.
    """

    scale: jax.Array
    zero_point: jax.Array
    bits: int = 8

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1))

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1


def storage_dtype(bits: int):
    return jnp.int8 if bits <= 8 else jnp.int32


def qparams_from_range(m: jax.Array, M: jax.Array, bits: int = 8) -> QParams:
    """Paper Eq. (3): scale / zero-point from an observed [m, M] range."""
    m = jnp.minimum(m, 0.0)  # range must include 0 so that 0 is exactly representable
    M = jnp.maximum(M, 0.0)
    scale = (M - m) / (2**bits - 1)
    scale = jnp.maximum(scale, _EPS)
    zero_point = (-jnp.round(m / scale) - 2 ** (bits - 1)).astype(jnp.int32)
    return QParams(scale=scale, zero_point=zero_point, bits=bits)


def symmetric_qparams_from_amax(amax: jax.Array, bits: int = 8) -> QParams:
    """Symmetric special case: z = 0, scale from the absolute max."""
    scale = jnp.maximum(amax, _EPS) / (2 ** (bits - 1) - 1)
    return QParams(scale=scale, zero_point=jnp.zeros_like(scale, jnp.int32), bits=bits)


def quantize(x: jax.Array, qp: QParams) -> jax.Array:
    """Paper Eq. (1): clamp(round(x / s) + z, qmin, qmax)."""
    q = jnp.round(x / qp.scale) + qp.zero_point
    q = jnp.clip(q, qp.qmin, qp.qmax)
    return q.astype(storage_dtype(qp.bits))


def dequantize(q: jax.Array, qp: QParams, dtype=jnp.float32) -> jax.Array:
    """Paper Eq. (4): x ~= s * (q - z)."""
    return (q.astype(jnp.int32) - qp.zero_point).astype(dtype) * qp.scale.astype(dtype)


def fake_quant(x: jax.Array, qp: QParams) -> jax.Array:
    """Quantize-dequantize roundtrip (simulated integer inference)."""
    return dequantize(quantize(x, qp), qp, dtype=x.dtype)


def _reduce_axes(ndim: int, channel_axis: int | None):
    if channel_axis is None:
        return tuple(range(ndim))
    channel_axis = channel_axis % ndim
    return tuple(a for a in range(ndim) if a != channel_axis)


def range_of(x: jax.Array, channel_axis: int | None = None) -> tuple[jax.Array, jax.Array]:
    """(min, max) per-tensor (channel_axis=None) or per-channel (keepdims)."""
    axes = _reduce_axes(x.ndim, channel_axis)
    return jnp.min(x, axis=axes, keepdims=channel_axis is not None), jnp.max(
        x, axis=axes, keepdims=channel_axis is not None
    )


def dynamic_qparams(x: jax.Array, bits: int = 8, channel_axis: int | None = None) -> QParams:
    """Dynamic quantization parameters: measure the range of ``x`` on the fly.

    This is the paper's "dynamic" baseline - it requires ``x`` to be fully
    materialized before its range is known (the O(b' * h) memory overhead the
    paper's method removes).
    """
    m, M = range_of(x, channel_axis)
    return qparams_from_range(m, M, bits)


def weight_qparams(w: jax.Array, bits: int = 8, channel_axis: int | None = None) -> QParams:
    """Weights are always quantized offline (both paper baselines and ours)."""
    return dynamic_qparams(w, bits=bits, channel_axis=channel_axis)
