"""Probabilistic surrogate of layer pre-activation moments (paper Sec. 4.1).

The surrogate assumes weights are i.i.d. Gaussian, W_ij ~ N(mu_W, sigma_W^2)
(per-tensor) or per output channel (per-channel).  Then for y = W x:

    E[y_j]   = mu_W[j]      * sum_i x_i        (Eq. 8)
    Var[y_j] = sigma_W[j]^2 * sum_i x_i^2      (Eq. 9)

so a single O(d) pass over the *input* prices the whole output's dynamic
range - the output never needs to be materialized at higher precision.

For convolutions, per-output-position estimates come from windowed sums of x
and x^2 (Eqs. 10-11), computed here as a convolution with a ones-kernel over
the channel-summed input.  Per-position / per-token estimates are aggregated
into per-tensor or per-channel statistics with the law of total variance
(Eq. 12; see DESIGN.md for the typo reconciliation):

    E[y]   = mean_pos E[y_pos]
    Var[y] = mean_pos Var[y_pos] + mean_pos (E[y_pos] - E[y])^2

The ``gamma`` *sampling stride* subsamples positions entering the estimate -
quadratic cost reduction for conv feature maps, linear for token sequences.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


class Moments(NamedTuple):
    """Predicted output moments. Shapes:

    per-tensor:  mean/var are (batch,)            - one interval per example
    per-channel: mean/var are (batch, channels)   - one interval per channel
    """

    mean: jax.Array
    var: jax.Array

    @property
    def std(self) -> jax.Array:
        return jnp.sqrt(jnp.maximum(self.var, 0.0))


class WeightStats(NamedTuple):
    """Offline per-layer weight statistics (computed once at deploy time)."""

    mu: jax.Array   # () per-tensor or (out_channels,) per-channel
    var: jax.Array  # same shape
    fan_in: int


def weight_stats(w: jax.Array, reduce_axes: tuple[int, ...], per_channel: bool) -> WeightStats:
    """Gaussian fit of the weights. ``reduce_axes`` are the fan-in axes.

    For a linear layer with w of shape (d, h), reduce_axes=(0,) keeps the
    output-channel axis.  per_channel=False additionally pools channels.
    """
    axes = tuple(range(w.ndim)) if not per_channel else reduce_axes
    mu = jnp.mean(w, axis=axes)
    var = jnp.var(w, axis=axes)
    fan_in = 1
    for a in reduce_axes:
        fan_in *= w.shape[a]
    return WeightStats(mu=mu, var=var, fan_in=int(fan_in))


def _aggregate(mean_pos: jax.Array, var_pos: jax.Array, axes: tuple[int, ...]) -> Moments:
    """Law-of-total-variance aggregation over position axes (Eq. 12)."""
    mean = jnp.mean(mean_pos, axis=axes)
    var = jnp.mean(var_pos, axis=axes) + jnp.mean(
        (mean_pos - jnp.expand_dims(mean, axes)) ** 2, axis=axes
    )
    return Moments(mean=mean, var=var)


# ---------------------------------------------------------------------------
# Linear / token-stack layers (Eqs. 8-9)
# ---------------------------------------------------------------------------


def linear_moments(
    x: jax.Array,
    ws: WeightStats,
    per_channel: bool,
    gamma: int = 1,
) -> Moments:
    """Surrogate moments of y = x @ W for x of shape (batch, ..., d).

    Any axes between batch and the feature axis are "positions" (tokens,
    pixels); ``gamma`` subsamples them with a stride.  Cost: O(d) per sampled
    position, independent of the output width h - this is the paper's
    headline complexity result.
    """
    if x.ndim > 2 and gamma > 1:
        x = x[:, ::gamma]
    s1 = jnp.sum(x, axis=-1)                  # (batch, pos...)
    s2 = jnp.sum(jnp.square(x), axis=-1)      # (batch, pos...)
    pos_axes = tuple(range(1, s1.ndim))
    if per_channel:
        mean_pos = s1[..., None] * ws.mu      # (batch, pos..., h)
        var_pos = s2[..., None] * ws.var
        if pos_axes:
            return _aggregate(mean_pos, var_pos, pos_axes)
        return Moments(mean=mean_pos, var=var_pos)
    mean_pos = s1 * ws.mu                     # scalar weight stats
    var_pos = s2 * ws.var
    if pos_axes:
        return _aggregate(mean_pos, var_pos, pos_axes)
    return Moments(mean=mean_pos, var=var_pos)


# ---------------------------------------------------------------------------
# Convolutions (Eqs. 10-11), NHWC layout
# ---------------------------------------------------------------------------


def conv_window_sums(
    x: jax.Array,
    kernel_hw: tuple[int, int],
    stride: tuple[int, int],
    padding: str,
    gamma: int = 1,
) -> tuple[jax.Array, jax.Array]:
    """Windowed sums S1 = sum_window x and S2 = sum_window x^2, NHWC input.

    Channel-independent: we first pool channels, then convolve with a ones
    kernel.  ``gamma`` multiplies the stride (the paper's sampling stride:
    positions sampled drop as gamma^-2).
    """
    kh, kw = kernel_hw
    sh, sw = stride
    xs = jnp.sum(x, axis=-1, keepdims=True)            # (N, H, W, 1)
    xs2 = jnp.sum(jnp.square(x), axis=-1, keepdims=True)
    ones = jnp.ones((kh, kw, 1, 1), x.dtype)
    dn = lax.conv_dimension_numbers(xs.shape, ones.shape, ("NHWC", "HWIO", "NHWC"))
    strides = (sh * gamma, sw * gamma)
    s1 = lax.conv_general_dilated(xs, ones, strides, padding, dimension_numbers=dn)
    s2 = lax.conv_general_dilated(xs2, ones, strides, padding, dimension_numbers=dn)
    return s1[..., 0], s2[..., 0]                      # (N, H', W')


def conv_moments(
    x: jax.Array,
    ws: WeightStats,
    kernel_hw: tuple[int, int],
    stride: tuple[int, int],
    padding: str,
    per_channel: bool,
    gamma: int = 1,
) -> Moments:
    """Surrogate moments for conv pre-activations (Eqs. 10-12)."""
    s1, s2 = conv_window_sums(x, kernel_hw, stride, padding, gamma)  # (N, H', W')
    if per_channel:
        mean_pos = s1[..., None] * ws.mu   # (N, H', W', C_out)
        var_pos = s2[..., None] * ws.var
        return _aggregate(mean_pos, var_pos, (1, 2))
    mean_pos = s1 * ws.mu
    var_pos = s2 * ws.var
    return _aggregate(mean_pos, var_pos, (1, 2))


def empirical_moments(y: jax.Array, per_channel: bool) -> Moments:
    """Ground-truth moments of an observed pre-activation tensor.

    Used by tests / calibration to validate the surrogate: y has shape
    (batch, pos..., channels).
    """
    if per_channel:
        axes = tuple(range(1, y.ndim - 1))
    else:
        axes = tuple(range(1, y.ndim))
    return Moments(mean=jnp.mean(y, axis=axes), var=jnp.var(y, axis=axes))


def depthwise_conv_moments(
    x: jax.Array,
    ws: WeightStats,
    kernel_hw: tuple[int, int],
    stride: tuple[int, int],
    padding: str,
    per_channel: bool,
    gamma: int = 1,
) -> Moments:
    """Surrogate moments for DEPTHWISE conv: output channel v sees only
    input channel v, so windowed sums are computed per channel (p=1 in
    Eqs. 10-11)."""
    kh, kw = kernel_hw
    sh, sw = stride
    C = x.shape[-1]
    ones = jnp.ones((kh, kw, 1, C), x.dtype)
    dn = lax.conv_dimension_numbers(x.shape, ones.shape, ("NHWC", "HWIO", "NHWC"))
    strides = (sh * gamma, sw * gamma)
    s1 = lax.conv_general_dilated(x, ones, strides, padding,
                                  dimension_numbers=dn, feature_group_count=C)
    s2 = lax.conv_general_dilated(jnp.square(x), ones, strides, padding,
                                  dimension_numbers=dn, feature_group_count=C)
    mean_pos = s1 * ws.mu          # (N, H', W', C) * () or (C,)
    var_pos = s2 * ws.var
    if per_channel:
        return _aggregate(mean_pos, var_pos, (1, 2))
    return _aggregate(mean_pos, var_pos, (1, 2, 3))
