"""Per-layer quantization policy resolution."""
from __future__ import annotations

import dataclasses
import re
from typing import Literal

Mode = Literal["none", "static", "dynamic", "pdq", "observe"]


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """How a layer's output (pre-activation) is quantized.

    mode:          'static' | 'dynamic' | 'pdq' | 'none' (fp passthrough)
    bits:          quantization bit-width (paper uses 8 throughout)
    per_channel:   per-channel vs per-tensor output/weight quantization
    gamma:         sampling stride for the PDQ moment estimate (Sec. 4.2)
    coverage:      target coverage for I(alpha, beta) calibration (Eq. 13)
    integer_path:  route through int8 kernels (serving) vs fake-quant emulation
    """

    mode: Mode = "pdq"
    bits: int = 8
    per_channel: bool = True
    gamma: int = 1
    coverage: float = 0.9995
    integer_path: bool = False


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Model-level quantization spec: a default policy + per-layer overrides.

    ``overrides`` maps regex patterns on layer names to policies; first match
    wins.  Layers matching ``skip`` regexes stay in full precision (the usual
    practice for e.g. routers / first & last layers).
    """

    default: QuantPolicy = dataclasses.field(default_factory=QuantPolicy)
    overrides: tuple[tuple[str, QuantPolicy], ...] = ()
    skip: tuple[str, ...] = ()

    def resolve(self, layer_name: str) -> QuantPolicy:
        for pat in self.skip:
            if re.search(pat, layer_name):
                return dataclasses.replace(self.default, mode="none")
        for pat, pol in self.overrides:
            if re.search(pat, layer_name):
                return pol
        return self.default


FP32 = QuantSpec(default=QuantPolicy(mode="none"))


def as_observe(spec: QuantSpec) -> QuantSpec:
    """Calibration variant of a spec: same layers, but capture instead of quantize."""
    def obs(p: QuantPolicy) -> QuantPolicy:
        return p if p.mode == "none" else dataclasses.replace(p, mode="observe")

    return QuantSpec(
        default=obs(spec.default),
        overrides=tuple((pat, obs(p)) for pat, p in spec.overrides),
        skip=spec.skip,
    )


def spec_for_mode(mode: Mode, per_channel: bool = True, gamma: int = 1,
                  bits: int = 8, integer_path: bool = False,
                  skip: tuple[str, ...] = ()) -> QuantSpec:
    return QuantSpec(
        default=QuantPolicy(mode=mode, per_channel=per_channel, gamma=gamma,
                            bits=bits, integer_path=integer_path),
        skip=skip,
    )
