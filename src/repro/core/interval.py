"""Asymmetric coverage intervals I(alpha, beta) and their calibration (Eq. 13).

The quantization range of a pre-activation tensor is taken to be

    I(alpha, beta) = [mu_y - alpha * sigma_y,  mu_y + beta * sigma_y]

with (mu_y, sigma_y) predicted by the surrogate (surrogate.py) per input.
(alpha, beta) are tuned once on a calibration set so that a target fraction
of the observed pre-activations falls inside I, then frozen (paper Sec. 4.1).

Calibration here uses the direct quantile method: with normalized deviations
u = (y - mu)/sigma pooled over the calibration set,

    alpha = -quantile(u, (1 - coverage)/2)
    beta  =  quantile(u, 1 - (1 - coverage)/2)

which is the smallest interval of the I(alpha,beta) family achieving the
coverage target on the calibration data - equivalent to (and cheaper than)
the paper's grid search over (alpha, beta).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .affine import QParams, qparams_from_range
from .surrogate import Moments

_SIGMA_FLOOR = 1e-8


class IntervalParams(NamedTuple):
    """Per-layer frozen (alpha, beta); scalars or (channels,) arrays."""

    alpha: jax.Array
    beta: jax.Array


def interval(moments: Moments, ip: IntervalParams) -> tuple[jax.Array, jax.Array]:
    """I(alpha, beta) bounds from predicted moments."""
    sigma = jnp.maximum(moments.std, _SIGMA_FLOOR)
    return moments.mean - ip.alpha * sigma, moments.mean + ip.beta * sigma


def qparams_from_interval(moments: Moments, ip: IntervalParams, bits: int = 8) -> QParams:
    """PDQ quantization parameters: Eq. (3) applied to I(alpha, beta).

    The scale tracks the *predicted dispersion* of this input's
    pre-activations; the zero-point tracks their predicted mean.
    """
    lo, hi = interval(moments, ip)
    return qparams_from_range(lo, hi, bits)


def coverage(y: jax.Array, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """Empirical coverage P(y in I) (Eq. 13).

    ``lo``/``hi`` must be broadcastable against y (per-example or
    per-example-per-channel intervals).
    """
    inside = (y >= lo) & (y <= hi)
    return jnp.mean(inside.astype(jnp.float32))


def calibrate_alpha_beta(
    deviations: np.ndarray | jax.Array,
    target_coverage: float = 0.9995,
    channel_axis: int | None = None,
) -> IntervalParams:
    """Fit (alpha, beta) from pooled normalized deviations u = (y - mu)/sigma.

    ``deviations`` is the pooled array over the calibration set.  With
    ``channel_axis`` set, a per-channel (alpha, beta) pair is fit (all other
    axes pooled); otherwise a single scalar pair.
    """
    u = np.asarray(deviations, np.float64)
    tail = (1.0 - target_coverage) / 2.0
    if channel_axis is not None:
        u = np.moveaxis(u, channel_axis, -1).reshape(-1, u.shape[channel_axis])
        lo_q = np.quantile(u, tail, axis=0)
        hi_q = np.quantile(u, 1.0 - tail, axis=0)
    else:
        lo_q = np.quantile(u, tail)
        hi_q = np.quantile(u, 1.0 - tail)
    # alpha scales the *downward* extent; never collapse below a tiny margin.
    alpha = np.maximum(-lo_q, 1e-3)
    beta = np.maximum(hi_q, 1e-3)
    return IntervalParams(alpha=jnp.asarray(alpha, jnp.float32), beta=jnp.asarray(beta, jnp.float32))


def grid_search_alpha_beta(
    deviations: np.ndarray,
    target_coverage: float = 0.9995,
    grid: np.ndarray | None = None,
) -> IntervalParams:
    """Paper-literal grid search over symmetric-step (alpha, beta) values.

    Kept for fidelity / ablation; `calibrate_alpha_beta` is the default.
    Picks the narrowest interval whose empirical coverage >= target.
    """
    u = np.asarray(deviations, np.float64).ravel()
    if grid is None:
        grid = np.linspace(0.5, 12.0, 47)
    best = (np.inf, grid[-1], grid[-1])
    for a in grid:
        for b in grid:
            cov = np.mean((u >= -a) & (u <= b))
            if cov >= target_coverage and (a + b) < best[0]:
                best = (a + b, a, b)
    _, a, b = best
    return IntervalParams(alpha=jnp.float32(a), beta=jnp.float32(b))
