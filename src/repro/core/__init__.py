"""repro.core - the paper's contribution: probabilistic dynamic quantization.

Public surface:
    affine      - uniform affine quantization (Eqs. 1-4)
    surrogate   - pre-activation moment surrogates (Eqs. 8-12)
    interval    - I(alpha, beta) + coverage calibration (Eq. 13)
    policy      - per-layer quantization policies / specs
    qlinear     - quantized dense/conv execution (static | dynamic | pdq)
    calibrate   - shared calibration driver
"""
from . import affine, calibrate, interval, policy, qlinear, surrogate
from .affine import QParams, dequantize, dynamic_qparams, fake_quant, qparams_from_range, quantize
from .calibrate import calibrate as run_calibration
from .interval import IntervalParams, calibrate_alpha_beta, coverage, qparams_from_interval
from .policy import FP32, QuantPolicy, QuantSpec, spec_for_mode
from .surrogate import Moments, WeightStats, conv_moments, empirical_moments, linear_moments, weight_stats

__all__ = [
    "affine", "calibrate", "interval", "policy", "qlinear", "surrogate",
    "QParams", "quantize", "dequantize", "fake_quant", "qparams_from_range", "dynamic_qparams",
    "IntervalParams", "coverage", "calibrate_alpha_beta", "qparams_from_interval",
    "QuantPolicy", "QuantSpec", "FP32", "spec_for_mode",
    "Moments", "WeightStats", "weight_stats", "linear_moments", "conv_moments",
    "empirical_moments", "run_calibration",
]
