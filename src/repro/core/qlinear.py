"""Quantized layer execution: static / dynamic / PDQ output quantization.

Two execution paths share this module:

* **emulation** (default; used for all accuracy experiments, mirroring the
  paper's "custom-made quantization API ... emulating the quantization
  pipeline"): weights and pre-activations are fake-quantized in float.
* **integer** (serving / kernels): int8 x int8 -> int32 matmuls through
  ``repro.kernels.ops`` with the PDQ-predicted requantization scale supplied
  *before* the matmul runs - the TPU analogue of the paper's O(1)-memory
  claim (see DESIGN.md Sec. 2).

Layer calibration state is a plain dict-of-arrays pytree per layer name:

    {'static_lo','static_hi'  : calibrated output range     (static mode)
     'alpha','beta'           : calibrated interval params  (pdq mode)
     'in_lo','in_hi'          : calibrated *input* range    (integer path)}
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from . import affine, interval, surrogate
from .policy import QuantPolicy

Tape = dict[str, Any]


def _example_range(y: jax.Array, per_channel: bool) -> tuple[jax.Array, jax.Array]:
    """Per-example (and optionally per-channel, last axis) range, keepdims."""
    axes = tuple(range(1, y.ndim - 1 if per_channel else y.ndim))
    lo = jnp.min(y, axis=axes, keepdims=True)
    hi = jnp.max(y, axis=axes, keepdims=True)
    return lo, hi


def _broadcast_qp(qp: affine.QParams, y_ndim: int, per_channel: bool) -> affine.QParams:
    """Reshape per-example (B,) / per-example-channel (B, C) params so they
    broadcast against y of shape (B, pos..., C)."""
    def fix(a):
        a = jnp.asarray(a)
        if a.ndim == 0:
            return a
        if per_channel and a.ndim == 2:      # (B, C)
            shape = (a.shape[0],) + (1,) * (y_ndim - 2) + (a.shape[1],)
        else:                                 # (B,)
            shape = (a.shape[0],) + (1,) * (y_ndim - 1)
        return a.reshape(shape)

    return affine.QParams(fix(qp.scale), fix(qp.zero_point), qp.bits)


def bias_adjust(m: surrogate.Moments, b: jax.Array | None, per_channel: bool) -> surrogate.Moments:
    """Fold the bias into the predicted moments (E[y+b] = E[y] + b)."""
    if b is None:
        return m
    if per_channel:
        return surrogate.Moments(mean=m.mean + b, var=m.var)
    return surrogate.Moments(mean=m.mean + jnp.mean(b), var=m.var + jnp.var(b))


def quantize_weights(w: jax.Array, policy: QuantPolicy, channel_axis: int) -> jax.Array:
    """Deploy-time weight fake-quantization (all modes quantize weights)."""
    qp = affine.weight_qparams(w, bits=policy.bits,
                               channel_axis=channel_axis if policy.per_channel else None)
    return affine.fake_quant(w, qp)


def output_quantize(
    y: jax.Array,
    policy: QuantPolicy,
    state: dict[str, jax.Array] | None,
    moments: surrogate.Moments | None,
) -> jax.Array:
    """Apply the mode-dependent output (pre-activation) quantization."""
    if policy.mode == "none" or policy.mode == "observe":
        return y
    if policy.mode == "dynamic":
        # Requires the fully materialized y: the O(b'·h) overhead baseline.
        lo, hi = _example_range(y, policy.per_channel)
        qp = affine.qparams_from_range(lo, hi, policy.bits)
        return affine.fake_quant(y, qp)
    if policy.mode == "static":
        qp = affine.qparams_from_range(state["static_lo"], state["static_hi"], policy.bits)
        return affine.fake_quant(y, qp)
    if policy.mode == "pdq":
        ip = interval.IntervalParams(alpha=state["alpha"], beta=state["beta"])
        qp = interval.qparams_from_interval(moments, ip, policy.bits)
        return affine.fake_quant(y, _broadcast_qp(qp, y.ndim, policy.per_channel))
    raise ValueError(f"unknown mode {policy.mode}")


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------


def dense(
    x: jax.Array,
    w: jax.Array,                      # (d, h)
    b: jax.Array | None,
    *,
    name: str,
    policy: QuantPolicy,
    state: dict[str, Any] | None = None,
    tape: Tape | None = None,
) -> jax.Array:
    """Quantized dense pre-activation y = x @ w + b, x: (B, ..., d)."""
    if policy.mode == "none":
        y = x @ w
        return y + b if b is not None else y

    wq = quantize_weights(w, policy, channel_axis=1)
    y = x @ wq
    if b is not None:
        y = y + b

    moments = None
    if policy.mode in ("pdq", "observe"):
        ws = surrogate.weight_stats(wq, reduce_axes=(0,), per_channel=policy.per_channel)
        moments = surrogate.linear_moments(x, ws, policy.per_channel, policy.gamma)
        moments = bias_adjust(moments, b, policy.per_channel)

    if tape is not None:
        tape[name] = {"kind": "dense", "y": y, "moments": moments}
    return output_quantize(y, policy, state.get(name) if state else None, moments)


# ---------------------------------------------------------------------------
# Conv (NHWC x HWIO -> NHWC)
# ---------------------------------------------------------------------------


def conv2d(
    x: jax.Array,                      # (N, H, W, C_in)
    k: jax.Array,                      # (kh, kw, C_in, C_out)
    b: jax.Array | None,
    *,
    stride: tuple[int, int] = (1, 1),
    padding: str = "SAME",
    feature_group_count: int = 1,
    name: str,
    policy: QuantPolicy,
    state: dict[str, Any] | None = None,
    tape: Tape | None = None,
) -> jax.Array:
    """Quantized conv pre-activation."""
    dn = lax.conv_dimension_numbers(x.shape, k.shape, ("NHWC", "HWIO", "NHWC"))

    def do_conv(kk):
        y = lax.conv_general_dilated(x, kk, stride, padding, dimension_numbers=dn,
                                     feature_group_count=feature_group_count)
        return y + b if b is not None else y

    if policy.mode == "none":
        return do_conv(k)

    kq = quantize_weights(k, policy, channel_axis=3)
    y = do_conv(kq)

    moments = None
    if policy.mode in ("pdq", "observe"):
        ws = surrogate.weight_stats(kq, reduce_axes=(0, 1, 2), per_channel=policy.per_channel)
        if feature_group_count > 1 and feature_group_count == x.shape[-1]:
            # Depthwise: each output channel sees only its own input channel,
            # so the windowed sums must stay channel-separate (Eq. 10-11 with
            # p=1 per channel).
            moments = surrogate.depthwise_conv_moments(
                x, ws, k.shape[:2], stride, padding, policy.per_channel,
                policy.gamma)
        else:
            if feature_group_count > 1:
                frac = k.shape[2] / x.shape[-1]
                ws = surrogate.WeightStats(mu=ws.mu * frac, var=ws.var * frac,
                                           fan_in=ws.fan_in)
            moments = surrogate.conv_moments(x, ws, k.shape[:2], stride,
                                             padding, policy.per_channel,
                                             policy.gamma)
        moments = bias_adjust(moments, b, policy.per_channel)

    if tape is not None:
        tape[name] = {"kind": "conv", "y": y, "moments": moments}
    return output_quantize(y, policy, state.get(name) if state else None, moments)


def quantize_input(
    x: jax.Array,
    *,
    name: str = "input",
    policy: QuantPolicy,
    state: dict[str, Any] | None = None,
    tape: Tape | None = None,
) -> jax.Array:
    """Model-input quantizer (static range; all modes share it)."""
    if policy.mode == "none":
        return x
    if tape is not None:
        tape[name] = {"kind": "input", "y": x, "moments": None}
    if policy.mode == "observe" or state is None or name not in state:
        return x
    qp = affine.qparams_from_range(state[name]["static_lo"], state[name]["static_hi"], policy.bits)
    return affine.fake_quant(x, qp)
