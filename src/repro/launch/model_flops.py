"""Analytic MODEL_FLOPS per (arch x shape): the MFU denominator.

MODEL_FLOPS = useful flops only: 6*N_active*T for training (2*N fwd + 4*N
bwd), 2*N_active*T for prefill, 2*N_active*B for decode, plus causal
attention-score flops (the 6N rule excludes them):

  attn_train  = 12 * L_attn * B * S^2 * H * Dh * 0.5      (fwd+bwd, causal)
  attn_prefill=  4 * L_attn * B * S^2 * H * Dh * 0.5
  attn_decode =  4 * L_attn * B * S_ctx * H * Dh

Sliding-window layers use S_ctx = min(S, window).  N counts come from
jax.eval_shape over the real init (no allocation); MoE expert leaves are
down-weighted by top_k/E (plus shared/dense applied to all tokens).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.tree_util import tree_flatten_with_path

from repro.models import SHAPES, build_model


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def param_counts(cfg) -> dict[str, float]:
    bundle = build_model(cfg)
    params = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    leaves, _ = tree_flatten_with_path(params)
    total = expert = embed = 0
    for p, leaf in leaves:
        n = int(np.prod(leaf.shape))
        name = _path_str(p)
        total += n
        if "/we_" in name or name.endswith(("we_gate", "we_up", "we_down")):
            expert += n
        if "embedding" in name:
            embed += n
    n_active = total - expert
    if cfg.moe is not None and expert:
        n_active += expert * cfg.moe.top_k / cfg.moe.n_experts
    return {"params_total": float(total), "params_expert": float(expert),
            "active": float(n_active), "params_embed": float(embed)}


def _attn_layers(cfg) -> list[int]:
    """Effective attention context bound per layer kind instance."""
    kinds = list(cfg.head) + list(cfg.pattern) * cfg.n_blocks + list(cfg.tail)
    out = []
    for k in kinds:
        if k == "mamba":
            continue
        if k == "local":
            out.append(cfg.window or 1 << 30)
        else:
            out.append(1 << 30)
    return out


def model_flops(cfg, shape_name: str) -> dict[str, float]:
    sp = SHAPES[shape_name]
    counts = param_counts(cfg)
    N = counts["active"]
    B, S = sp.batch, sp.seq
    H, Dh = cfg.n_heads, cfg.hd
    if cfg.mla is not None:
        Dh = cfg.mla.qk_nope + cfg.mla.qk_rope

    windows = _attn_layers(cfg)
    if sp.kind == "train":
        T = B * S
        dense = 6.0 * N * T
        attn = sum(12.0 * B * min(S, w) * S * H * Dh * 0.5 for w in windows)
    elif sp.kind == "prefill":
        T = B * S
        dense = 2.0 * N * T
        attn = sum(4.0 * B * min(S, w) * S * H * Dh * 0.5 for w in windows)
    else:  # decode: one token, context S
        T = B
        dense = 2.0 * N * B
        attn = sum(4.0 * B * min(S, w) * H * Dh for w in windows)
    return {**counts, "dense": dense, "attn": attn, "total": dense + attn}
