"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --reduced --steps 100 --batch 8 --seq 128 [--mesh host]

--reduced uses the smoke-scale config of the same family (CPU-friendly);
omit it on a real pod to train the full assigned config.  --mesh host
builds a mesh over the local devices and runs the fully-sharded step
(same code path as the production mesh).
"""
from __future__ import annotations

import argparse
import json


from repro.configs import ALL_ARCHS, get_config, reduced_config
from repro.data import DataConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import build_model
from repro.models import context as mctx
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig
from repro.train.train_step import dist_context_for


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=list(ALL_ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["none", "host", "pod", "multipod"],
                    default="none")
    ap.add_argument("--quant-opt-state", action="store_true")
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    bundle = build_model(cfg)

    mesh = None
    if args.mesh == "host":
        mesh = make_host_mesh()
    elif args.mesh == "pod":
        mesh = make_production_mesh()
    elif args.mesh == "multipod":
        mesh = make_production_mesh(multi_pod=True)
    if mesh is not None:
        mctx.set_context(dist_context_for(mesh))

    trainer = Trainer(
        bundle,
        AdamWConfig(lr=args.lr, quant_state=args.quant_opt_state),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch),
        TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, log_every=10),
        mesh=mesh,
    )
    out = trainer.train()
    print(json.dumps({"history": out["history"][-5:],
                      "restarts": out["restarts"],
                      "final_loss": out["final_loss"]}, indent=1))


if __name__ == "__main__":
    main()
