"""Serving driver: bucketed batched prefill + continuous batching with the
PDQ-int8 path, single-device or mesh-distributed.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --requests 8 --max-new 16 [--int8] [--int8-kv] \
        [--buckets 32,64,128] [--legacy-prefill] [--chunked-prefill] \
        [--mesh 4x2] [--slots-per-replica 2]

``--mesh DxM`` serves over a ('data', 'model') device mesh
(ShardedServeEngine: slots data-parallel across D replicas, projection
columns tensor-parallel across M shards).  On a CPU host the driver forces
enough virtual devices automatically - this line must run before jax
imports, hence the early environ bootstrap below.
"""
from __future__ import annotations

import sys


from repro.launch.mesh import bootstrap_mesh_env

bootstrap_mesh_env(sys.argv)

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get_config, reduced_config
from repro.launch.mesh import make_serve_mesh, parse_mesh
from repro.models import build_model
from repro.serve import Request, ServeEngine, ShardedServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=list(ALL_ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--int8", action="store_true", help="PDQ int8 weights")
    ap.add_argument("--int8-kv", action="store_true", help="int8 KV cache")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prompt-len", type=int, default=8,
                    help="max prompt length (lengths are drawn in [1, this])")
    ap.add_argument("--buckets", default="32,64,128,256",
                    help="comma-separated prefill length buckets")
    ap.add_argument("--legacy-prefill", action="store_true",
                    help="per-request prefill baseline (recompiles per "
                         "distinct prompt length)")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="split prompts beyond the largest bucket into "
                         "bucket-sized chunks instead of rejecting them")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve over a data x model device mesh "
                         "(ShardedServeEngine)")
    ap.add_argument("--slots-per-replica", type=int, default=None,
                    help="cache slots per data-parallel replica "
                         "(default: --slots)")
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.int8_kv:
        cfg = dataclasses.replace(cfg, quant_kv="dynamic")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))

    buckets = tuple(int(b) for b in args.buckets.split(","))
    if args.mesh:
        if args.legacy_prefill:
            raise SystemExit("--legacy-prefill is single-device only")
        data, model = parse_mesh(args.mesh)
        mesh = make_serve_mesh(data, model)
        spr = args.slots_per_replica or args.slots
        eng = ShardedServeEngine(cfg, params, mesh=mesh,
                                 slots_per_replica=spr,
                                 max_len=args.max_len,
                                 quantize_weights=args.int8,
                                 temperature=args.temperature,
                                 buckets=buckets,
                                 chunked_prefill=args.chunked_prefill)
        mode = f"sharded {data}x{model} ({spr} slots/replica)"
    else:
        eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len,
                          quantize_weights=args.int8,
                          temperature=args.temperature, buckets=buckets,
                          batch_prefill=not args.legacy_prefill,
                          chunked_prefill=args.chunked_prefill)
        mode = "legacy" if args.legacy_prefill else "bucketed"
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(1, args.prompt_len + 1))),
                    max_new=args.max_new) for i in range(args.requests)]
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    total_new = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s) int8={args.int8} int8_kv={args.int8_kv} "
          f"prefill={mode}")
    print("  buckets:", eng.buckets)
    print("  stats:  ", {k: v for k, v in eng.stats.items()
                         if not k.startswith("replica_")})
    for r, (adm, occ) in enumerate(zip(eng.stats["replica_admits"],
                                       eng.stats["replica_occupancy"])):
        print(f"  replica {r}: admits={adm} occupied={occ}/"
              f"{eng.slots_per_replica}")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {r.generated}")


if __name__ == "__main__":
    main()
