"""Serving driver: bucketed batched prefill + continuous batching with the
PDQ-int8 path.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --requests 8 --max-new 16 [--int8] [--int8-kv] \
        [--buckets 32,64,128] [--legacy-prefill]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get_config, reduced_config
from repro.models import build_model
from repro.serve import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=list(ALL_ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--int8", action="store_true", help="PDQ int8 weights")
    ap.add_argument("--int8-kv", action="store_true", help="int8 KV cache")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prompt-len", type=int, default=8,
                    help="max prompt length (lengths are drawn in [1, this])")
    ap.add_argument("--buckets", default="32,64,128,256",
                    help="comma-separated prefill length buckets")
    ap.add_argument("--legacy-prefill", action="store_true",
                    help="per-request prefill baseline (recompiles per "
                         "distinct prompt length)")
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.int8_kv:
        cfg = dataclasses.replace(cfg, quant_kv="dynamic")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))

    eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len,
                      quantize_weights=args.int8,
                      temperature=args.temperature,
                      buckets=tuple(int(b) for b in args.buckets.split(",")),
                      batch_prefill=not args.legacy_prefill)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(1, args.prompt_len + 1))),
                    max_new=args.max_new) for i in range(args.requests)]
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    total_new = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s) int8={args.int8} int8_kv={args.int8_kv} "
          f"prefill={'legacy' if args.legacy_prefill else 'bucketed'}")
    print("  buckets:", eng.buckets)
    print("  stats:  ", dict(eng.stats))
    for r in reqs[:3]:
        print(f"  req {r.uid}: {r.generated}")


if __name__ == "__main__":
    main()
