"""Serving driver: bucketed batched prefill + continuous batching with the
PDQ-int8 path, single-device, mesh-distributed, or multi-process.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --requests 8 --max-new 16 [--int8] [--int8-kv] \
        [--buckets 32,64,128] [--legacy-prefill] [--chunked-prefill] \
        [--mesh 4x2] [--slots-per-replica 2] [--num-processes 2]

``--mesh DxM`` serves over a ('data', 'model') device mesh
(ShardedServeEngine: slots data-parallel across D replicas, projection
columns tensor-parallel across M shards).  On a CPU host the driver forces
enough virtual devices automatically - this line must run before jax
imports, hence the early environ bootstrap below.

``--num-processes N`` additionally splits the mesh over N OS processes
joined by ``jax.distributed`` (MultiHostServeEngine): this process becomes
a LAUNCHER that spawns N children (each re-runs this driver with
--process-id i), streams their output, and exits non-zero the moment any
child dies - so a hung or crashed worker is an actionable failure, not a
silent stall.  Child 0 is the serving coordinator; it prints per-process
admit/occupancy stats at the end.  A child can also be started by hand
(e.g. one per host) with explicit --process-id/--coordinator.
"""
from __future__ import annotations

import sys


from repro.launch.mesh import bootstrap_mesh_env

bootstrap_mesh_env(sys.argv)

import argparse
import collections
import os
import signal
import subprocess
import threading
import time

import numpy as np

# typed child exit codes the launcher knows how to explain (keep in sync
# with repro.distributed.fault - imported lazily there to avoid pulling
# jax into the launcher before the children's env is set up)
_EXIT_MEANING = {87: "deadline watchdog fired (hung collective/dead peer)",
                 41: "fault-injection kill"}


def build_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--int8", action="store_true", help="PDQ int8 weights")
    ap.add_argument("--int8-kv", action="store_true", help="int8 KV cache")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--decode-steps", type=int, default=1,
                    help="decode tokens fused per host dispatch (the N-step "
                         "decode fast path; 1 = classic per-token launches)")
    ap.add_argument("--prompt-len", type=int, default=8,
                    help="max prompt length (lengths are drawn in [1, this])")
    ap.add_argument("--buckets", default="32,64,128,256",
                    help="comma-separated prefill length buckets")
    ap.add_argument("--legacy-prefill", action="store_true",
                    help="per-request prefill baseline (recompiles per "
                         "distinct prompt length)")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="split prompts beyond the largest bucket into "
                         "bucket-sized chunks instead of rejecting them")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV pool: fixed-size pages + indirection "
                         "tables instead of slot rows (prefix sharing, "
                         "preempt-and-requeue under pressure)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="tokens per KV page (--paged)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="physical pages per replica (--paged; default "
                         "sizes the pool for slot-row parity)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable copy-on-write prompt-prefix sharing "
                         "(--paged)")
    ap.add_argument("--spill", action="store_true",
                    help="spill preempted pages to host memory for warm "
                         "resume (--paged, single-device only)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve over a data x model device mesh "
                         "(ShardedServeEngine)")
    ap.add_argument("--slots-per-replica", type=int, default=None,
                    help="cache slots per data-parallel replica "
                         "(default: --slots)")
    ap.add_argument("--num-processes", type=int, default=1,
                    help="split --mesh over N jax.distributed processes "
                         "(spawns the children unless --process-id is set)")
    ap.add_argument("--process-id", type=int, default=None,
                    help="this child's jax.distributed process index "
                         "(set by the --num-processes launcher)")
    ap.add_argument("--coordinator", default=None,
                    help="jax.distributed coordinator address (default: the "
                         "launcher picks a free local port; a hand-started "
                         "child must be given one explicitly)")
    ap.add_argument("--launch-timeout", type=float, default=None,
                    help="per-launch deadline (seconds) for multi-process "
                         "collectives; a hung rendezvous exits with the "
                         "typed watchdog code instead of blocking forever")
    ap.add_argument("--snapshot", default=None, metavar="PATH",
                    help="write the scheduler drain record here on "
                         "preemption (SIGTERM) or fleet failure")
    ap.add_argument("--resume", default=None, metavar="PATH",
                    help="requeue the unfinished requests of a previous "
                         "run's --snapshot record instead of generating a "
                         "fresh trace")
    ap.add_argument("--pdq-fallback", action="store_true",
                    help="guard every PDQ projection with a per-launch "
                         "fp-dequant fallback on non-finite output")
    ap.add_argument("--http", type=int, default=None, metavar="PORT",
                    help="serve an HTTP front door instead of a canned "
                         "trace: POST /v1/completions (SSE streaming), "
                         "GET /healthz, GET /v1/stats; 0 picks a free port "
                         "(printed on startup).  SIGTERM/SIGINT drain, "
                         "snapshot (--snapshot) and exit cleanly")
    ap.add_argument("--max-pending", type=int, default=32,
                    help="HTTP admission watermark: submits past this many "
                         "queued requests are shed with 429 + Retry-After")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace-event JSON (Perfetto-"
                         "loadable) of request/phase spans here on exit; "
                         "with --num-processes the coordinator writes ONE "
                         "merged trace with a process row per jax process")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="disable the metrics registry + lifecycle timing "
                         "(the <=2%% overhead A/B switch; /metrics then "
                         "renders empty)")
    return ap.parse_args(argv)


def _fmt_ms(seconds: float) -> str:
    return f"{seconds * 1e3:.1f}ms"


def report_telemetry(eng, args) -> None:
    """Drain/exit printout: latency histogram summaries (p50/p90/p99) and
    the shed rate, plus the --trace-out write.  Shared by the canned-trace
    and --http exits."""
    summ = eng.tel.summary()
    for key, label in (("ttft", "ttft"), ("per_token", "per-token"),
                       ("queue_wait", "queue wait")):
        s = summ.get(key)
        if s and s["count"]:
            print(f"  {label}: n={s['count']} p50={_fmt_ms(s['p50'])} "
                  f"p90={_fmt_ms(s['p90'])} p99={_fmt_ms(s['p99'])}")
    shed = eng.stats.get("shed", 0)
    served = len(eng.finished)
    if shed:
        print(f"  shed: {shed} requests "
              f"({shed / max(shed + served, 1):.1%} of submitted)")
    if args.trace_out:
        eng.tel.tracer.write(args.trace_out)
        n = len(eng.tel.tracer.events())
        print(f"  trace: {n} spans -> {args.trace_out}", flush=True)


def _tee_stderr(proc, ring) -> threading.Thread:
    """Stream a child's stderr to ours while keeping the tail in ``ring``
    (the launcher's post-mortem: WHAT the dead process last said)."""

    def pump():
        for line in iter(proc.stderr.readline, ""):
            ring.append(line.rstrip("\n"))
            sys.stderr.write(line)
            sys.stderr.flush()
        proc.stderr.close()

    t = threading.Thread(target=pump, daemon=True)
    t.start()
    return t


def spawn_processes(args, argv) -> int:
    """Launcher mode: spawn one child per process, fail fast and LOUD.

    Children share this terminal's stdout (their prints are the
    per-process log); stderr is teed through a per-child ring buffer.
    The first child to exit non-zero takes the fleet down: remaining
    children are terminated, and the launcher reports WHICH process died,
    its exit code (decoded for the typed watchdog/fault-injection codes)
    and the last lines it wrote to stderr - so CI sees an actionable
    post-mortem instead of a bare non-zero exit or a 6-hour hang.

    A SIGTERM to the launcher is forwarded to the coordinator child
    (process 0) only: it drains, snapshots (with --snapshot) and releases
    the workers through the command protocol, so the whole fleet exits
    cleanly."""
    env = dict(os.environ)
    from repro.launch.mesh import pick_coordinator, strip_forced_device_count
    env["XLA_FLAGS"] = strip_forced_device_count(env.get("XLA_FLAGS", ""))
    coordinator = pick_coordinator(args.coordinator)
    procs = [subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", *argv,
         "--coordinator", coordinator, "--process-id", str(i)], env=env,
        stderr=subprocess.PIPE, text=True)
        for i in range(args.num_processes)]
    rings = [collections.deque(maxlen=20) for _ in procs]
    tees = [_tee_stderr(p, r) for p, r in zip(procs, rings)]
    live = dict(enumerate(procs))

    def forward_term(signum, frame):
        # SIGINT rides the same path as SIGTERM: forward to the
        # coordinator child BEFORE any reaping - it drains, snapshots and
        # releases the workers through the command protocol, and the
        # launcher's poll loop then collects everyone's clean exit
        if 0 in live:
            live[0].send_signal(signal.SIGTERM)     # coordinator drains

    prev = signal.signal(signal.SIGTERM, forward_term)
    prev_int = signal.signal(signal.SIGINT, forward_term)
    try:
        while live:
            time.sleep(0.2)
            for i, p in list(live.items()):
                rc = p.poll()
                if rc is None:
                    continue
                del live[i]
                if rc != 0:
                    meaning = _EXIT_MEANING.get(rc)
                    why = f" [{meaning}]" if meaning else ""
                    print(f"serve launcher: process {i} died with exit code "
                          f"{rc}{why}; terminating {len(live)} remaining",
                          file=sys.stderr, flush=True)
                    for t in tees:
                        t.join(timeout=2)
                    tail = list(rings[i])
                    if tail:
                        print(f"serve launcher: last stderr of process {i}:",
                              file=sys.stderr)
                        for line in tail:
                            print(f"  [proc {i}] {line}", file=sys.stderr)
                        sys.stderr.flush()
                    for q in live.values():
                        q.terminate()
                    for q in live.values():
                        q.wait()
                    return rc
        return 0
    finally:
        signal.signal(signal.SIGTERM, prev)
        signal.signal(signal.SIGINT, prev_int)
        for t in tees:
            t.join(timeout=2)


def serve_http(args, eng, multiproc: bool) -> None:
    """``--http`` mode: the streaming front door (serve/service.py +
    serve/frontend.py) drives the scheduler continuously; requests arrive
    over HTTP instead of a canned trace.  SIGTERM and SIGINT both route
    through ``request_drain()``: the loop stops at a round boundary,
    unfinished streams get a typed ``drain`` finish, the snapshot is
    written (--snapshot), and a later ``--resume`` run regenerates the
    interrupted work token-exactly."""
    import asyncio

    from repro.serve import HttpFrontend, ServeService

    svc = ServeService(eng, max_pending=args.max_pending)
    if args.resume:
        from repro.distributed.fault import load_snapshot
        from repro.serve import resume_requests
        done, reqs = resume_requests(load_snapshot(args.resume))
        eng.pending.extend(reqs)       # headless requeue: no client holds
        print(f"resuming {len(reqs)} unfinished requests "   # these streams
              f"({len(done)} already finished) from {args.resume}",
              flush=True)
    svc.start()

    async def amain():
        fe = await HttpFrontend(svc, port=args.http).start()
        print(f"serving HTTP on 127.0.0.1:{fe.port} "
              f"(watermark {args.max_pending})", flush=True)
        stop = asyncio.Event()
        loop = asyncio.get_running_loop()

        def on_signal():
            svc.request_drain()
            stop.set()

        for sig in (signal.SIGTERM, signal.SIGINT):
            loop.add_signal_handler(sig, on_signal)
        await stop.wait()
        # the drain must COMPLETE while this loop is still alive: open SSE
        # handlers deliver their typed 'drain' finish through loop wakers,
        # and closing the loop first would strand them mid-stream
        await loop.run_in_executor(None, svc.join, 600.0)
        await fe.stop()

    asyncio.run(amain())
    svc.join(timeout=600)
    if multiproc:
        eng.stop_workers()
    if svc.error is not None:
        raise SystemExit(f"serve loop failed: {svc.error!r}")
    done = len(eng.finished)
    left = len(eng.pending) + sum(r is not None for r in eng.active)
    print(f"drained: {done} requests finished, {left} unfinished "
          + (f"snapshotted to {eng.snapshot_path}" if eng.snapshot_path
             else "(no --snapshot: progress dropped)"), flush=True)
    print("  stats:  ", {k: v for k, v in eng.stats.items()
                         if not k.startswith("replica_")})
    report_telemetry(eng, args)


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    args = build_args(argv)

    multiproc = args.num_processes > 1 or args.process_id is not None
    if multiproc:
        if not args.mesh:
            raise SystemExit("--num-processes requires --mesh DxM")
        if args.legacy_prefill:
            raise SystemExit("--legacy-prefill is single-device only")
    if args.num_processes > 1 and args.process_id is None:
        raise SystemExit(spawn_processes(args, argv))

    if multiproc:
        # child: join the jax.distributed job BEFORE any device query
        if not args.coordinator:
            raise SystemExit("a hand-started --process-id child needs an "
                             "explicit --coordinator HOST:PORT")
        from repro.launch.mesh import init_distributed
        init_distributed(args.coordinator, args.num_processes,
                         args.process_id)

    from repro.configs import ALL_ARCHS
    from repro.launch.mesh import make_serve_mesh, parse_mesh
    from repro.serve import Request, ServeConfig, build_engine

    if args.arch not in ALL_ARCHS:
        raise SystemExit(f"unknown --arch {args.arch!r}; "
                         f"choose from {sorted(ALL_ARCHS)}")
    if args.paged and args.legacy_prefill:
        raise SystemExit("--paged needs the bucketed prefill path")

    mesh = None
    if args.mesh:
        data, model = parse_mesh(args.mesh)
        if data % max(args.num_processes, 1):
            raise SystemExit(f"--mesh data axis ({data}) must divide over "
                             f"--num-processes ({args.num_processes})")
        mesh = make_serve_mesh(data, model)

    sc = ServeConfig(
        arch=args.arch, reduced=args.reduced, int8_kv=args.int8_kv,
        slots=args.slots, max_len=args.max_len,
        quantize_weights=args.int8, temperature=args.temperature,
        buckets=tuple(int(b) for b in args.buckets.split(",")),
        batch_prefill=not args.legacy_prefill,
        chunked_prefill=args.chunked_prefill,
        decode_steps=args.decode_steps,
        pdq_fallback=args.pdq_fallback, mesh=mesh,
        slots_per_replica=args.slots_per_replica or args.slots,
        multihost=multiproc, launch_timeout=args.launch_timeout,
        snapshot_path=args.snapshot, paged=args.paged,
        page_size=args.page_size, pool_pages=args.pool_pages,
        prefix_sharing=not args.no_prefix_sharing, spill=args.spill,
        telemetry=not args.no_telemetry, trace=args.trace_out is not None)
    try:
        eng = build_engine(sc)
    except ValueError as e:
        raise SystemExit(str(e))
    cfg = eng.cfg

    if mesh is not None:
        spr = args.slots_per_replica or args.slots
        mode = f"sharded {data}x{model} ({spr} slots/replica)"
        if multiproc:
            mode += f" x{args.num_processes}proc"
    else:
        mode = "legacy" if args.legacy_prefill else "bucketed"
    if args.paged:
        mode += f" paged/{args.page_size}"

    if multiproc and not eng.is_coordinator:
        print(f"[proc {args.process_id}] worker following the coordinator "
              f"command stream", flush=True)
        eng.serve_worker()
        print(f"[proc {args.process_id}] worker done", flush=True)
        return

    if args.http is not None:
        return serve_http(args, eng, multiproc)

    if args.resume:
        # requeue the previous run's unfinished work (progress cleared:
        # (uid, step)-keyed sampling regenerates the identical tokens)
        from repro.distributed.fault import load_snapshot
        from repro.serve import resume_requests
        done, reqs = resume_requests(load_snapshot(args.resume))
        print(f"resuming {len(reqs)} unfinished requests "
              f"({len(done)} already finished) from {args.resume}",
              flush=True)
    else:
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab,
                                            int(rng.integers(1, args.prompt_len + 1))),
                        max_new=args.max_new) for i in range(args.requests)]
    # preemption: SIGTERM/SIGINT request a drain - the scheduler finishes
    # the round, snapshots (with --snapshot) and run() returns; the
    # workers are then released through the normal CMD_STOP
    signal.signal(signal.SIGTERM, lambda *_: eng.request_drain())
    signal.signal(signal.SIGINT, lambda *_: eng.request_drain())
    t0 = time.perf_counter()
    eng.run(reqs)
    if multiproc:
        eng.stop_workers()
    dt = time.perf_counter() - t0
    if eng.drained:
        left = sum(not r.done for r in reqs)
        print(f"drained on preemption: {left} unfinished requests "
              + (f"snapshotted to {eng.snapshot_path}" if eng.snapshot_path
                 else "(no --snapshot: progress dropped)"), flush=True)
    total_new = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s) int8={args.int8} int8_kv={args.int8_kv} "
          f"prefill={mode}")
    print("  buckets:", eng.buckets)
    print("  stats:  ", {k: v for k, v in eng.stats.items()
                         if not k.startswith("replica_")})
    report_telemetry(eng, args)
    for r, (adm, occ) in enumerate(zip(eng.stats["replica_admits"],
                                       eng.stats["replica_occupancy"])):
        print(f"  replica {r}: admits={adm} occupied={occ}/"
              f"{eng.slots_per_replica}")
    if multiproc:
        for proc, hs in sorted(eng.host_stats().items()):
            print(f"  process {proc}: replicas={hs['replicas']} "
                  f"admits={hs['admits']} occupied={hs['occupied']}/"
                  f"{hs['slots']}")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {r.generated}")


if __name__ == "__main__":
    main()
