"""Production mesh definitions.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; everything else
sees the real device count).
"""
from __future__ import annotations

import os

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    if len(jax.devices()) == n:
        return jax.make_mesh(shape, axes)
    # dry-run host platform exposes 512 placeholder devices; the single-pod
    # mesh uses the first 256 of them.
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, axes)


def make_host_mesh(data: int | None = None, model: int | None = None):
    """Small mesh over whatever devices exist (tests / CPU smoke runs)."""
    n = jax.device_count()
    if data is None and model is None:
        model = 1
        data = n
    elif data is None:
        data = n // model
    elif model is None:
        model = n // data
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh(spec: str) -> tuple[int, int]:
    """'4x2' -> (data=4, model=2)."""
    try:
        data, model = (int(p) for p in spec.lower().split("x"))
    except ValueError as e:
        raise ValueError(f"--mesh expects DxM (e.g. 4x2), got {spec!r}") from e
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be positive, got {spec!r}")
    return data, model


def mesh_arg(argv) -> str | None:
    """The value of --mesh DxM / --mesh=DxM in argv, else None (scanned
    by hand: this runs BEFORE argparse so the device count can be sized
    first)."""
    for i, a in enumerate(argv):
        if a == "--mesh" and i + 1 < len(argv):
            return argv[i + 1]
        if a.startswith("--mesh="):
            return a.split("=", 1)[1]
    return None


def _argv_int(argv, name: str) -> int | None:
    """The integer value of --name N / --name=N in argv, else None (scanned
    by hand: this runs BEFORE argparse so the device count can be sized
    first)."""
    for i, a in enumerate(argv):
        if a == f"--{name}" and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith(f"--{name}="):
            return int(a.split("=", 1)[1])
    return None


def strip_forced_device_count(flags: str) -> str:
    """Drop any --xla_force_host_platform_device_count=N from an XLA_FLAGS
    string (a multi-process spawner must not leak its own forced count into
    children that need a per-process one)."""
    return " ".join(f for f in flags.split()
                    if not f.startswith("--xla_force_host_platform_device_count"))


def bootstrap_mesh_env(argv) -> None:
    """Force the right number of virtual host devices for a --mesh run on
    a CPU host: D*M for a single process, D*M // --num-processes for a
    ``jax.distributed`` child (identified by --process-id).

    Importing this module does not initialize the jax backend, so
    XLA_FLAGS set here still takes effect - call before the first device
    query (launch/serve.py and benchmarks/bench_serve.py call it at
    module import, before anything touches jax.devices())."""
    spec = mesh_arg(argv)
    if spec is None:
        return
    data, model = parse_mesh(spec)
    want = data * model
    nprocs = _argv_int(argv, "num-processes") or 1
    if _argv_int(argv, "process-id") is not None:
        if (data * model) % nprocs:
            raise ValueError(f"mesh {data}x{model} does not split over "
                             f"{nprocs} processes")
        want = data * model // nprocs
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={want}").strip()


def pick_coordinator(addr: str | None, *, attempts: int = 5) -> str:
    """``addr`` if given, else 127.0.0.1 with a fresh OS-assigned port:
    two concurrent multi-process fleets on one host (overlapping bench
    runs, a retry racing a hung predecessor) must not rendezvous with
    each other's coordination service.  The ephemeral bind is retried
    (bounded) so transient EADDRINUSE under heavy concurrent CI does not
    kill the launcher."""
    if addr:
        return addr
    import socket
    import time as _time
    for attempt in range(attempts):
        try:
            with socket.socket() as s:
                s.bind(("127.0.0.1", 0))
                return f"127.0.0.1:{s.getsockname()[1]}"
        except OSError:
            if attempt == attempts - 1:
                raise
            _time.sleep(0.2 * (2 ** attempt))
    raise AssertionError("unreachable")


def init_distributed(coordinator: str, num_processes: int,
                     process_id: int, *, attempts: int = 3,
                     backoff: float = 1.0) -> None:
    """``jax.distributed`` bootstrap for one serve process: CPU collectives
    go through gloo (the CPU client's only cross-process implementation),
    then the coordination service connects this process to its peers.
    Must run before the first device query.

    The initialize is retried with exponential backoff (bounded): the
    coordination-service port can be mid-release from a previous fleet
    (TIME_WAIT) or the coordinator child can come up a beat after a
    worker - both transient, both previously fatal."""
    import jax as _jax
    if os.environ.get("JAX_PLATFORMS", "cpu") == "cpu":
        _jax.config.update("jax_cpu_collectives_implementation", "gloo")
    for attempt in range(attempts):
        try:
            _jax.distributed.initialize(coordinator,
                                        num_processes=num_processes,
                                        process_id=process_id)
            return
        except Exception as e:
            if attempt == attempts - 1:
                raise
            import sys as _sys
            import time as _time
            try:                       # drop any half-open connection state
                _jax.distributed.shutdown()
            except Exception:
                pass
            delay = backoff * (2 ** attempt)
            print(f"init_distributed: attempt {attempt + 1}/{attempts} "
                  f"failed ({e!r}); retrying in {delay:.1f}s",
                  file=_sys.stderr, flush=True)
            _time.sleep(delay)


def make_serve_mesh(data: int, model: int):
    """('data', 'model') mesh over the first data*model devices (the
    virtual-device CPU path exposes more than the mesh needs)."""
    n = data * model
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"mesh {data}x{model} needs {n} devices, found "
            f"{len(jax.devices())}; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices()[:n]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def mesh_info(mesh) -> dict:
    return {
        "axis_names": list(mesh.axis_names),
        "shape": [int(mesh.shape[a]) for a in mesh.axis_names],
        "n_devices": int(np.prod([mesh.shape[a] for a in mesh.axis_names])),
    }
