"""Production mesh definitions.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; everything else
sees the real device count).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    if len(jax.devices()) == n:
        return jax.make_mesh(shape, axes)
    # dry-run host platform exposes 512 placeholder devices; the single-pod
    # mesh uses the first 256 of them.
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, axes)


def make_host_mesh(data: int | None = None, model: int | None = None):
    """Small mesh over whatever devices exist (tests / CPU smoke runs)."""
    n = jax.device_count()
    if data is None and model is None:
        model = 1
        data = n
    elif data is None:
        data = n // model
    elif model is None:
        model = n // data
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_info(mesh) -> dict:
    return {
        "axis_names": list(mesh.axis_names),
        "shape": [int(mesh.shape[a]) for a in mesh.axis_names],
        "n_devices": int(np.prod([mesh.shape[a] for a in mesh.axis_names])),
    }
