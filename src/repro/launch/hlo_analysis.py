"""Scaled HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body* once, which
undercounts lax.scan-heavy programs (layer stacks, chunked attention, loss
chunks) by their trip counts.  This module re-derives per-device costs from
the partitioned HLO text with loop-trip scaling:

  * computations are parsed into (name -> ops) blocks;
  * each ``while`` op contributes scale(body) += scale(parent) * trip, where
    the trip count is recovered from the largest integer constant in the
    loop condition computation (how lax.scan bounds lower);
  * matmul FLOPs come from ``dot`` ops: 2 * prod(result) * K, with K read
    from lhs_contracting_dims;
  * collective payload bytes use the result shapes of all-reduce (x2,
    ring), all-gather, reduce-scatter, all-to-all, collective-permute.

Everything is per-device (the partitioned module is the per-device program).
"""
from __future__ import annotations

import dataclasses
import re

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*\)\s*->")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CALL_ATTRS = ("body=", "condition=", "to_apply=", "calls=")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "u4": 1, "s4": 1, "token": 0}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclasses.dataclass
class Costs:
    dot_flops: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))


def _shape_elems_bytes(tok: str) -> tuple[int, int]:
    m = _SHAPE.match(tok)
    if not m:
        return 0, 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(dt, 4)


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and stripped.endswith("{"):
                cur = m.group(1)
                comps[cur] = []
            continue
        if stripped == "}" or stripped.startswith("} //"):
            cur = None
            continue
        comps[cur].append(stripped)
    return comps


def _entry_name(hlo: str) -> str | None:
    for line in hlo.splitlines():
        s = line.strip()
        if s.startswith("ENTRY"):
            m = _COMP_HEADER.match(s)
            if m:
                return m.group(1)
    return None


def _callees(line: str) -> list[tuple[str, str]]:
    """(attr, computation) references on an op line."""
    out = []
    for attr in _CALL_ATTRS:
        for m in re.finditer(re.escape(attr) + r"%?([\w\.\-_]+)", line):
            out.append((attr.rstrip("="), m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", line)
    if m:
        for name in m.group(1).split(","):
            out.append(("branch", name.strip().lstrip("%")))
    return out


def _trip_count(cond_ops: list[str]) -> int:
    best = 1
    for line in cond_ops:
        m = re.search(r"constant\((\d+)\)", line)
        if m:
            best = max(best, int(m.group(1)))
    return best


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*(.*)$")
_OPERANDS_RE = re.compile(r"dot\(([^)]*)\)")


def _result_dims(rhs: str) -> list[int] | None:
    m = _SHAPE.search(rhs)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


def _symbol_table(ops: list[str]) -> dict[str, list[int]]:
    """op name -> result dims (first shape after '='), incl. parameters."""
    table: dict[str, list[int]] = {}
    for line in ops:
        m = _DEF_RE.match(line)
        if not m:
            continue
        dims = _result_dims(m.group(2))
        if dims is not None:
            table[m.group(1)] = dims
    return table


def _dot_flops(line: str, table: dict[str, list[int]]) -> float:
    m = _DEF_RE.match(line)
    if not m:
        return 0.0
    res_dims = _result_dims(m.group(2))
    if res_dims is None:
        return 0.0
    res = 1
    for d in res_dims:
        res *= d
    # lhs operand: first argument of dot(...); shape inline or via symbol.
    # NB: don't split the operand list on "," first - multi-dim shapes
    # contain commas ("f32[128,256]{1,0}"), so the first inline shape in the
    # operand string IS the lhs shape.
    lhs_dims = None
    mo = _OPERANDS_RE.search(line)
    if mo:
        operands = mo.group(1)
        ms = _SHAPE.search(operands)
        if ms:
            lhs_dims = [int(d) for d in ms.group(2).split(",") if d]
        else:
            name = operands.split(",")[0].strip().lstrip("%")
            lhs_dims = table.get(name)
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    K = 1
    if lhs_dims and mc is not None:
        for idx in mc.group(1).split(","):
            if idx:
                K *= lhs_dims[int(idx)]
    return 2.0 * res * K


def analyze(hlo: str) -> Costs:
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    scales: dict[str, float] = {c: 0.0 for c in comps}
    if entry is None:
        return Costs()
    scales[entry] = 1.0

    # propagate scales breadth-first (HLO call graphs are acyclic)
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        c = order[i]
        i += 1
        for line in comps.get(c, ()):
            callees = _callees(line)
            trip = 1
            if " while(" in line or line.startswith("while") or "= while" in line:
                cond = next((n for a, n in callees if a == "condition"), None)
                if cond is not None:
                    trip = _trip_count(comps.get(cond, []))
            for attr, name in callees:
                if name not in comps:
                    continue
                mult = trip if attr == "body" else 1
                scales[name] = scales.get(name, 0.0) + scales[c] * mult
                if name not in seen:
                    seen.add(name)
                    order.append(name)

    costs = Costs()
    for c, ops in comps.items():
        s = scales.get(c, 0.0)
        if s == 0.0:
            continue
        table = _symbol_table(ops)
        for line in ops:
            if " dot(" in line:
                costs.dot_flops += s * _dot_flops(line, table)
            else:
                for kind in _COLLECTIVES:
                    if f" {kind}(" in line or f"{kind}-start(" in line:
                        shapes = _SHAPE.findall(line)
                        if shapes:
                            dt, dims = shapes[0]
                            n = 1
                            for d in dims.split(","):
                                if d:
                                    n *= int(d)
                            b = n * _DTYPE_BYTES.get(dt, 4)
                            if kind == "all-reduce":
                                b *= 2
                            costs.collective_bytes[kind] = (
                                costs.collective_bytes.get(kind, 0.0) + s * b)
                        break
    return costs
