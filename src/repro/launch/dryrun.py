import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (16x16 single pod / 2x16x16 multi-pod),
  2. lowers the appropriate step function (train_step for train shapes,
     prefill / decode for serving shapes) against ShapeDtypeStruct inputs
     with full in/out shardings - no array is ever allocated,
  3. compiles it (proves the sharding config is coherent end-to-end),
  4. records memory_analysis / cost_analysis / per-collective byte counts
     into benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json,
     which §Roofline and benchmarks/roofline.py consume.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""
import argparse
import json
import re
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALL_ARCHS, get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh, mesh_info
from repro.models import SHAPES, build_model
from repro.models import context as mctx
from repro.optim import AdamWConfig
from repro.launch import hlo_analysis
from repro.train.train_step import (abstract_state, dist_context_for,
                                    state_specs)

ART_DIR = os.path.normpath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "..", "..",
    "benchmarks", "artifacts", "dryrun"))

# int8 optimizer moment states for the configs whose fp32 Adam would not fit
# 16 GB/chip on a single pod (DESIGN.md Sec. 5).
QUANT_OPT_STATE = {"arctic-480b", "deepseek-v2-236b"}

_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=\s*(?:\()?([a-z0-9]+\[[^\]]*\])")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "u4": 1, "s4": 1}


def _shape_bytes(text: str) -> int:
    m = _SHAPE_RE.match(text)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum collective payload bytes (per device) from partitioned HLO.

    Model: all-reduce counts 2x its shape (ring reduce+broadcast);
    all-gather counts its (full) result; reduce-scatter / all-to-all /
    collective-permute count their result bytes.
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind, shape = m.group(1), m.group(2)
        b = _shape_bytes(shape)
        if kind == "all-reduce":
            b *= 2
        out[kind] = out.get(kind, 0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def skip_reason(cfg, shape_name: str) -> str | None:
    if shape_name == "long_500k" and not cfg.long_context:
        return ("pure full-attention arch: long_500k needs sub-quadratic "
                "attention (DESIGN.md Arch-applicability)")
    return None


def lower_cell(arch: str, shape_name: str, mesh, variant: str = "baseline"):
    """Returns the lowered computation for one cell.

    variant='opt' applies the beyond-baseline schedule (EXPERIMENTS.md Perf):
    train -> remat policy 'save_heavy'; prefill -> sequence parallelism
    (tokens + KV cache sharded over 'model', parallel-q attention).
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    if variant == "opt" and SHAPES[shape_name].kind == "train":
        cfg = _dc.replace(cfg, remat="save_heavy")
    if variant == "opt" and SHAPES[shape_name].kind == "decode" and cfg.ssm is None:
        # PDQ-int8 serving: int8 KV cache + W8A8 weights (paper tie-in)
        cfg = _dc.replace(cfg, quant_kv="dynamic")
    bundle = build_model(cfg)
    sp = SHAPES[shape_name]
    specs = bundle.input_specs(shape_name)
    ctx = dist_context_for(mesh)

    if sp.kind == "train":
        opt_cfg = AdamWConfig(quant_state=arch in QUANT_OPT_STATE)
        with mctx.use_context(ctx):
            st = abstract_state(bundle, opt_cfg)
            sspec = state_specs(st, mesh)
            state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspec,
                                    is_leaf=lambda x: isinstance(x, P))
            batch_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s), shd.batch_spec(mesh, specs),
                is_leaf=lambda x: isinstance(x, P))
            from repro.optim import schedule as _sched
            from repro.train.train_step import make_step_fn
            step = make_step_fn(bundle, opt_cfg,
                                lambda s: _sched.warmup_cosine(s))
            fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, NamedSharding(mesh, P())),
                         donate_argnums=(0,))
            return fn.lower(st, specs)

    if variant == "opt" and sp.kind == "decode":
        from repro.models.linops import quantize_param_tree
        params = jax.eval_shape(
            lambda: quantize_param_tree(bundle.init(jax.random.PRNGKey(0))))
    else:
        params = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    pspec = shd.param_specs(params, mesh)
    params_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                             is_leaf=lambda x: isinstance(x, P))

    if sp.kind == "prefill":
        mem_len = specs.get("frames").shape[1] if "frames" in specs else 0
        caches = jax.eval_shape(
            lambda: bundle.init_caches(sp.batch, sp.seq, mem_len))
        sp_prefill = variant == "opt"
        cache_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            shd.cache_spec(mesh, caches, sp.batch, seq_over_model=sp_prefill),
            is_leaf=lambda x: isinstance(x, P))
        batch_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            shd.batch_spec(mesh, specs, seq_over_model=sp_prefill),
            is_leaf=lambda x: isinstance(x, P))
        with mctx.use_context(ctx):
            fn = jax.jit(bundle.prefill,
                         in_shardings=(params_sh, batch_sh, cache_sh),
                         out_shardings=(NamedSharding(mesh, P()), cache_sh),
                         donate_argnums=(2,))
            return fn.lower(params, specs, caches)

    # decode
    caches = specs["caches"]
    cache_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), shd.cache_spec(mesh, caches, sp.batch),
        is_leaf=lambda x: isinstance(x, P))
    tok_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        shd.batch_spec(mesh, {"tokens": specs["tokens"],
                              "positions": specs["positions"]}),
        is_leaf=lambda x: isinstance(x, P))
    with mctx.use_context(ctx):
        fn = jax.jit(bundle.decode_step,
                     in_shardings=(params_sh, cache_sh, tok_sh["tokens"],
                                   tok_sh["positions"]),
                     out_shardings=(NamedSharding(mesh, P()), cache_sh),
                     donate_argnums=(1,))
        return fn.lower(params, caches, specs["tokens"], specs["positions"])


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "baseline") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = get_config(arch)
    reason = skip_reason(cfg, shape_name)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "variant": variant}
    if reason:
        rec.update(status="skipped", reason=reason)
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        lowered = lower_cell(arch, shape_name, mesh, variant)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        try:
            mem = compiled.memory_analysis()
            mem_rec = {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
            }
        except Exception as e:  # pragma: no cover
            mem_rec = {"error": str(e)}
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        scaled = hlo_analysis.analyze(hlo)
    rec.update(
        status="ok",
        mesh_info=mesh_info(mesh),
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        cost_keys={k: float(v) for k, v in cost.items()
                   if isinstance(v, (int, float)) and k in (
                       "flops", "bytes accessed", "transcendentals",
                       "utilization operand 0 {}", "bytes accessed output {}")},
        memory=mem_rec,
        collectives=coll,
        scaled_dot_flops=float(scaled.dot_flops),
        scaled_collectives={k: float(v)
                            for k, v in scaled.collective_bytes.items()},
        scaled_collective_total=float(scaled.total_collective_bytes),
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ALL_ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=["baseline", "opt"])
    args = ap.parse_args()

    os.makedirs(ART_DIR, exist_ok=True)
    cells = []
    archs = list(ALL_ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    n_ok = n_skip = n_fail = 0
    for arch, shape, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        suffix = "" if args.variant == "baseline" else f"__{args.variant}"
        path = os.path.join(ART_DIR, f"{arch}__{shape}__{mesh_name}{suffix}.json")
        if args.skip_done and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("status") in ("ok", "skipped"):
                    print(f"[cached ] {arch} {shape} {mesh_name}")
                    n_ok += 1
                    continue
        try:
            rec = run_cell(arch, shape, mp, args.variant)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "status": "failed", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        tag = rec["status"]
        n_ok += tag == "ok"
        n_skip += tag == "skipped"
        n_fail += tag == "failed"
        extra = ""
        if tag == "ok":
            extra = (f"flops={rec['flops']:.3e} coll={rec['collectives']['total']:.3e}B "
                     f"lower={rec['lower_s']}s compile={rec['compile_s']}s")
        elif tag == "failed":
            extra = rec["error"][:200]
        print(f"[{tag:7s}] {arch} {shape} {mesh_name} {extra}", flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
