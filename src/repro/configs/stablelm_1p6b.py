"""stablelm-1.6b [dense]: 24L d_model=2048 32H d_ff=5632 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b; unverified]

long_500k: SKIPPED - pure full attention.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=5632, vocab=100352, head_dim=64,
    pattern=("global",),
)
