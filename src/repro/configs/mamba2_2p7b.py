"""mamba2-2.7b [ssm]: 64L d_model=2560 attention-free, ssm_state=128 (SSD).
[arXiv:2405.21060]

Attention-free: long_500k RUNS (state cache is O(1) in context length).
PDQ applies to the in/out projections; the SSD recurrence stays bf16
(DESIGN.md Arch-applicability).
"""
from repro.models.config import ArchConfig
from repro.models.ssm import SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280,
    pattern=("mamba",),
    ssm=SSMConfig(d_model=2560, d_state=128, head_dim=64, expand=2, d_conv=4,
                  chunk=256),
    long_context=True,
)
