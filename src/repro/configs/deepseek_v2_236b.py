"""deepseek-v2-236b [moe]: 60L d_model=5120 128H MLA d_ff_expert=1536
vocab=102400, MoE 160e top-6, 2 shared experts; MLA kv_lora=512.
[arXiv:2405.04434; hf]

Layer 0 is a dense FFN (as in the released model); remaining 59 layers MoE.
long_500k: SKIPPED - full (MLA) attention, quadratic at 500k (DESIGN.md).
"""
from repro.models.config import ArchConfig, MLAConfig
from repro.models.moe import MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288, vocab=102400,
    head=("global_dense",), pattern=("global",),
    mla=MLAConfig(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2,
                  d_ff_dense=12288, router_scale=16.0),
    rope_theta=10_000.0,
)
