"""seamless-m4t-medium [audio]: enc-dec, 12L encoder + 12L decoder,
d_model=1024 16H d_ff=4096 vocab=256206. [arXiv:2308.11596; hf]

The speech frontend is a STUB (assignment): input_specs provides precomputed
frame embeddings (seq/4 frames).  long_500k: SKIPPED (full attention).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec", enc_layers=12,
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, head_dim=64,
    pattern=("global",),
    frontend="audio",
)
