"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144, 5:1 local:global, 128k context. [hf:google/gemma-3; unverified]

long_500k: RUNS - 5/6 of layers are sliding-window(1024); the 8 global
layers' KV cache is sharded over the data axis (context parallelism).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
    d_ff=15360, vocab=262144, head_dim=256,
    pattern=("local", "local", "local", "local", "local", "global"),
    window=1024, embed_scale=True, rope_theta=1_000_000.0,
    long_context=True,
)
