"""yi-6b [dense]: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-arch GQA. [arXiv:2403.04652; hf]

long_500k: SKIPPED - pure full attention.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab=64000, head_dim=128,
    pattern=("global",),
)
