"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual. [hf:Snowflake/snowflake-arctic-base; hf]

long_500k: SKIPPED - pure full attention (DESIGN.md).
"""
from repro.models.config import ArchConfig
from repro.models.moe import MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, head_dim=128,
    pattern=("global",),
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual=True, d_ff_dense=4864),
)
