"""zamba2-7b [hybrid]: 81L d_model=3584, Mamba2 backbone + shared attention
blocks, ssm_state=64, d_ff=14336 (shared-block MLP), vocab=32000.
[arXiv:2411.15242]

Layout adaptation (DESIGN.md): 13 x (5 mamba + 1 shared-attn block) + 3 tail
mamba layers = 81; the 'shared' block reuses ONE attn+MLP param set at every
occurrence (per-occurrence KV cache), mirroring zamba2's shared blocks.
long_500k: RUNS (hybrid).
"""
from repro.models.config import ArchConfig
from repro.models.ssm import SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, head_dim=112,
    pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "shared"),
    tail=("mamba", "mamba", "mamba"),
    ssm=SSMConfig(d_model=3584, d_state=64, head_dim=64, expand=2, d_conv=4,
                  chunk=256),
    long_context=True,
)
