"""Architecture registry: ``get_config('<arch-id>')`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

_MODULES = {
    "deepseek-v2-236b": "deepseek_v2_236b",
    "arctic-480b": "arctic_480b",
    "mamba2-2.7b": "mamba2_2p7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "zamba2-7b": "zamba2_7b",
    "gemma3-12b": "gemma3_12b",
    "stablelm-1.6b": "stablelm_1p6b",
    "yi-6b": "yi_6b",
    "gemma2-2b": "gemma2_2b",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
}

ALL_ARCHS = tuple(_MODULES)


def get_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG.validate()


def reduced_config(name: str, **overrides):
    from repro.models.config import reduced
    return reduced(get_config(name), **overrides)
