"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000,
local+global alternating, logit softcap. [arXiv:2408.00118; hf]

long_500k: RUNS - half the layers are sliding-window(4096); global layers'
KV sharded over the data axis.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
    d_ff=9216, vocab=256000, head_dim=256,
    pattern=("local", "global"), window=4096,
    attn_softcap=50.0, logit_softcap=30.0, embed_scale=True,
    long_context=True,
)
