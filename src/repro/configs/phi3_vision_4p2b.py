"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H d_ff=8192 vocab=32064;
phi3-mini backbone + CLIP vision tower.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

The CLIP frontend is a STUB (assignment): input_specs provides 1024 patch
embeddings prepended to the text sequence.  long_500k: SKIPPED (full attn).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064, head_dim=96,
    pattern=("global",),
    frontend="vision", frontend_tokens=1024,
)
