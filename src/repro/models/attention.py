"""Attention variants: GQA (full/sliding-window), MLA, cross-attention.

All functions are pure; KV caches are dict pytrees threaded by the caller.
Training/prefill attention is chunked (flash-style online softmax via
lax.scan) so the (S x S) score matrix never materializes - required at
32k prefill and beyond.

KV caches:
  full   : {'k','v': (B, S, Hkv, Dh), 'len': (B,)}        [optionally int8 + scales]
  window : {'k','v': (B, W, Hkv, Dh), 'len': (B,)}         ring buffer
  mla    : {'ckv': (B, S, r), 'krope': (B, S, dr), 'len': (B,)}
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .layers import apply_rope, dense_init, rms_norm, softcap
from .linops import is_quantized, is_segment_view, lin, lin_grouped

NEG = -2.0e30


@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    attn_softcap: float | None = None
    window: int | None = None          # sliding window (local attention)
    quant_kv: str = "none"             # 'none' | 'dynamic' | 'pdq'


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention core
# ---------------------------------------------------------------------------


def chunked_attention(
    q: jax.Array,            # (B, Sq, H, Dh)
    k: jax.Array,            # (B, Sk, Hkv, Dh)
    v: jax.Array,            # (B, Sk, Hkv, Dh)
    q_pos: jax.Array,        # (B, Sq) absolute positions
    k_pos: jax.Array,        # (B, Sk)
    *,
    causal: bool = True,
    window: int | None = None,
    attn_softcap: float | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    parallel_q: bool = False,
) -> jax.Array:
    """Online-softmax attention; scores exist only per (q_chunk x kv_chunk).

    q/k share head_dim Dh; v may have a different head_dim Dv (MLA)."""
    B, Sq, H, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // Hkv
    scale = Dh ** -0.5
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq, nk = Sq // q_chunk, Sk // kv_chunk

    # (B, Sq, H, Dh) -> (nq, B, H, qc, Dh); scale in q.dtype (bf16 stays bf16)
    qc = q.reshape(B, nq, q_chunk, H, Dh).transpose(1, 0, 3, 2, 4) \
        * jnp.asarray(scale, q.dtype)
    qp = q_pos.reshape(B, nq, q_chunk).transpose(1, 0, 2)
    kc = k.reshape(B, nk, kv_chunk, Hkv, Dh).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, nk, kv_chunk, Hkv, Dv).transpose(1, 0, 3, 2, 4)
    kp = k_pos.reshape(B, nk, kv_chunk).transpose(1, 0, 2)

    def q_step(_, qx):
        qi, qpi = qx                                  # (B, H, qc, Dh), (B, qc)
        qi = qi.reshape(B, Hkv, G, q_chunk, Dh)

        def kv_step(carry, kx):
            m, l, acc = carry
            ki, vi, kpi = kx                          # (B, Hkv, kc, Dh), (B, kc)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qi, ki,
                           preferred_element_type=jnp.float32)
            s = softcap(s, attn_softcap)
            msk = jnp.ones((B, 1, 1, q_chunk, kv_chunk), bool)
            rel = qpi[:, None, None, :, None] - kpi[:, None, None, None, :]
            if causal:
                msk &= rel >= 0
            if window is not None:
                msk &= rel < window
            s = jnp.where(msk, s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk, p, 0.0)
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), ()

        init = (jnp.full((B, Hkv, G, q_chunk), NEG, jnp.float32),
                jnp.zeros((B, Hkv, G, q_chunk), jnp.float32),
                jnp.zeros((B, Hkv, G, q_chunk, Dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, (kc, vc, kp))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, o.reshape(B, H, q_chunk, Dv)

    if parallel_q:
        # q blocks as a batched dim (shardable: sequence parallelism); the
        # online-softmax scan runs only over KV chunks.
        qb = qc.reshape(nq, B, Hkv, G, q_chunk, Dh)

        def kv_step_p(carry, kx):
            m, l, acc = carry
            ki, vi, kpi = kx
            s = jnp.einsum("nbhgqd,bhkd->nbhgqk", qb, ki,
                           preferred_element_type=jnp.float32)
            s = softcap(s, attn_softcap)
            rel = qp[:, :, None, None, :, None] - kpi[None, :, None, None, None, :]
            msk = jnp.ones(rel.shape, bool)
            if causal:
                msk &= rel >= 0
            if window is not None:
                msk &= rel < window
            s = jnp.where(msk, s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk, p, 0.0)
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "nbhgqk,bhkd->nbhgqd", p.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), ()

        init = (jnp.full((nq, B, Hkv, G, q_chunk), NEG, jnp.float32),
                jnp.zeros((nq, B, Hkv, G, q_chunk), jnp.float32),
                jnp.zeros((nq, B, Hkv, G, q_chunk, Dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step_p, init, (kc, vc, kp))
        o = acc / jnp.maximum(l, 1e-30)[..., None]      # (nq,B,Hkv,G,qc,Dv)
        out = o.reshape(nq, B, H, q_chunk, Dv)
    else:
        _, out = jax.lax.scan(q_step, None, (qc, qp))  # (nq, B, H, qc, Dv)
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,            # (B, H, Dh) one token
    k: jax.Array,            # (B, S, Hkv, Dh)
    v: jax.Array,
    q_pos: jax.Array,        # (B,)
    k_pos: jax.Array,        # (B, S) absolute position per slot (-1 = empty)
    *,
    window: int | None = None,
    attn_softcap: float | None = None,
) -> jax.Array:
    B, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, Dh) * jnp.asarray(Dh ** -0.5, q.dtype)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k, preferred_element_type=jnp.float32)
    s = softcap(s, attn_softcap)
    rel = q_pos[:, None] - k_pos                      # (B, S)
    ok = (rel >= 0) & (k_pos >= 0)
    if window is not None:
        ok &= rel < window
    s = jnp.where(ok[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------


def gqa_init(key, dims: AttnDims, dtype):
    ks = jax.random.split(key, 4)
    d, H, Hkv, Dh = dims.d_model, dims.n_heads, dims.n_kv_heads, dims.head_dim
    return {
        "wq": dense_init(ks[0], d, H * Dh, dtype),
        "wk": dense_init(ks[1], d, Hkv * Dh, dtype),
        "wv": dense_init(ks[2], d, Hkv * Dh, dtype),
        "wo": dense_init(ks[3], H * Dh, d, dtype),
    }


def init_cache(dims: AttnDims, batch: int, max_len: int, dtype) -> dict[str, Any]:
    Hkv, Dh = dims.n_kv_heads, dims.head_dim
    S = min(max_len, dims.window) if dims.window else max_len
    cache = {
        "pos": jnp.full((batch, S), -1, jnp.int32),
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if dims.quant_kv != "none":
        # int8 caches live in KERNEL layout (B, Hkv, S, Dh) with S rounded
        # up to a 128 multiple: the flash-decode kernel then streams tiles
        # with zero per-step transposes/pads (ops.decode_attend_i8kv).  The
        # padded tail is never written (slots index the logical S from
        # cache['pos']) and always masked (offs >= length).
        Sp = S + (-S) % 128
        cache["k"] = jnp.zeros((batch, Hkv, Sp, Dh), jnp.int8)
        cache["v"] = jnp.zeros((batch, Hkv, Sp, Dh), jnp.int8)
        cache["k_scale"] = jnp.ones((batch, Hkv, Sp), jnp.float32)
        cache["v_scale"] = jnp.ones((batch, Hkv, Sp), jnp.float32)
    else:
        cache["k"] = jnp.zeros((batch, S, Hkv, Dh), dtype)
        cache["v"] = jnp.zeros((batch, S, Hkv, Dh), dtype)
    return cache


def _quant_kv_token(k_new, v_new):
    """Symmetric per-(token, head) int8 quantization of new KV entries."""
    def q(t):
        amax = jnp.max(jnp.abs(t), axis=-1)                     # (B, S, Hkv)
        scale = jnp.maximum(amax, 1e-6) / 127.0
        tq = jnp.clip(jnp.round(t / scale[..., None]), -127, 127).astype(jnp.int8)
        return tq, scale
    kq, ks = q(k_new.astype(jnp.float32))
    vq, vs = q(v_new.astype(jnp.float32))
    return kq, ks, vq, vs


def _cache_write(cache, k_new, v_new, positions, quant: str):
    """Write S_new tokens at ring positions (pos % W for windows)."""
    B, S_new = positions.shape
    W = cache["pos"].shape[1]              # logical length (int8 caches pad S)
    slots = positions % W
    bidx = jnp.arange(B)[:, None]
    if quant != "none":
        kq, ks, vq, vs = _quant_kv_token(k_new, v_new)
        cache = dict(cache)
        # kernel-layout cache (B, Hkv, Sp, Dh): advanced indexing brings
        # the (B, S_new) gather dims to the front, so the (B, S_new, Hkv,
        # Dh) update lands without any transpose.
        cache["k"] = cache["k"].at[bidx, :, slots].set(kq)
        cache["v"] = cache["v"].at[bidx, :, slots].set(vq)
        cache["k_scale"] = cache["k_scale"].at[bidx, :, slots].set(ks)
        cache["v_scale"] = cache["v_scale"].at[bidx, :, slots].set(vs)
    else:
        cache = dict(cache)
        cache["k"] = cache["k"].at[bidx, slots].set(k_new.astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[bidx, slots].set(v_new.astype(cache["v"].dtype))
    cache["pos"] = cache["pos"].at[bidx, slots].set(positions)
    cache["len"] = jnp.maximum(cache["len"], positions[:, -1] + 1)
    return cache


def _clamp_padded(vals, positions, seq_lens):
    """Redirect right-pad rows of a prefill write onto the row's LAST REAL
    token.

    ``seq_lens[b]`` counts the valid leading entries of row b; entries at
    sequence index >= seq_lens[b] are bucket padding.  Rewriting both the
    VALUES and the POSITIONS of pad entries to those of index seq_lens[b]-1
    makes every duplicate scatter slot carry identical data, so the write
    stays deterministic (XLA scatter order is unspecified for duplicate
    indices) and the cache ends up bit-identical to an unpadded prefill:
    pad tokens never exist in it.  Returns (clamped_vals, clamped_pos).
    """
    B, S = positions.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (B, S), 1)
    valid = idx < seq_lens[:, None]
    last = jnp.maximum(seq_lens - 1, 0)                    # (B,)
    bidx = jnp.arange(B)
    out = []
    for v in vals:
        v_last = v[bidx, last][:, None]                    # (B, 1, ...)
        mask = valid.reshape(valid.shape + (1,) * (v.ndim - 2))
        out.append(jnp.where(mask, v, v_last))
    pos = jnp.where(valid, positions, positions[bidx, last][:, None])
    return out, pos


def _cache_kv_float(cache, dtype):
    if "k_scale" in cache:
        S = cache["pos"].shape[1]
        k = cache["k"].astype(jnp.float32) * cache["k_scale"][..., None]
        v = cache["v"].astype(jnp.float32) * cache["v_scale"][..., None]
        # kernel layout (B, Hkv, Sp, Dh) -> logical (B, S, Hkv, Dh)
        k = jnp.transpose(k, (0, 2, 1, 3))[:, :S]
        v = jnp.transpose(v, (0, 2, 1, 3))[:, :S]
        return k.astype(dtype), v.astype(dtype)
    return cache["k"], cache["v"]


def _valid_k_pos(cache_pos: jax.Array) -> jax.Array:
    """Cache slot positions with empty slots (-1) pushed beyond every real
    query position, so the causal mask of ``chunked_attention`` (which has
    no explicit validity mask) excludes them: rel = q_pos - 2^30 < 0."""
    return jnp.where(cache_pos >= 0, cache_pos, jnp.int32(2 ** 30))


def gqa_apply(
    p,
    dims: AttnDims,
    x: jax.Array,                     # (B, S, d)  [S=1 for decode]
    positions: jax.Array,             # (B, S)
    *,
    mode: str,                        # 'train' | 'prefill' | 'decode'
    cache: dict | None = None,
    causal: bool = True,
    seq_lens: jax.Array | None = None,   # (B,) valid prefix per right-padded row
    chunked: bool = False,            # continuation chunk: attend the cache
):
    B, S, d = x.shape
    H, Hkv, Dh = dims.n_heads, dims.n_kv_heads, dims.head_dim
    # Q/K/V consume the same normed input: quantized params run ONE
    # prologue + ONE wide W8A8 matmul for the triple (linops.lin_grouped)
    q, k, v = lin_grouped(x, (p["wq"], p["wk"], p["wv"]))
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, Hkv, Dh)
    v = v.reshape(B, S, Hkv, Dh)
    q = apply_rope(q, positions, dims.rope_theta)
    k = apply_rope(k, positions, dims.rope_theta)

    if mode == "train":
        o = chunked_attention(q, k, v, positions, positions, causal=causal,
                              window=dims.window, attn_softcap=dims.attn_softcap)
        return lin(o.reshape(B, S, H * Dh), p["wo"]), None

    assert cache is not None
    if mode == "prefill" and chunked:
        # chunked-prefill continuation: the cache row already holds earlier
        # chunks.  Attend the PRE-write cache (the landed prefix) plus this
        # chunk's own k/v, concatenated - causal over absolute positions,
        # empty slots pushed out of causal range - and only then write the
        # chunk.  The order matters for sliding-window layers, whose ring
        # cache holds exactly the last W positions: writing first would
        # evict keys still inside earlier in-chunk queries' windows.
        # Appending the chunk after the cache slots inserts only
        # exactly-zero (masked) terms into the softmax sums, so fp-cache
        # numerics match an unpadded prefill; an int8 KV cache contributes
        # its dequantized prefix (the same values decode would see) -
        # approximate, documented.
        assert seq_lens is not None
        kf, vf = _cache_kv_float(cache, x.dtype)
        k_all = jnp.concatenate([kf, k.astype(kf.dtype)], axis=1)
        v_all = jnp.concatenate([vf, v.astype(vf.dtype)], axis=1)
        pos_all = jnp.concatenate([_valid_k_pos(cache["pos"]), positions],
                                  axis=1)
        o = chunked_attention(q, k_all, v_all, positions, pos_all,
                              causal=causal, window=dims.window,
                              attn_softcap=dims.attn_softcap,
                              q_chunk=S, kv_chunk=k_all.shape[1],
                              parallel_q=True)
        (kc, vc), pos_c = _clamp_padded((k, v), positions, seq_lens)
        cache = _cache_write(cache, kc, vc, pos_c, dims.quant_kv)
        return lin(o.reshape(B, S, H * Dh), p["wo"]), cache
    if mode == "prefill":
        if seq_lens is None:
            cache = _cache_write(cache, k, v, positions, dims.quant_kv)
        else:
            # bucketed prefill: pads attend nothing (causal mask, pad
            # positions exceed every real q position) but must not WRITE -
            # clamp their k/v/positions onto the last real token instead.
            (kc, vc), pos_c = _clamp_padded((k, v), positions, seq_lens)
            cache = _cache_write(cache, kc, vc, pos_c, dims.quant_kv)
        o = chunked_attention(q, k, v, positions, positions, causal=causal,
                              window=dims.window, attn_softcap=dims.attn_softcap,
                              parallel_q=True)
        return lin(o.reshape(B, S, H * Dh), p["wo"]), cache

    # decode: S == 1
    cache = _cache_write(cache, k, v, positions, dims.quant_kv)
    q1 = q[:, 0]                                            # (B, H, Dh)
    if ("k_scale" in cache and dims.attn_softcap is None and dims.window is None):
        if is_quantized(p["wo"]) and not is_segment_view(p["wo"]):
            # fused path: the attend kernel's output stage also runs the wo
            # projection's PDQ prologue over the flattened row, so the
            # quantized wo costs one W8A8 launch instead of prologue+matmul
            o, o_q, s_x, s1, s2 = ops.decode_attend_i8kv(
                q1.astype(jnp.float32), cache["k"], cache["v"],
                cache["k_scale"], cache["v_scale"], cache["len"],
                wo_prologue=True, pro_dtype=x.dtype)
            y = ops.pdq_dense_from_prologue(
                o.reshape(B, 1, H * Dh).astype(x.dtype),
                o_q.reshape(B, 1, H * Dh),
                s_x.reshape(B, 1, 1), s1.reshape(B, 1, 1), s2.reshape(B, 1, 1),
                p["wo"], out_dtype=x.dtype)
            return y, cache
        # int8-KV flash-decode kernel path (falls back to ref off-TPU)
        o = ops.decode_attend_i8kv(
            q1.astype(jnp.float32), cache["k"], cache["v"],
            cache["k_scale"], cache["v_scale"], cache["len"])
        o = o.astype(x.dtype)
    else:
        kf, vf = _cache_kv_float(cache, x.dtype)
        o = decode_attention(q1, kf, vf, positions[:, 0], cache["pos"],
                             window=dims.window, attn_softcap=dims.attn_softcap)
    return lin(o.reshape(B, 1, H * Dh), p["wo"]), cache


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder); no rope, bidirectional over memory
# ---------------------------------------------------------------------------


def cross_init(key, dims: AttnDims, dtype):
    return gqa_init(key, dims, dtype)


def cross_apply(p, dims: AttnDims, x, memory_kv, memory_mask=None):
    """x: (B, Sq, d); memory_kv: precomputed (k, v) each (B, Sm, Hkv, Dh)."""
    B, Sq, _ = x.shape
    H, Hkv, Dh = dims.n_heads, dims.n_kv_heads, dims.head_dim
    q = lin(x, p["wq"]).reshape(B, Sq, H, Dh)
    k, v = memory_kv
    Sm = k.shape[1]
    qpos = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    kpos = jnp.broadcast_to(jnp.arange(Sm)[None], (B, Sm))
    o = chunked_attention(q, k, v, qpos, kpos, causal=False, window=None)
    return lin(o.reshape(B, Sq, H * Dh), p["wo"])


def cross_memory(p, dims: AttnDims, memory):
    """Precompute cross-attention K/V from encoder output (B, Sm, d)."""
    B, Sm, _ = memory.shape
    Hkv, Dh = dims.n_kv_heads, dims.head_dim
    # wk/wv share the encoder memory input (wq reads the decoder stream, so
    # cross params group only this pair - see linops.CROSS_SIBLING_SETS)
    k, v = lin_grouped(memory, (p["wk"], p["wv"]))
    return k.reshape(B, Sm, Hkv, Dh), v.reshape(B, Sm, Hkv, Dh)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank q/kv with compressed KV cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLADims:
    d_model: int
    n_heads: int
    q_lora: int
    kv_lora: int
    qk_nope: int
    qk_rope: int
    v_head: int
    rope_theta: float = 10_000.0


def mla_init(key, m: MLADims, dtype):
    ks = jax.random.split(key, 7)
    H = m.n_heads
    return {
        "wq_a": dense_init(ks[0], m.d_model, m.q_lora, dtype),
        "q_norm": jnp.zeros((m.q_lora,), dtype),
        "wq_b": dense_init(ks[1], m.q_lora, H * (m.qk_nope + m.qk_rope), dtype),
        "wkv_a": dense_init(ks[2], m.d_model, m.kv_lora + m.qk_rope, dtype),
        "kv_norm": jnp.zeros((m.kv_lora,), dtype),
        "wk_b": dense_init(ks[3], m.kv_lora, H * m.qk_nope, dtype),
        "wv_b": dense_init(ks[4], m.kv_lora, H * m.v_head, dtype),
        "wo": dense_init(ks[5], H * m.v_head, m.d_model, dtype),
    }


def mla_init_cache(m: MLADims, batch: int, max_len: int, dtype):
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora), dtype),
        "krope": jnp.zeros((batch, max_len, m.qk_rope), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def _mla_qkv(p, m: MLADims, x, positions):
    B, S, _ = x.shape
    H = m.n_heads
    # the two input-side low-rank projections share x -> one grouped call
    qa, kv = lin_grouped(x, (p["wq_a"], p["wkv_a"]))
    q = lin(rms_norm(qa, p["q_norm"]), p["wq_b"])
    q = q.reshape(B, S, H, m.qk_nope + m.qk_rope)
    q_nope, q_rope = q[..., : m.qk_nope], q[..., m.qk_nope:]
    q_rope = apply_rope(q_rope, positions, m.rope_theta)
    ckv = rms_norm(kv[..., : m.kv_lora], p["kv_norm"])
    krope = apply_rope(kv[..., None, m.kv_lora:], positions, m.rope_theta)[..., 0, :]
    return q_nope, q_rope, ckv, krope


def mla_apply(p, m: MLADims, x, positions, *, mode: str, cache=None,
              seq_lens=None, chunked: bool = False):
    B, S, _ = x.shape
    H = m.n_heads
    q_nope, q_rope, ckv, krope = _mla_qkv(p, m, x, positions)

    if mode == "prefill" and chunked:
        # chunked-prefill continuation: land this chunk's compressed stream
        # in the cache, then run the EXPANDED attention path against the
        # whole buffer - wk_b/wv_b re-expand the stored ckv, which holds
        # exactly the values an unchunked prefill computed, so the per-head
        # k/v match the unchunked path (the absorbed decode formulation
        # would associate the matmuls differently).
        assert cache is not None and seq_lens is not None
        (ckv_c, krope_c), pos_c = _clamp_padded((ckv, krope), positions,
                                                seq_lens)
        bidx = jnp.arange(B)[:, None]
        cache = dict(cache)
        cache["ckv"] = cache["ckv"].at[bidx, pos_c].set(
            ckv_c.astype(cache["ckv"].dtype))
        cache["krope"] = cache["krope"].at[bidx, pos_c].set(
            krope_c.astype(cache["krope"].dtype))
        cache["pos"] = cache["pos"].at[bidx, pos_c].set(pos_c)
        cache["len"] = jnp.maximum(cache["len"], pos_c[:, -1] + 1)
        Sb = cache["ckv"].shape[1]
        k_nope = lin(cache["ckv"], p["wk_b"]).reshape(B, Sb, H, m.qk_nope)
        v = lin(cache["ckv"], p["wv_b"]).reshape(B, Sb, H, m.v_head)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(cache["krope"][:, :, None],
                                      (B, Sb, H, m.qk_rope))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        o = chunked_attention(q, k, v, positions, _valid_k_pos(cache["pos"]),
                              causal=True, q_chunk=S, kv_chunk=Sb)
        return lin(o.reshape(B, S, H * m.v_head), p["wo"]), cache

    if mode in ("train", "prefill"):
        # expanded path: materialize per-head k/v from the compressed stream
        k_nope = lin(ckv, p["wk_b"]).reshape(B, S, H, m.qk_nope)
        v = lin(ckv, p["wv_b"]).reshape(B, S, H, m.v_head)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope[:, :, None], (B, S, H, m.qk_rope))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        o = chunked_attention(q, k, v, positions, positions, causal=True)
        y = lin(o.reshape(B, S, H * m.v_head), p["wo"])
        if mode == "train":
            return y, None
        ckv_c, krope_c, pos_c = ckv, krope, positions
        if seq_lens is not None:   # bucketed prefill: no pad entries (see _clamp_padded)
            (ckv_c, krope_c), pos_c = _clamp_padded((ckv, krope), positions,
                                                    seq_lens)
        bidx = jnp.arange(B)[:, None]
        cache = dict(cache)
        cache["ckv"] = cache["ckv"].at[bidx, pos_c].set(ckv_c.astype(cache["ckv"].dtype))
        cache["krope"] = cache["krope"].at[bidx, pos_c].set(krope_c.astype(cache["krope"].dtype))
        cache["pos"] = cache["pos"].at[bidx, pos_c].set(pos_c)
        cache["len"] = jnp.maximum(cache["len"], pos_c[:, -1] + 1)
        return y, cache

    # decode (absorbed): attention runs entirely in the compressed space.
    bidx = jnp.arange(B)[:, None]
    cache = dict(cache)
    cache["ckv"] = cache["ckv"].at[bidx, positions].set(ckv.astype(cache["ckv"].dtype))
    cache["krope"] = cache["krope"].at[bidx, positions].set(krope.astype(cache["krope"].dtype))
    cache["pos"] = cache["pos"].at[bidx, positions].set(positions)
    cache["len"] = jnp.maximum(cache["len"], positions[:, -1] + 1)

    from .linops import is_quantized
    wk_b_arr = (p["wk_b"]["q"].astype(jnp.float32) * p["wk_b"]["scale"][None, :]
                if is_quantized(p["wk_b"]) else p["wk_b"])
    wk_b = wk_b_arr.reshape(m.kv_lora, H, m.qk_nope)
    q_eff = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], wk_b)          # (B, H, r)
    scale = (m.qk_nope + m.qk_rope) ** -0.5
    s = (jnp.einsum("bhr,bsr->bhs", q_eff, cache["ckv"],
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0], cache["krope"],
                      preferred_element_type=jnp.float32)) * scale
    ok = (cache["pos"] <= positions[:, :1]) & (cache["pos"] >= 0)
    s = jnp.where(ok[:, None, :], s, NEG)
    prob = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", prob.astype(cache["ckv"].dtype), cache["ckv"],
                     preferred_element_type=jnp.float32)            # (B, H, r)
    wv_b_arr = (p["wv_b"]["q"].astype(jnp.float32) * p["wv_b"]["scale"][None, :]
                if is_quantized(p["wv_b"]) else p["wv_b"])
    wv_b = wv_b_arr.reshape(m.kv_lora, H, m.v_head)
    o = jnp.einsum("bhr,rhv->bhv", ctx.astype(x.dtype), wv_b)
    return lin(o.reshape(B, 1, H * m.v_head), p["wo"]), cache
