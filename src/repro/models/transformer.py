"""Decoder-only LM assembly: head + scanned pattern blocks + tail.

The repeated pattern blocks run under lax.scan over stacked params (compile
time stays O(pattern), not O(n_layers)); head/tail layers are unrolled.
Caches are threaded through the scan as xs/ys.  ``mode`` is one of
'train' | 'prefill' | 'decode'.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from jax.ad_checkpoint import checkpoint_name

from . import context
from .attention import (AttnDims, gqa_apply, gqa_init, init_cache, mla_apply,
                        mla_init, mla_init_cache)
from .config import ArchConfig
from .layers import embed_init, mlp_apply, mlp_init, rms_norm, softcap
from .moe import moe_ffn_dense_masked, moe_ffn_tokens, moe_init
from .ssm import ssm_apply, ssm_init, ssm_init_cache

MLADimsFields = ("d_model", "n_heads", "q_lora", "kv_lora", "qk_nope", "qk_rope",
                 "v_head", "rope_theta")


def _attn_dims(cfg: ArchConfig, kind: str) -> AttnDims:
    return AttnDims(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd, rope_theta=cfg.rope_theta, attn_softcap=cfg.attn_softcap,
        window=cfg.window if kind == "local" else None, quant_kv=cfg.quant_kv)


def _mla_dims(cfg: ArchConfig):
    from .attention import MLADims
    m = cfg.mla
    return MLADims(d_model=cfg.d_model, n_heads=cfg.n_heads, q_lora=m.q_lora,
                   kv_lora=m.kv_lora, qk_nope=m.qk_nope, qk_rope=m.qk_rope,
                   v_head=m.v_head, rope_theta=cfg.rope_theta)


def _is_moe(cfg: ArchConfig, kind: str) -> bool:
    return cfg.moe is not None and kind != "global_dense"


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------


def layer_init(key, cfg: ArchConfig, kind: str, dtype):
    if kind == "mamba":
        k1, = jax.random.split(key, 1)
        return {"norm": jnp.zeros((cfg.d_model,), dtype),
                "ssm": ssm_init(k1, cfg.ssm, dtype)}
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mla is not None and kind in ("global", "global_dense"):
        attn = mla_init(k1, _mla_dims(cfg), dtype)
    else:
        attn = gqa_init(k1, _attn_dims(cfg, kind), dtype)
    p = {"attn_norm": jnp.zeros((cfg.d_model,), dtype), "attn": attn,
         "ffn_norm": jnp.zeros((cfg.d_model,), dtype)}
    if _is_moe(cfg, kind):
        p["ffn"] = moe_init(k2, cfg.d_model, cfg.moe, dtype)
    else:
        d_ff = cfg.d_ff if kind != "global_dense" else (cfg.moe.d_ff_dense
                                                        if cfg.moe else cfg.d_ff)
        p["ffn"] = mlp_init(k2, cfg.d_model, d_ff or cfg.d_ff, dtype)
    if cfg.family == "encdec":
        p["cross_norm"] = jnp.zeros((cfg.d_model,), dtype)
        p["cross"] = gqa_init(k3, _attn_dims(cfg, "global"), dtype)
    return p


def layer_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int, dtype,
                mem_len: int = 0):
    if kind == "mamba":
        return ssm_init_cache(cfg.ssm, batch, dtype)
    if cfg.mla is not None and kind in ("global", "global_dense"):
        return mla_init_cache(_mla_dims(cfg), batch, max_len, dtype)
    c = init_cache(_attn_dims(cfg, kind), batch, max_len, dtype)
    if cfg.family == "encdec":
        Sm = max(mem_len, 1)
        c["cross_k"] = jnp.zeros((batch, Sm, cfg.n_kv_heads, cfg.hd), dtype)
        c["cross_v"] = jnp.zeros((batch, Sm, cfg.n_kv_heads, cfg.hd), dtype)
    return c


def _apply_ffn(p_ffn, cfg: ArchConfig, kind: str, h: jax.Array, mode: str,
               seq_lens=None):
    """Returns (y, aux).  ``seq_lens`` (B,) marks the valid prefix of
    right-padded bucketed-prefill rows: pad tokens are masked out of MoE
    routing so they cannot claim expert capacity (DESIGN.md Sec. 4)."""
    if not _is_moe(cfg, kind):
        return mlp_apply(p_ffn, h), jnp.float32(0.0)
    B, S, d = h.shape
    x2 = h.reshape(B * S, d)
    mask = None
    if seq_lens is not None:
        mask = (jax.lax.broadcasted_iota(jnp.int32, (B, S), 1)
                < seq_lens[:, None]).reshape(B * S)
    ctx = context.get_context()
    routed = {k: p_ffn[k] for k in ("router", "we_gate", "we_up", "we_down")}
    use_ep = ctx is not None and mode in ("train", "prefill")
    if ctx is None:
        fn = moe_ffn_tokens if mode in ("train", "prefill") else moe_ffn_dense_masked
        y, aux = fn(routed, x2, cfg.moe, axis_name=None, token_mask=mask)
    elif use_ep:
        def f(rp, xt, mt):
            yy, ax = moe_ffn_tokens(rp, xt, cfg.moe, axis_name=ctx.expert_axis,
                                    token_mask=mt)
            return yy, jax.lax.pmean(ax, ctx.token_axes)
        if mask is None:
            mask = jnp.ones((B * S,), bool)
        y, aux = context.shard_map(
            f, mesh=ctx.mesh,
            in_specs=(context.moe_param_specs(routed), P(ctx.token_axes, None),
                      P(ctx.token_axes)),
            out_specs=(P(ctx.token_axes, None), P()),
            check_vma=False,
        )(routed, x2, mask)
    else:
        def f(rp, xt, mt):
            yy, ax = moe_ffn_dense_masked(rp, xt, cfg.moe,
                                          axis_name=ctx.expert_axis,
                                          token_mask=mt)
            return yy, jax.lax.pmean(ax, ctx.data_axes)
        if mask is None:
            mask = jnp.ones((B * S,), bool)
        y, aux = context.shard_map(
            f, mesh=ctx.mesh,
            in_specs=(context.moe_param_specs(routed), P(ctx.data_axes, None),
                      P(ctx.data_axes)),
            out_specs=(P(ctx.data_axes, None), P()),
            check_vma=False,
        )(routed, x2, mask)
    y = checkpoint_name(y, "moe_out")
    y = y.reshape(B, S, d)
    if cfg.moe.n_shared:
        y = y + mlp_apply(p_ffn["shared"], h)
    if cfg.moe.dense_residual:
        y = y + mlp_apply(p_ffn["dense"], h)
    return y, aux


def layer_apply(p, cfg: ArchConfig, kind: str, h, positions, *, mode: str,
                cache=None, memory=None, causal: bool = True, seq_lens=None,
                chunked: bool = False):
    """Returns (h, new_cache, aux).  ``seq_lens`` (B,) marks the valid
    prefix of right-padded bucketed-prefill rows (None = no padding);
    ``chunked`` marks a chunked-prefill continuation (the cache rows
    already hold earlier chunks, which attention must see)."""
    eps = cfg.norm_eps
    if kind == "mamba":
        y, new_cache = ssm_apply(p["ssm"], cfg.ssm, rms_norm(h, p["norm"], eps),
                                 mode=mode, cache=cache, seq_lens=seq_lens)
        return h + y, new_cache, jnp.float32(0.0)

    xin = rms_norm(h, p["attn_norm"], eps)
    if cfg.mla is not None and kind in ("global", "global_dense"):
        a, new_cache = mla_apply(p["attn"], _mla_dims(cfg), xin, positions,
                                 mode=mode, cache=cache, seq_lens=seq_lens,
                                 chunked=chunked)
    else:
        a, new_cache = gqa_apply(p["attn"], _attn_dims(cfg, kind), xin, positions,
                                 mode=mode, cache=cache, causal=causal,
                                 seq_lens=seq_lens, chunked=chunked)
    a = checkpoint_name(a, "attn_out")
    h = h + a

    if cfg.family == "encdec":
        from .attention import cross_apply, cross_memory
        dims = _attn_dims(cfg, "global")
        if mode == "train":
            mem_kv = cross_memory(p["cross"], dims, memory)
        elif mode == "prefill":
            mem_kv = cross_memory(p["cross"], dims, memory)
            new_cache = dict(new_cache)
            new_cache["cross_k"], new_cache["cross_v"] = mem_kv
        else:
            mem_kv = (cache["cross_k"], cache["cross_v"])
            new_cache = dict(new_cache)
            new_cache["cross_k"], new_cache["cross_v"] = mem_kv
        c = cross_apply(p["cross"], dims, rms_norm(h, p["cross_norm"], eps), mem_kv)
        h = h + c

    f, aux = _apply_ffn(p["ffn"], cfg, kind, rms_norm(h, p["ffn_norm"], eps),
                        mode, seq_lens=seq_lens if mode == "prefill" else None)
    return h + f, new_cache, aux


# ---------------------------------------------------------------------------
# full stacks
# ---------------------------------------------------------------------------


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def lm_init(key, cfg: ArchConfig):
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {"embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype)}
    params["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    params["head"] = tuple(
        layer_init(k, cfg, kind, dtype)
        for k, kind in zip(jax.random.split(keys[1], max(len(cfg.head), 1)), cfg.head))
    params["tail"] = tuple(
        layer_init(k, cfg, kind, dtype)
        for k, kind in zip(jax.random.split(keys[2], max(len(cfg.tail), 1)), cfg.tail))
    if "shared" in cfg.pattern or "shared" in cfg.head or "shared" in cfg.tail:
        params["shared_block"] = layer_init(keys[3], cfg, "global", dtype)

    def one_block(k):
        ks = jax.random.split(k, len(cfg.pattern))
        return tuple(
            layer_init(ks[j], cfg, kind, dtype) if kind != "shared" else {}
            for j, kind in enumerate(cfg.pattern))

    params["blocks"] = jax.vmap(one_block)(jax.random.split(keys[4], cfg.n_blocks))

    if cfg.family == "encdec":
        def enc_block(k):
            return layer_init(k, dataclass_enc(cfg), "global", dtype)
        params["enc_blocks"] = jax.vmap(enc_block)(
            jax.random.split(keys[5], cfg.enc_layers))
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dtype)
    return params


def dataclass_enc(cfg: ArchConfig) -> ArchConfig:
    """Encoder layers: plain bidirectional attention + dense FFN."""
    import dataclasses
    return dataclasses.replace(cfg, family="lm", moe=None, mla=None)


def lm_init_caches(cfg: ArchConfig, batch: int, max_len: int, mem_len: int = 0):
    dtype = _dtype(cfg)
    caches: dict[str, Any] = {
        "head": tuple(layer_cache(cfg, k, batch, max_len, dtype, mem_len) for k in cfg.head),
        "tail": tuple(layer_cache(cfg, k, batch, max_len, dtype, mem_len) for k in cfg.tail),
    }

    def stack(tree):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_blocks, *x.shape)).copy(), tree)

    caches["blocks"] = tuple(
        stack(layer_cache(cfg, kind if kind != "shared" else "global",
                          batch, max_len, dtype, mem_len))
        for kind in cfg.pattern)
    return caches


def _encoder_apply(params, cfg: ArchConfig, frames: jax.Array):
    """Bidirectional encoder over stub frame embeddings (B, Sm, d)."""
    ecfg = dataclass_enc(cfg)
    B, Sm, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(Sm)[None], (B, Sm))
    h = frames

    def body(carry, block_p):
        hh = carry
        hh, _, _ = layer_apply(block_p, ecfg, "global", hh, positions,
                               mode="train", cache=None, causal=False)
        return hh, ()

    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def lm_apply(params, cfg: ArchConfig, *, tokens=None, positions, mode: str,
             caches=None, frames=None, patches=None, seq_lens=None,
             chunked: bool = False):
    """Returns (h_final, new_caches, aux_sum).

    tokens: (B, S) int32 (text); patches: (B, Pimg, d) stub embeddings
    prepended to the sequence (VLM); frames: (B, Sm, d) encoder input
    (encdec family); seq_lens: (B,) valid-prefix lengths (in full-sequence
    index space, patches included) when rows are right-padded to a bucket
    length - pad entries then never reach any cache or recurrent state.
    ``chunked`` marks a chunked-prefill continuation: ``positions`` are
    then absolute (offset by the tokens already landed in ``caches``) and
    attention runs against the cache buffer (see serve/engine.py).
    """
    dtype = _dtype(cfg)
    from .layers import embed_apply
    h = embed_apply(params["embed"], tokens).astype(dtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, dtype)
    if patches is not None:
        h = jnp.concatenate([patches.astype(dtype), h], axis=1)

    memory = None
    if cfg.family == "encdec":
        assert frames is not None or (caches is not None and mode == "decode")
        if frames is not None:
            memory = _encoder_apply(params, cfg, frames.astype(dtype))

    aux_total = jnp.float32(0.0)
    new_caches: dict[str, Any] = {"head": [], "tail": [], "blocks": None}

    for i, kind in enumerate(cfg.head):
        c = caches["head"][i] if caches else None
        h, nc, aux = layer_apply(params["head"][i], cfg, kind, h, positions,
                                 mode=mode, cache=c, memory=memory,
                                 seq_lens=seq_lens, chunked=chunked)
        new_caches["head"].append(nc)
        aux_total += aux

    shared_p = params.get("shared_block")

    def block_body(carry, xs):
        hh, aux_acc = carry
        block_p, block_c = xs
        ncs = []
        for j, kind in enumerate(cfg.pattern):
            pj = shared_p if kind == "shared" else block_p[j]
            cj = block_c[j] if block_c is not None else None
            hh, ncj, aux = layer_apply(pj, cfg, kind if kind != "shared" else "global",
                                       hh, positions, mode=mode, cache=cj,
                                       memory=memory, seq_lens=seq_lens,
                                       chunked=chunked)
            ncs.append(ncj if ncj is not None else ())
            aux_acc = aux_acc + aux
        return (hh, aux_acc), tuple(ncs)

    body = block_body
    if mode == "train" and cfg.remat == "full":
        body = jax.checkpoint(block_body, prevent_cse=False)
    elif mode == "train" and cfg.remat == "save_heavy":
        policy = jax.checkpoint_policies.save_only_these_names(
            "moe_out", "attn_out")
        body = jax.checkpoint(block_body, prevent_cse=False, policy=policy)

    xs = (params["blocks"], caches["blocks"] if caches else None)
    (h, aux_total), blocks_nc = jax.lax.scan(body, (h, aux_total), xs)
    new_caches["blocks"] = blocks_nc

    for i, kind in enumerate(cfg.tail):
        c = caches["tail"][i] if caches else None
        h, nc, aux = layer_apply(params["tail"][i], cfg, kind, h, positions,
                                 mode=mode, cache=c, memory=memory,
                                 seq_lens=seq_lens, chunked=chunked)
        new_caches["tail"].append(nc)
        aux_total += aux

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    new_caches["head"] = tuple(new_caches["head"])
    new_caches["tail"] = tuple(new_caches["tail"])
    return h, (new_caches if mode != "train" else None), aux_total


def lm_logits(params, cfg: ArchConfig, h: jax.Array) -> jax.Array:
    logits = h @ params["embed"]["embedding"].T.astype(h.dtype)
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap)
