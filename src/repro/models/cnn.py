"""Paper-faithful CNN track: Mini-ResNet / Mini-MobileNetV2 / Mini-Seg.

These models exercise the full quantization machinery (core/qlinear) exactly
as the paper does: every conv/dense pre-activation is quantized per the
active QuantSpec (static | dynamic | pdq x per-tensor | per-channel), the
calibration tape records observations, and the same three-way comparison is
run in-domain and under the corruption suite (paper Tables 1-2).

A procedural "gratings" dataset stands in for ImageNet/COCO (no datasets in
this container): class k is a fixed random oriented color grating; a seg
variant labels each pixel by quadrant-dependent class.  Small nets reach
high accuracy in a few hundred Adam steps on CPU.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qlinear
from repro.core.policy import FP32, QuantSpec


# ---------------------------------------------------------------------------
# Synthetic data
# ---------------------------------------------------------------------------


def make_gratings(key: int, n: int, *, res: int = 24, n_classes: int = 10,
                  noise: float = 0.15):
    """Returns images (n, res, res, 3) in [0,1] and labels (n,)."""
    rng = np.random.default_rng(12345)          # class definitions are fixed
    freqs = rng.uniform(0.4, 1.6, (n_classes, 2))
    phases = rng.uniform(0, 2 * np.pi, (n_classes, 3))
    colors = rng.uniform(0.3, 1.0, (n_classes, 3))

    srng = np.random.default_rng(key)
    labels = srng.integers(0, n_classes, n)
    yy, xx = np.mgrid[0:res, 0:res] / res * 2 * np.pi
    imgs = np.empty((n, res, res, 3), np.float32)
    for i, c in enumerate(labels):
        base = np.sin(freqs[c, 0] * xx * 3 + freqs[c, 1] * yy * 3
                      + phases[c][:, None, None]).transpose(1, 2, 0)
        img = 0.5 + 0.5 * base * colors[c]
        img += srng.normal(0, noise, img.shape)
        imgs[i] = np.clip(img, 0, 1)
    return imgs, labels.astype(np.int64)


def seg_labels(labels: np.ndarray, res: int, n_classes: int) -> np.ndarray:
    """Per-pixel labels: class in one quadrant, background elsewhere."""
    n = labels.shape[0]
    out = np.zeros((n, res, res), np.int64)
    h = res // 2
    for i, c in enumerate(labels):
        q = c % 4
        r0, c0 = (0 if q < 2 else h), (0 if q % 2 == 0 else h)
        out[i, r0:r0 + h, c0:c0 + h] = 1 + (c % (n_classes - 1))
    return out


# ---------------------------------------------------------------------------
# Models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    arch: str = "mini_resnet"        # 'mini_resnet' | 'mini_mobilenet' | 'mini_seg'
    width: int = 32
    n_classes: int = 10
    res: int = 24


def _conv_init(key, kh, kw, cin, cout):
    scale = (2.0 / (kh * kw * cin)) ** 0.5
    return scale * jax.random.normal(key, (kh, kw, cin, cout))


def cnn_init(key, cfg: CNNConfig):
    w = cfg.width
    ks = jax.random.split(key, 24)
    if cfg.arch == "mini_resnet":
        return {
            "stem": _conv_init(ks[0], 3, 3, 3, w),
            "b1a": _conv_init(ks[1], 3, 3, w, w),
            "b1b": _conv_init(ks[2], 3, 3, w, w),
            "down1": _conv_init(ks[3], 3, 3, w, 2 * w),
            "b2a": _conv_init(ks[4], 3, 3, 2 * w, 2 * w),
            "b2b": _conv_init(ks[5], 3, 3, 2 * w, 2 * w),
            "down2": _conv_init(ks[6], 3, 3, 2 * w, 4 * w),
            "b3a": _conv_init(ks[7], 3, 3, 4 * w, 4 * w),
            "b3b": _conv_init(ks[8], 3, 3, 4 * w, 4 * w),
            "fc": 0.05 * jax.random.normal(ks[9], (4 * w, cfg.n_classes)),
            "fc_b": jnp.zeros((cfg.n_classes,)),
        }
    if cfg.arch == "mini_mobilenet":
        def block(i, cin, cout):
            return {
                "expand": _conv_init(ks[3 * i], 1, 1, cin, 4 * cin),
                "dw": _conv_init(ks[3 * i + 1], 3, 3, 1, 4 * cin),
                "project": _conv_init(ks[3 * i + 2], 1, 1, 4 * cin, cout),
            }
        return {
            "stem": _conv_init(ks[20], 3, 3, 3, w),
            "ir1": block(0, w, w),
            "ir2": block(1, w, 2 * w),
            "ir3": block(2, 2 * w, 2 * w),
            "ir4": block(3, 2 * w, 4 * w),
            "fc": 0.05 * jax.random.normal(ks[21], (4 * w, cfg.n_classes)),
            "fc_b": jnp.zeros((cfg.n_classes,)),
        }
    if cfg.arch == "mini_seg":
        return {
            "stem": _conv_init(ks[0], 3, 3, 3, w),
            "e1": _conv_init(ks[1], 3, 3, w, 2 * w),
            "e2": _conv_init(ks[2], 3, 3, 2 * w, 2 * w),
            "mid": _conv_init(ks[3], 3, 3, 2 * w, 2 * w),
            "d1": _conv_init(ks[4], 3, 3, 2 * w, w),
            "head": _conv_init(ks[5], 1, 1, w, cfg.n_classes),
        }
    raise ValueError(cfg.arch)


def _c(x, k, *, name, spec, qstate, tape, stride=(1, 1), groups=1):
    return qlinear.conv2d(x, k, None, stride=stride, padding="SAME",
                          feature_group_count=groups, name=name,
                          policy=spec.resolve(name), state=qstate, tape=tape)


def cnn_apply(params, x, *, cfg: CNNConfig, spec: QuantSpec = FP32,
              qstate: dict | None = None, tape: dict | None = None):
    """x: (N, res, res, 3) in [0,1] -> logits (N, n_classes) or seg map."""
    relu = jax.nn.relu
    x = qlinear.quantize_input(x, policy=spec.resolve("input"), state=qstate,
                               tape=tape)
    kw = dict(spec=spec, qstate=qstate, tape=tape)
    p = params

    if cfg.arch == "mini_resnet":
        h = relu(_c(x, p["stem"], name="stem", **kw))
        r = h
        h = relu(_c(h, p["b1a"], name="b1a", **kw))
        h = relu(_c(h, p["b1b"], name="b1b", **kw) + r)
        h = relu(_c(h, p["down1"], name="down1", stride=(2, 2), **kw))
        r = h
        h = relu(_c(h, p["b2a"], name="b2a", **kw))
        h = relu(_c(h, p["b2b"], name="b2b", **kw) + r)
        h = relu(_c(h, p["down2"], name="down2", stride=(2, 2), **kw))
        r = h
        h = relu(_c(h, p["b3a"], name="b3a", **kw))
        h = relu(_c(h, p["b3b"], name="b3b", **kw) + r)
        h = jnp.mean(h, axis=(1, 2))
        return qlinear.dense(h, p["fc"], p["fc_b"], name="fc",
                             policy=spec.resolve("fc"), state=qstate, tape=tape)

    if cfg.arch == "mini_mobilenet":
        h = relu(_c(x, p["stem"], name="stem", **kw))
        for i, (bname, stride) in enumerate(
                [("ir1", 1), ("ir2", 2), ("ir3", 1), ("ir4", 2)]):
            b = p[bname]
            inp = h
            e = relu(_c(h, b["expand"], name=f"{bname}/expand", **kw))
            e = relu(_c(e, b["dw"], name=f"{bname}/dw", stride=(stride, stride),
                        groups=e.shape[-1], **kw))
            h = _c(e, b["project"], name=f"{bname}/project", **kw)
            if h.shape == inp.shape:
                h = h + inp
        h = jnp.mean(h, axis=(1, 2))
        return qlinear.dense(h, p["fc"], p["fc_b"], name="fc",
                             policy=spec.resolve("fc"), state=qstate, tape=tape)

    if cfg.arch == "mini_seg":
        h = relu(_c(x, p["stem"], name="stem", **kw))
        h = relu(_c(h, p["e1"], name="e1", stride=(2, 2), **kw))
        h = relu(_c(h, p["e2"], name="e2", **kw))
        h = relu(_c(h, p["mid"], name="mid", **kw))
        h = jax.image.resize(h, (h.shape[0], cfg.res, cfg.res, h.shape[-1]),
                             "nearest")
        h = relu(_c(h, p["d1"], name="d1", **kw))
        return _c(h, p["head"], name="head", **kw)   # (N, res, res, classes)

    raise ValueError(cfg.arch)


# ---------------------------------------------------------------------------
# Training (fp32) - small Adam loop so quantization is evaluated on a
# *trained* network, as in the paper.
# ---------------------------------------------------------------------------


def train_cnn(cfg: CNNConfig, *, steps: int = 300, batch: int = 64,
              lr: float = 2e-3, seed: int = 0, segmentation: bool = False):
    params = cnn_init(jax.random.PRNGKey(seed), cfg)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def loss_fn(p, xb, yb):
        logits = cnn_apply(p, xb, cfg=cfg)
        if segmentation:
            ls = jax.nn.log_softmax(logits, -1)
            gold = jnp.take_along_axis(ls, yb[..., None], -1)[..., 0]
            return -jnp.mean(gold)
        ls = jax.nn.log_softmax(logits, -1)
        return -jnp.mean(jnp.take_along_axis(ls, yb[:, None], -1))

    @jax.jit
    def step(p, m, v, t, xb, yb):
        g = jax.grad(loss_fn)(p, xb, yb)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree.map(lambda a: a / (1 - 0.9 ** t), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.999 ** t), v)
        p = jax.tree.map(lambda a, mm, vv: a - lr * mm / (jnp.sqrt(vv) + 1e-8),
                         p, mh, vh)
        return p, m, v

    for t in range(1, steps + 1):
        xb, yb = make_gratings(1000 + t, batch, res=cfg.res,
                               n_classes=cfg.n_classes, noise=0.45)
        if segmentation:
            yb = seg_labels(yb, cfg.res, cfg.n_classes)
        params, m, v = step(params, m, v, t, jnp.asarray(xb), jnp.asarray(yb))
    return params


def evaluate(params, cfg: CNNConfig, images, labels, *, spec=FP32,
             qstate=None, segmentation: bool = False, batch: int = 128):
    """Top-1 accuracy (or mean pixel accuracy for segmentation)."""
    correct = total = 0
    for i in range(0, len(images), batch):
        xb = jnp.asarray(images[i: i + batch])
        yb = labels[i: i + batch]
        logits = cnn_apply(params, xb, cfg=cfg, spec=spec, qstate=qstate)
        pred = np.asarray(jnp.argmax(logits, -1))
        correct += (pred == yb).sum()
        total += yb.size
    return correct / total
