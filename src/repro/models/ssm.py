"""Mamba2 (SSD - state-space duality) block, arXiv:2405.21060.

Chunked training/prefill algorithm (the "SSD minimal" formulation):
intra-chunk attention-like term + inter-chunk state recurrence via lax.scan;
single-step recurrent update for decode.  The recurrent state is the only
cache - O(H * P * N) per sequence regardless of context length, which is why
the long_500k shape runs on SSM/hybrid architectures.

Layout: x ( B, L, d_model ) -> in_proj -> [z | xc | B | C | dt] with
d_inner = expand * d_model, H = d_inner / head_dim heads, n_groups = 1.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm
from .linops import lin


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.d_state


def ssm_init(key, cfg: SSMConfig, dtype):
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.d_state + cfg.n_heads
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, d_in_proj, dtype),
        "conv_w": 0.1 * jax.random.normal(ks[1], (cfg.d_conv, cfg.conv_dim), jnp.float32).astype(dtype),
        "conv_b": jnp.zeros((cfg.conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, cfg.n_heads)).astype(jnp.float32),
        "D": jnp.ones((cfg.n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((cfg.n_heads,), jnp.float32),
        "norm": jnp.zeros((cfg.d_inner,), dtype),
        "out_proj": dense_init(ks[4], cfg.d_inner, cfg.d_model, dtype),
    }


def ssm_init_cache(cfg: SSMConfig, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_dim), dtype),
        "state": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state), jnp.float32),
    }


def _split_proj(cfg: SSMConfig, zxbcdt: jax.Array):
    di, ds, H = cfg.d_inner, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di: 2 * di + 2 * ds]
    dt = zxbcdt[..., 2 * di + 2 * ds:]
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array,
                 prev: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv over (B, L, C); kernel w: (K, C).

    ``prev`` (B, K-1, C) supplies the left context - the conv-cache tail of
    the preceding chunk during chunked prefill (a fresh cache's zeros make
    this identical to plain zero padding)."""
    K = w.shape[0]
    if prev is None:
        pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([prev.astype(xBC.dtype), xBC], axis=1)
    out = sum(pad[:, i: i + xBC.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _segsum(x: jax.Array) -> jax.Array:
    """(..., T) -> (..., T, T) lower-triangular pairwise cumulative sums."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_scan(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD. x: (B, L, H, P); dt: (B, L, H); A: (H,) (negative);
    Bm/Cm: (B, L, N).  Returns y (B, L, H, P) and final state (B, H, P, N)."""
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, L)
    pad = (-L) % chunk
    if pad:
        # zero-pad is exact: dt=0 => decay=1 and zero state update
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk

    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    dA = dtc * A[None, None, None, :]                       # (B, nc, c, H)
    dA_cs = jnp.cumsum(dA, axis=2)

    # intra-chunk ("diagonal") term
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))       # (B, nc, H, c, c)
    scores = jnp.einsum("bztn,bzsn->bzts", Cc, Bc)          # (B, nc, c, c)
    y_diag = jnp.einsum("bzts,bzhts,bzsh,bzshp->bzthp", scores, Lmat, dtc, xc)

    # chunk summary states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)     # (B, nc, c, H)
    states = jnp.einsum("bzsn,bzsh,bzsh,bzshp->bzhpn",
                        Bc, decay_states, dtc, xc)          # (B, nc, H, P, N)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])               # (B, nc, H)
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(carry, inp):
        st_new, dec = inp                                    # (B,H,P,N), (B,H)
        prev = carry
        out = prev
        nxt = prev * dec[..., None, None] + st_new
        return nxt, out

    final, prev_states = jax.lax.scan(
        step, init_state.astype(jnp.float32),
        (states.swapaxes(0, 1).astype(jnp.float32), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                 # (B, nc, H, P, N)

    decay_in = jnp.exp(dA_cs)                                # (B, nc, c, H)
    y_off = jnp.einsum("bztn,bzth,bzhpn->bzthp", Cc, decay_in, prev_states)

    y = (y_diag + y_off).reshape(Bsz, Lp, H, P)[:, :L]
    return y.astype(x.dtype), final


def ssm_apply(p, cfg: SSMConfig, x: jax.Array, *, mode: str, cache=None,
              seq_lens=None):
    """x: (B, L, d_model); decode has L == 1 and requires cache.

    ``seq_lens`` (B,) marks right-padded prefill rows: entries at index >=
    seq_lens[b] are bucket padding.  Zeroing their dt makes the recurrence
    skip them exactly (decay exp(0)=1, zero state update - the same
    property ssd_scan's internal chunk padding relies on), and the conv
    cache tail is gathered at each row's true end instead of the padded
    one, so decode continues from a state bit-identical to an unpadded
    prefill."""
    B, L, _ = x.shape
    H, P, N = cfg.n_heads, cfg.head_dim, cfg.d_state
    zxbcdt = lin(x, p["in_proj"])
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B, L, H)
    A = -jnp.exp(p["A_log"])                                         # (H,)

    if mode == "decode":
        assert cache is not None and L == 1
        window = jnp.concatenate([cache["conv"], xBC], axis=1)       # (B, K, C)
        conv_out = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                       p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32))
        xc = conv_out[:, : cfg.d_inner].reshape(B, H, P)
        Bm = conv_out[:, cfg.d_inner: cfg.d_inner + N]
        Cm = conv_out[:, cfg.d_inner + N:]
        dt1 = dt[:, 0]                                               # (B, H)
        dA = jnp.exp(dt1 * A[None, :])                               # (B, H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt1, xc.astype(jnp.float32), Bm)
        state = cache["state"] * dA[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, Cm)
        y = y + p["D"][None, :, None] * xc.astype(jnp.float32)
        y = y.reshape(B, 1, cfg.d_inner).astype(x.dtype)
        new_cache = {"conv": window[:, 1:], "state": state}
    else:
        # prefill: the conv left-context and the scan's initial state both
        # come from the cache when one is threaded (zeros on a fresh row,
        # i.e. identical to the uncached path; the landed tail/state of the
        # previous chunk during chunked prefill - continuation is exact
        # because the recurrence carries the full SSM state).
        conv_out = _causal_conv(xBC, p["conv_w"], p["conv_b"],
                                prev=cache["conv"] if cache is not None else None)
        xc = conv_out[..., : cfg.d_inner].reshape(B, L, H, P)
        Bm = conv_out[..., cfg.d_inner: cfg.d_inner + N].astype(jnp.float32)
        Cm = conv_out[..., cfg.d_inner + N:].astype(jnp.float32)
        if seq_lens is not None:
            valid = jnp.arange(L)[None, :] < seq_lens[:, None]
            dt = jnp.where(valid[..., None], dt, 0.0)      # dt: (B, L, H)
        init_state = cache["state"] if cache is not None else None
        y, final = ssd_scan(xc.astype(jnp.float32), dt, A, Bm, Cm, cfg.chunk,
                            init_state)
        y = y + p["D"][None, None, :, None] * xc.astype(y.dtype)
        y = y.reshape(B, L, cfg.d_inner).astype(x.dtype)
        new_cache = None
        if cache is not None:   # prefill keeps conv tail + final state
            window = jnp.concatenate([cache["conv"], xBC], axis=1)
            if seq_lens is None:
                tail = window[:, -(cfg.d_conv - 1):]
            else:
                # last d_conv-1 REAL inputs end at window index
                # (d_conv-1) + seq_len - 1, i.e. start at index seq_len
                idx = seq_lens[:, None] + jnp.arange(cfg.d_conv - 1)[None, :]
                tail = window[jnp.arange(B)[:, None], idx]
            new_cache = {"conv": tail, "state": final}

    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return lin(y, p["out_proj"]), new_cache
