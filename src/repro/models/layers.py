"""Shared neural-net building blocks (pure JAX, pytree params).

Params are nested dicts; leaf names are the contract the sharding rules in
``repro.distributed.sharding`` key on - do not rename casually.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .linops import _common_group, is_quantized, is_segment_view, lin, lin_grouped


def uniform_init(key, shape, scale, dtype):
    return jax.random.uniform(key, shape, dtype=jnp.float32, minval=-scale,
                              maxval=scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype):
    return uniform_init(key, (d_in, d_out), (3.0 / d_in) ** 0.5, dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                              # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, dh/2)
    sin = jnp.sin(angles)[..., None, :]                        # (..., seq, 1, dh/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp_apply(p, x):
    # gate/up consume the same normed input: quantized params run ONE
    # prologue + ONE wide W8A8 matmul for the pair, and when w_down is
    # quantized too the gate/up matmul's epilogue also computes
    # silu(g) * u and w_down's PDQ prologue in-kernel (ops.pdq_mlp)
    grec = _common_group((p["w_gate"], p["w_up"]))
    if (grec is not None and is_quantized(p["w_down"])
            and not is_segment_view(p["w_down"])):
        return ops.pdq_mlp(x, grec, p["w_down"], out_dtype=x.dtype)
    g, u = lin_grouped(x, (p["w_gate"], p["w_up"]))
    return lin(jax.nn.silu(g) * u, p["w_down"])


# ---------------------------------------------------------------------------
# Embedding / unembedding with chunked cross-entropy
# ---------------------------------------------------------------------------


def embed_init(key, vocab, d_model, dtype):
    return {"embedding": 0.02 * jax.random.normal(key, (vocab, d_model), jnp.float32).astype(dtype)}


def embed_apply(p, tokens):
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed_logits(p, h, softcap_val: float | None = None):
    logits = h @ p["embedding"].T
    return softcap(logits.astype(jnp.float32), softcap_val)


def chunked_xent_loss(
    embedding: jax.Array,       # (V, d)
    h: jax.Array,               # (B, S, d) final hidden states
    labels: jax.Array,          # (B, S) int32
    *,
    chunk: int = 512,
    logit_softcap: float | None = None,
) -> jax.Array:
    """Cross-entropy without materializing the full (B, S, V) logits.

    Scans over sequence chunks; each chunk's logits exist only inside the
    (rematerialized) scan body.  Essential at vocab >= 100k x seq 4k.
    """
    B, S, d = h.shape
    chunk = min(chunk, S)
    n_chunks = S // chunk
    h_c = h[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, d).swapaxes(0, 1)
    y_c = labels[:, : n_chunks * chunk].reshape(B, n_chunks, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xs):
        hc, yc = xs                                   # (B, chunk, d), (B, chunk)
        logits = softcap((hc @ embedding.T).astype(jnp.float32), logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), ()

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (h_c, y_c))
    return total / (B * n_chunks * chunk)
