"""Public model API: build a ModelBundle from an ArchConfig.

The bundle's step functions are pure and jit/pjit-friendly; the dry-run
lowers them against ``input_specs(shape)`` ShapeDtypeStructs without any
allocation.

Shapes (assignment):
    train_4k     seq 4096,   global batch 256   -> train step
    prefill_32k  seq 32768,  global batch 32    -> prefill (serve) step
    decode_32k   seq 32768,  global batch 128   -> one-token decode step
    long_500k    seq 524288, global batch 1     -> one-token decode step
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kernel_ops

from .config import ArchConfig
from .layers import chunked_xent_loss
from .transformer import _dtype, lm_apply, lm_init, lm_init_caches, lm_logits


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str           # 'train' | 'prefill' | 'decode'
    seq: int
    batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def _src_len(cfg: ArchConfig, seq: int) -> int:
    """Encoder-side length for encdec (audio frames downsample ~4x)."""
    return max(seq // 4, 8)


def _patch_count(cfg: ArchConfig) -> int:
    return cfg.frontend_tokens if cfg.frontend == "vision" else 0


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    train_loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_caches: Callable[..., Any]
    input_specs: Callable[[str], dict[str, Any]]
    cache_slice: Callable[..., Any] = None
    cache_merge: Callable[..., Any] = None
    prefill_many: Callable[..., Any] = None
    cache_scatter: Callable[..., Any] = None
    prefill_chunk: Callable[..., Any] = None
    paged_cache: Callable[..., Any] = None


@dataclasses.dataclass(frozen=True)
class _PageMeta:
    """Per-leaf paging classification (a pytree leaf of the meta tree)."""
    kind: str            # 'seq' (pageable) | 'flat' (stays per-slot rows)
    seq_axis: int = -1
    n_leaf: int = 0      # this leaf's pages per sequence (>= pool n_pp)
    shape: tuple = ()
    dtype: Any = None


@dataclasses.dataclass(frozen=True)
class PagedCacheOps:
    """Device half of the paged KV-cache pool (serve/pages.py holds the
    allocator): closures that move data between the physical page pool and
    the logical (B, ...) cache layout the step functions consume.  Every
    movement is one fused row scatter per leaf (``ops.cache_scatter_pages``
    - the same scalar-prefetched machinery as the slot-row scatter), so
    the paged engine adds no host round-trips.

    Leaves whose shape does not grow with ``max_len`` (SSM/conv state,
    windowed rings shorter than max_len, encdec memories, the flat ``len``
    leaf) classify 'flat' and keep their per-slot rows inside the pool
    tree untouched - paging is per-leaf, not per-family.
    """
    page: int
    n_pp: int            # page-table width: max_len // page
    meta: Any            # cache-shaped tree of _PageMeta
    init: Callable[..., Any]       # (n_pages) -> physical pool tree
    gather: Callable[..., Any]     # (pool, pt, lengths) -> logical caches
    writeback: Callable[..., Any]  # (pool, logical, pt, positions) -> pool
    land: Callable[..., Any]       # (pool, sub, src_map, rows, js) -> pool
    copy: Callable[..., Any]       # (pool, copy_map) -> pool (COW)
    capture: Callable[..., Any]    # (pool, slot, page_ids) -> host record
    restore: Callable[..., Any]    # (pool, rec, pmap, src_map) -> pool


def build_model(cfg: ArchConfig) -> ModelBundle:
    cfg = cfg.validate()
    dtype = _dtype(cfg)

    def init(rng):
        return lm_init(rng, cfg)

    # ----------------------------------------------------------------- train
    def train_loss(params, batch):
        tokens = batch["tokens"]
        labels = batch["labels"]
        B, S_text = tokens.shape
        P = _patch_count(cfg)
        pos = jnp.broadcast_to(jnp.arange(P + S_text)[None], (B, P + S_text))
        h, _, aux = lm_apply(
            params, cfg, tokens=tokens, positions=pos, mode="train",
            frames=batch.get("frames"), patches=batch.get("patches"))
        h_text = h[:, P:]
        loss = chunked_xent_loss(params["embed"]["embedding"], h_text, labels,
                                 chunk=cfg.loss_chunk,
                                 logit_softcap=cfg.logit_softcap)
        return loss + 0.01 * aux, {"xent": loss, "aux": aux}

    # --------------------------------------------------------------- serving
    def init_caches(batch: int, max_len: int, mem_len: int = 0):
        return lm_init_caches(cfg, batch, max_len, mem_len)

    def prefill(params, batch, caches):
        tokens = batch["tokens"]
        B, S_text = tokens.shape
        P = _patch_count(cfg)
        pos = jnp.broadcast_to(jnp.arange(P + S_text)[None], (B, P + S_text))
        h, caches, _ = lm_apply(
            params, cfg, tokens=tokens, positions=pos, mode="prefill",
            caches=caches, frames=batch.get("frames"),
            patches=batch.get("patches"))
        logits = lm_logits(params, cfg, h[:, -1:])[:, 0]
        return logits, caches

    def prefill_many(params, batch, caches, seq_lens):
        """Batched bucketed prefill over right-padded prompts.

        batch['tokens']: (B, L) int32 where row b holds seq_lens[b] real
        tokens followed by padding up to the bucket length L.  ``caches``
        is a fresh B-row cache pool; every row is fully (re)written -
        pad entries are redirected onto the row's last real token (see
        attention._clamp_padded / ssm_apply) and masked out of MoE
        routing (moe.route token_mask, so they claim no expert-capacity
        slot - DESIGN.md Sec. 4), making the resulting rows bit-identical
        to B independent unpadded prefills.  Returns
        (logits (B, vocab) of each row's LAST REAL token, caches); land
        the rows into the serving pool with ``cache_scatter``.

        Because L is the only shape that varies across workloads, an
        engine lifetime compiles at most len(buckets) executables of this
        function - the per-request path recompiled per distinct prompt
        length instead.
        """
        tokens = batch["tokens"]
        B, S_text = tokens.shape
        P = _patch_count(cfg)
        pos = jnp.broadcast_to(jnp.arange(P + S_text)[None], (B, P + S_text))
        sl = seq_lens.astype(jnp.int32)
        # valid prefix incl patches; rows with seq_lens == 0 are DUMMY rows
        # of a partially-filled batch - their patch tokens are masked too,
        # so a dummy row routes nothing through MoE and claims no expert
        # capacity (the cache scatter drops its rows regardless)
        tot = jnp.where(sl > 0, sl + P, 0)
        h, caches, _ = lm_apply(
            params, cfg, tokens=tokens, positions=pos, mode="prefill",
            caches=caches, frames=batch.get("frames"),
            patches=batch.get("patches"), seq_lens=tot)
        h_last = h[jnp.arange(B), jnp.maximum(tot - 1, 0)][:, None]
        logits = lm_logits(params, cfg, h_last)[:, 0]
        return logits, caches

    def prefill_chunk(params, batch, caches, seq_lens, start_lens):
        """Continue a chunked prefill: row b of ``caches`` already holds
        ``start_lens[b]`` landed tokens; this call appends the next chunk
        (``seq_lens[b]`` real tokens, right-padded to the chunk bucket) and
        attends the whole cache buffer, so queries see both the landed
        prefix and the chunk.  Returns (logits of each row's last real
        token, caches) - the final chunk's logits seed decoding exactly as
        ``prefill_many``'s do.  Text-only families: the vision patch
        prepend and the encdec encoder pass assume a single whole-prompt
        prefill.
        """
        if cfg.frontend == "vision" or cfg.family == "encdec":
            raise NotImplementedError(
                f"chunked prefill supports text-only families, not "
                f"frontend={cfg.frontend!r} / family={cfg.family!r}")
        tokens = batch["tokens"]
        B, L = tokens.shape
        start = start_lens.astype(jnp.int32)
        pos = start[:, None] + jnp.arange(L, dtype=jnp.int32)[None]
        sl = seq_lens.astype(jnp.int32)
        h, caches, _ = lm_apply(
            params, cfg, tokens=tokens, positions=pos, mode="prefill",
            caches=caches, seq_lens=sl, chunked=True)
        h_last = h[jnp.arange(B), jnp.maximum(sl - 1, 0)][:, None]
        logits = lm_logits(params, cfg, h_last)[:, 0]
        return logits, caches

    def decode_step(params, caches, tokens, positions):
        """tokens: (B, 1); positions: (B, 1) absolute positions."""
        h, caches, _ = lm_apply(params, cfg, tokens=tokens, positions=positions,
                                mode="decode", caches=caches)
        logits = lm_logits(params, cfg, h[:, -1:])[:, 0]
        return logits, caches

    # -------------------------------------------------- cache slot helpers
    # head/tail cache leaves carry batch on axis 0; scanned block caches are
    # stacked (n_blocks, batch, ...) so batch is axis 1.
    def cache_slice(caches, lo: int, hi: int):
        return {
            "head": jax.tree.map(lambda c: c[lo:hi], caches["head"]),
            "tail": jax.tree.map(lambda c: c[lo:hi], caches["tail"]),
            "blocks": jax.tree.map(lambda c: c[:, lo:hi], caches["blocks"]),
        }

    def cache_merge(caches, sub, lo: int):
        return {
            "head": jax.tree.map(lambda c, s: c.at[lo:lo + s.shape[0]].set(s),
                                 caches["head"], sub["head"]),
            "tail": jax.tree.map(lambda c, s: c.at[lo:lo + s.shape[0]].set(s),
                                 caches["tail"], sub["tail"]),
            "blocks": jax.tree.map(lambda c, s: c.at[:, lo:lo + s.shape[1]].set(s),
                                   caches["blocks"], sub["blocks"]),
        }

    def cache_scatter(caches, sub, src_map):
        """Pool slot s takes sub batch row src_map[s]; src_map[s] == -1
        keeps the pooled slot bit-exactly.  One fused scatter per leaf
        (kernels/kv_cache.cache_scatter_p on TPU) lands an entire bucketed
        prefill batch at once, replacing the per-request slice/merge loop.
        src_map shape: (pool_slots,) int32, values in [-1, sub_batch).
        """
        scat = kernel_ops.cache_scatter_rows
        return {
            "head": jax.tree.map(lambda c, s: scat(c, s, src_map),
                                 caches["head"], sub["head"]),
            "tail": jax.tree.map(lambda c, s: scat(c, s, src_map),
                                 caches["tail"], sub["tail"]),
            "blocks": jax.tree.map(lambda c, s: scat(c, s, src_map, batch_axis=1),
                                   caches["blocks"], sub["blocks"]),
        }

    # ------------------------------------------------------ paged cache pool
    def paged_cache(batch: int, max_len: int, mem_len: int = 0,
                    page: int = 64) -> PagedCacheOps:
        """Build the device ops for a paged cache pool (see PagedCacheOps).

        Pageable leaves are found structurally: a leaf whose shape differs
        between ``init_caches(max_len)`` and ``init_caches(2 * max_len)``
        grows with the sequence, and the differing axis is its seq axis;
        everything else (SSM/conv state, sub-max_len window rings, encdec
        memories, ``len``) stays flat per-slot rows.  The physical pool
        replaces (batch, seq) with a single leading page axis: head/tail
        leaves become (n_pages, ..., page, ...), stacked block leaves
        (n_blocks, n_pages, ..., page, ...), so the existing
        ``distributed/sharding.serve_pool_specs`` row-axis specs shard the
        paged pool over 'data' unchanged.
        """
        assert max_len % page == 0, (
            f"page size {page} must divide max_len {max_len}")
        n_pp = max_len // page
        a = jax.eval_shape(lambda: init_caches(batch, max_len, mem_len))
        b = jax.eval_shape(lambda: init_caches(batch, 2 * max_len, mem_len))

        def classify(sa, sb, ba):
            diffs = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                     if x != y]
            if not diffs:
                return _PageMeta("flat", shape=sa.shape, dtype=sa.dtype)
            assert len(diffs) == 1, (sa.shape, sb.shape)
            ax = diffs[0]
            S = sa.shape[ax]
            assert S % page == 0, (
                f"page size {page} does not divide seq extent {S} of cache "
                f"leaf {sa.shape}; pick a power-of-two page <= 128 that "
                f"divides max_len")
            assert S // page >= n_pp, (sa.shape, ax, page, n_pp)
            return _PageMeta("seq", seq_axis=ax, n_leaf=S // page,
                             shape=sa.shape, dtype=sa.dtype)

        secs = (("head", 0), ("tail", 0), ("blocks", 1))
        meta = {sec: jax.tree.map(functools.partial(classify, ba=ba),
                                  a[sec], b[sec]) for sec, ba in secs}
        # batch-1 pristine init: the gather scratch must start from each
        # leaf's INIT fill (``pos`` fills with -1 = empty-slot sentinel, not
        # zero), so unallocated page regions read bit-exactly like the
        # never-written region of a slot-row cache
        base1 = init_caches(1, max_len, mem_len)

        def tmap(fn, *trees):
            return {sec: jax.tree.map(functools.partial(fn, ba=ba),
                                      meta[sec], *(t[sec] for t in trees))
                    for sec, ba in secs}

        def init(n_pages: int):
            def one(m, *, ba):
                if m.kind == "flat":
                    return jnp.zeros(m.shape, m.dtype)
                shape = list(m.shape)
                shape[ba] = n_pages
                shape[m.seq_axis] = page
                return jnp.zeros(tuple(shape), m.dtype)
            return tmap(one)

        def gather(pool, pt, lengths):
            """Physical pages -> a (B, ...) logical tree the unmodified
            decode step runs on.  pt: (B, n_pp) int32 page tables;
            lengths: (B,) written tokens per row.  -1 entries and pages at
            or beyond the write frontier gather nothing, leaving the
            scratch at the leaf's INIT fill - bit-exactly the
            never-written region of a slot-row cache.  The frontier mask
            also launders recycled pages: a page freshly allocated for
            decode growth (still holding its previous owner's bytes) is
            masked on first gather, written through the logical scratch,
            and comes back fully cleaned by ``writeback``."""
            B = pt.shape[0]
            keep = (jnp.arange(pt.shape[1], dtype=jnp.int32)[None, :] * page
                    ) < lengths[:, None]
            pt = jnp.where(keep, pt, -1)

            def one(m, pool_leaf, b1, *, ba):
                if m.kind == "flat":
                    return pool_leaf
                shape = list(m.shape)
                shape[ba] = B                  # local batch under shard_map
                z = jnp.broadcast_to(b1, tuple(shape))
                zp = kernel_ops.to_page_rows(z, m.seq_axis, page,
                                             batch_axis=ba)
                gmap = jnp.full((B, m.n_leaf), -1, jnp.int32)
                gmap = gmap.at[:, :pt.shape[1]].set(pt).reshape(B * m.n_leaf)
                out = kernel_ops.cache_scatter_pages(zp, pool_leaf, gmap,
                                                     batch_axis=ba)
                return kernel_ops.from_page_rows(out, tuple(shape),
                                                 m.seq_axis, page,
                                                 batch_axis=ba)
            return tmap(one, pool, base1)

        def writeback(pool, logical, pt, positions, n_steps=None,
                      max_steps: int = 1):
            """Scatter each live row's decode-written pages back into the
            pool: the pages holding positions ``positions[b]`` through
            ``positions[b] + n_steps[b] - 1`` (the N-step block a fused
            decode dispatch wrote; ``n_steps=None`` is the single-step
            case).  ``max_steps`` is the STATIC block bound, fixing the
            per-row window at ``W = (max_steps + page - 2) // page + 1``
            candidate pages (W == 1 reduces exactly to the old single-page
            map).  Whole pages are written, so a recycled page comes back
            fully cleaned (init fill beyond the last written token - the
            gather laundered it).  Free slots (-1 table entries) and
            beyond-window candidates land on the write-only DUMP page 0,
            where colliding writes are harmless: page 0 is never read."""
            B = pt.shape[0]
            p0 = positions[:, 0]
            j0 = jnp.clip(p0 // page, 0, pt.shape[1] - 1)
            if n_steps is None:
                j1 = j0
            else:
                last = p0 + jnp.maximum(n_steps, 1) - 1
                j1 = jnp.clip(last // page, 0, pt.shape[1] - 1)
            W = (int(max_steps) + page - 2) // page + 1

            def one(m, pool_leaf, lg, *, ba):
                if m.kind == "flat":
                    return lg                  # flat state IS the new rows
                N = pool_leaf.shape[ba]
                wmap = jnp.full((N,), -1, jnp.int32)
                for w in range(W):
                    jb = jnp.minimum(j0 + w, pt.shape[1] - 1)
                    valid = (j0 + w) <= j1
                    ent = pt[jnp.arange(B), jb]
                    tgt = jnp.where(valid & (ent > 0), ent, 0)
                    val = jnp.where(valid,
                                    jnp.arange(B) * m.n_leaf + jb, -1)
                    wmap = wmap.at[tgt].set(val)
                lp = kernel_ops.to_page_rows(lg, m.seq_axis, page,
                                             batch_axis=ba)
                return kernel_ops.cache_scatter_pages(pool_leaf, lp, wmap,
                                                      batch_axis=ba)
            return tmap(one, pool, logical)

        def land(pool, sub, src_map, land_rows, land_js):
            """Land a bucketed prefill batch: flat leaves scatter whole
            slot rows through ``src_map`` (the existing semantics); paged
            leaves scatter pages - pool page p takes page ``land_js[p]``
            of scratch row ``land_rows[p]`` (-1 keeps; shared prefix pages
            are excluded by the planner)."""
            def one(m, pool_leaf, s, *, ba):
                if m.kind == "flat":
                    return kernel_ops.cache_scatter_rows(pool_leaf, s,
                                                         src_map,
                                                         batch_axis=ba)
                lmap = jnp.where(land_rows >= 0,
                                 land_rows * m.n_leaf + land_js, -1)
                sp = kernel_ops.to_page_rows(s, m.seq_axis, page,
                                             batch_axis=ba)
                return kernel_ops.cache_scatter_pages(pool_leaf, sp, lmap,
                                                      batch_axis=ba)
            return tmap(one, pool, sub)

        def copy(pool, copy_map):
            """Pool-internal page copy (the COW arm): page p takes page
            ``copy_map[p]`` (-1 keeps)."""
            def one(m, pool_leaf, *, ba):
                if m.kind == "flat":
                    return pool_leaf
                return kernel_ops.cache_scatter_pages(pool_leaf, pool_leaf,
                                                      copy_map,
                                                      batch_axis=ba)
            return tmap(one, pool)

        def capture(pool, slot: int, page_ids):
            """Host (numpy) copy of one request's pages - padded to n_pp
            so the restore program compiles once - plus its flat per-slot
            rows: the spill record's payload."""
            ids = jnp.asarray(np.asarray(page_ids, np.int32))
            k = int(ids.shape[0])

            def one(m, pool_leaf, *, ba):
                if m.kind == "flat":
                    sel = pool_leaf[slot:slot + 1] if ba == 0 else \
                        pool_leaf[:, slot:slot + 1]
                    return np.asarray(sel)
                sel = np.asarray(jnp.take(pool_leaf, ids, axis=ba))
                pad = list(sel.shape)
                pad[ba] = n_pp - k
                return np.concatenate(
                    [sel, np.zeros(pad, sel.dtype)], axis=ba)
            return tmap(one, pool)

        def restore(pool, rec, pmap, src_map):
            """Scatter a spill record back in: paged leaves from its
            captured (n_pp-padded) pages through ``pmap`` (pool page ->
            record page index, -1 keeps), flat leaves from its captured
            rows through ``src_map`` (slot -> record row 0, -1 keeps)."""
            def one(m, pool_leaf, rv, *, ba):
                if m.kind == "flat":
                    return kernel_ops.cache_scatter_rows(pool_leaf, rv,
                                                         src_map,
                                                         batch_axis=ba)
                return kernel_ops.cache_scatter_pages(pool_leaf, rv, pmap,
                                                      batch_axis=ba)
            return tmap(one, pool, rec)

        return PagedCacheOps(page=page, n_pp=n_pp, meta=meta, init=init,
                             gather=gather, writeback=writeback, land=land,
                             copy=copy, capture=capture, restore=restore)

    # ------------------------------------------------------------ dry-run IO
    def input_specs(shape_name: str) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every input of the step function."""
        sp = SHAPES[shape_name]
        f32, i32 = jnp.float32, jnp.int32
        P = _patch_count(cfg)
        if sp.kind == "train":
            S_text = sp.seq - P
            specs = {
                "tokens": jax.ShapeDtypeStruct((sp.batch, S_text), i32),
                "labels": jax.ShapeDtypeStruct((sp.batch, S_text), i32),
            }
            if cfg.frontend == "vision":
                specs["patches"] = jax.ShapeDtypeStruct((sp.batch, P, cfg.d_model), dtype)
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (sp.batch, _src_len(cfg, sp.seq), cfg.d_model), dtype)
            return specs
        if sp.kind == "prefill":
            S_text = sp.seq - P
            specs = {
                "tokens": jax.ShapeDtypeStruct((sp.batch, S_text), i32),
            }
            if cfg.frontend == "vision":
                specs["patches"] = jax.ShapeDtypeStruct((sp.batch, P, cfg.d_model), dtype)
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (sp.batch, _src_len(cfg, sp.seq), cfg.d_model), dtype)
            return specs
        # decode: one new token against a seq-length cache
        mem_len = _src_len(cfg, sp.seq) if cfg.family == "encdec" else 0
        caches = jax.eval_shape(lambda: init_caches(sp.batch, sp.seq, mem_len))
        return {
            "tokens": jax.ShapeDtypeStruct((sp.batch, 1), i32),
            "positions": jax.ShapeDtypeStruct((sp.batch, 1), i32),
            "caches": caches,
        }

    return ModelBundle(cfg=cfg, init=init, train_loss=train_loss,
                       prefill=prefill, decode_step=decode_step,
                       init_caches=init_caches, input_specs=input_specs,
                       cache_slice=cache_slice, cache_merge=cache_merge,
                       prefill_many=prefill_many, cache_scatter=cache_scatter,
                       prefill_chunk=prefill_chunk, paged_cache=paged_cache)
