"""Public model API: build a ModelBundle from an ArchConfig.

The bundle's step functions are pure and jit/pjit-friendly; the dry-run
lowers them against ``input_specs(shape)`` ShapeDtypeStructs without any
allocation.

Shapes (assignment):
    train_4k     seq 4096,   global batch 256   -> train step
    prefill_32k  seq 32768,  global batch 32    -> prefill (serve) step
    decode_32k   seq 32768,  global batch 128   -> one-token decode step
    long_500k    seq 524288, global batch 1     -> one-token decode step
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops

from .config import ArchConfig
from .layers import chunked_xent_loss
from .transformer import _dtype, lm_apply, lm_init, lm_init_caches, lm_logits


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str           # 'train' | 'prefill' | 'decode'
    seq: int
    batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def _src_len(cfg: ArchConfig, seq: int) -> int:
    """Encoder-side length for encdec (audio frames downsample ~4x)."""
    return max(seq // 4, 8)


def _patch_count(cfg: ArchConfig) -> int:
    return cfg.frontend_tokens if cfg.frontend == "vision" else 0


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    train_loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_caches: Callable[..., Any]
    input_specs: Callable[[str], dict[str, Any]]
    cache_slice: Callable[..., Any] = None
    cache_merge: Callable[..., Any] = None
    prefill_many: Callable[..., Any] = None
    cache_scatter: Callable[..., Any] = None
    prefill_chunk: Callable[..., Any] = None


def build_model(cfg: ArchConfig) -> ModelBundle:
    cfg = cfg.validate()
    dtype = _dtype(cfg)

    def init(rng):
        return lm_init(rng, cfg)

    # ----------------------------------------------------------------- train
    def train_loss(params, batch):
        tokens = batch["tokens"]
        labels = batch["labels"]
        B, S_text = tokens.shape
        P = _patch_count(cfg)
        pos = jnp.broadcast_to(jnp.arange(P + S_text)[None], (B, P + S_text))
        h, _, aux = lm_apply(
            params, cfg, tokens=tokens, positions=pos, mode="train",
            frames=batch.get("frames"), patches=batch.get("patches"))
        h_text = h[:, P:]
        loss = chunked_xent_loss(params["embed"]["embedding"], h_text, labels,
                                 chunk=cfg.loss_chunk,
                                 logit_softcap=cfg.logit_softcap)
        return loss + 0.01 * aux, {"xent": loss, "aux": aux}

    # --------------------------------------------------------------- serving
    def init_caches(batch: int, max_len: int, mem_len: int = 0):
        return lm_init_caches(cfg, batch, max_len, mem_len)

    def prefill(params, batch, caches):
        tokens = batch["tokens"]
        B, S_text = tokens.shape
        P = _patch_count(cfg)
        pos = jnp.broadcast_to(jnp.arange(P + S_text)[None], (B, P + S_text))
        h, caches, _ = lm_apply(
            params, cfg, tokens=tokens, positions=pos, mode="prefill",
            caches=caches, frames=batch.get("frames"),
            patches=batch.get("patches"))
        logits = lm_logits(params, cfg, h[:, -1:])[:, 0]
        return logits, caches

    def prefill_many(params, batch, caches, seq_lens):
        """Batched bucketed prefill over right-padded prompts.

        batch['tokens']: (B, L) int32 where row b holds seq_lens[b] real
        tokens followed by padding up to the bucket length L.  ``caches``
        is a fresh B-row cache pool; every row is fully (re)written -
        pad entries are redirected onto the row's last real token (see
        attention._clamp_padded / ssm_apply) and masked out of MoE
        routing (moe.route token_mask, so they claim no expert-capacity
        slot - DESIGN.md Sec. 4), making the resulting rows bit-identical
        to B independent unpadded prefills.  Returns
        (logits (B, vocab) of each row's LAST REAL token, caches); land
        the rows into the serving pool with ``cache_scatter``.

        Because L is the only shape that varies across workloads, an
        engine lifetime compiles at most len(buckets) executables of this
        function - the per-request path recompiled per distinct prompt
        length instead.
        """
        tokens = batch["tokens"]
        B, S_text = tokens.shape
        P = _patch_count(cfg)
        pos = jnp.broadcast_to(jnp.arange(P + S_text)[None], (B, P + S_text))
        sl = seq_lens.astype(jnp.int32)
        # valid prefix incl patches; rows with seq_lens == 0 are DUMMY rows
        # of a partially-filled batch - their patch tokens are masked too,
        # so a dummy row routes nothing through MoE and claims no expert
        # capacity (the cache scatter drops its rows regardless)
        tot = jnp.where(sl > 0, sl + P, 0)
        h, caches, _ = lm_apply(
            params, cfg, tokens=tokens, positions=pos, mode="prefill",
            caches=caches, frames=batch.get("frames"),
            patches=batch.get("patches"), seq_lens=tot)
        h_last = h[jnp.arange(B), jnp.maximum(tot - 1, 0)][:, None]
        logits = lm_logits(params, cfg, h_last)[:, 0]
        return logits, caches

    def prefill_chunk(params, batch, caches, seq_lens, start_lens):
        """Continue a chunked prefill: row b of ``caches`` already holds
        ``start_lens[b]`` landed tokens; this call appends the next chunk
        (``seq_lens[b]`` real tokens, right-padded to the chunk bucket) and
        attends the whole cache buffer, so queries see both the landed
        prefix and the chunk.  Returns (logits of each row's last real
        token, caches) - the final chunk's logits seed decoding exactly as
        ``prefill_many``'s do.  Text-only families: the vision patch
        prepend and the encdec encoder pass assume a single whole-prompt
        prefill.
        """
        if cfg.frontend == "vision" or cfg.family == "encdec":
            raise NotImplementedError(
                f"chunked prefill supports text-only families, not "
                f"frontend={cfg.frontend!r} / family={cfg.family!r}")
        tokens = batch["tokens"]
        B, L = tokens.shape
        start = start_lens.astype(jnp.int32)
        pos = start[:, None] + jnp.arange(L, dtype=jnp.int32)[None]
        sl = seq_lens.astype(jnp.int32)
        h, caches, _ = lm_apply(
            params, cfg, tokens=tokens, positions=pos, mode="prefill",
            caches=caches, seq_lens=sl, chunked=True)
        h_last = h[jnp.arange(B), jnp.maximum(sl - 1, 0)][:, None]
        logits = lm_logits(params, cfg, h_last)[:, 0]
        return logits, caches

    def decode_step(params, caches, tokens, positions):
        """tokens: (B, 1); positions: (B, 1) absolute positions."""
        h, caches, _ = lm_apply(params, cfg, tokens=tokens, positions=positions,
                                mode="decode", caches=caches)
        logits = lm_logits(params, cfg, h[:, -1:])[:, 0]
        return logits, caches

    # -------------------------------------------------- cache slot helpers
    # head/tail cache leaves carry batch on axis 0; scanned block caches are
    # stacked (n_blocks, batch, ...) so batch is axis 1.
    def cache_slice(caches, lo: int, hi: int):
        return {
            "head": jax.tree.map(lambda c: c[lo:hi], caches["head"]),
            "tail": jax.tree.map(lambda c: c[lo:hi], caches["tail"]),
            "blocks": jax.tree.map(lambda c: c[:, lo:hi], caches["blocks"]),
        }

    def cache_merge(caches, sub, lo: int):
        return {
            "head": jax.tree.map(lambda c, s: c.at[lo:lo + s.shape[0]].set(s),
                                 caches["head"], sub["head"]),
            "tail": jax.tree.map(lambda c, s: c.at[lo:lo + s.shape[0]].set(s),
                                 caches["tail"], sub["tail"]),
            "blocks": jax.tree.map(lambda c, s: c.at[:, lo:lo + s.shape[1]].set(s),
                                   caches["blocks"], sub["blocks"]),
        }

    def cache_scatter(caches, sub, src_map):
        """Pool slot s takes sub batch row src_map[s]; src_map[s] == -1
        keeps the pooled slot bit-exactly.  One fused scatter per leaf
        (kernels/kv_cache.cache_scatter_p on TPU) lands an entire bucketed
        prefill batch at once, replacing the per-request slice/merge loop.
        src_map shape: (pool_slots,) int32, values in [-1, sub_batch).
        """
        scat = kernel_ops.cache_scatter_rows
        return {
            "head": jax.tree.map(lambda c, s: scat(c, s, src_map),
                                 caches["head"], sub["head"]),
            "tail": jax.tree.map(lambda c, s: scat(c, s, src_map),
                                 caches["tail"], sub["tail"]),
            "blocks": jax.tree.map(lambda c, s: scat(c, s, src_map, batch_axis=1),
                                   caches["blocks"], sub["blocks"]),
        }

    # ------------------------------------------------------------ dry-run IO
    def input_specs(shape_name: str) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every input of the step function."""
        sp = SHAPES[shape_name]
        f32, i32 = jnp.float32, jnp.int32
        P = _patch_count(cfg)
        if sp.kind == "train":
            S_text = sp.seq - P
            specs = {
                "tokens": jax.ShapeDtypeStruct((sp.batch, S_text), i32),
                "labels": jax.ShapeDtypeStruct((sp.batch, S_text), i32),
            }
            if cfg.frontend == "vision":
                specs["patches"] = jax.ShapeDtypeStruct((sp.batch, P, cfg.d_model), dtype)
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (sp.batch, _src_len(cfg, sp.seq), cfg.d_model), dtype)
            return specs
        if sp.kind == "prefill":
            S_text = sp.seq - P
            specs = {
                "tokens": jax.ShapeDtypeStruct((sp.batch, S_text), i32),
            }
            if cfg.frontend == "vision":
                specs["patches"] = jax.ShapeDtypeStruct((sp.batch, P, cfg.d_model), dtype)
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (sp.batch, _src_len(cfg, sp.seq), cfg.d_model), dtype)
            return specs
        # decode: one new token against a seq-length cache
        mem_len = _src_len(cfg, sp.seq) if cfg.family == "encdec" else 0
        caches = jax.eval_shape(lambda: init_caches(sp.batch, sp.seq, mem_len))
        return {
            "tokens": jax.ShapeDtypeStruct((sp.batch, 1), i32),
            "positions": jax.ShapeDtypeStruct((sp.batch, 1), i32),
            "caches": caches,
        }

    return ModelBundle(cfg=cfg, init=init, train_loss=train_loss,
                       prefill=prefill, decode_step=decode_step,
                       init_caches=init_caches, input_specs=input_specs,
                       cache_slice=cache_slice, cache_merge=cache_merge,
                       prefill_many=prefill_many, cache_scatter=cache_scatter,
                       prefill_chunk=prefill_chunk)
