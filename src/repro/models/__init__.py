from . import api, attention, config, context, layers, linops, moe, ssm, transformer
from .api import SHAPES, ModelBundle, build_model
from .config import ArchConfig, reduced

__all__ = ["api", "attention", "config", "context", "layers", "linops", "moe",
           "ssm", "transformer", "build_model", "ModelBundle", "SHAPES",
           "ArchConfig", "reduced"]
