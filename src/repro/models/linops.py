"""Switchable linear op: fp matmul or PDQ-int8 (W8A8) execution.

Models call ``lin(x, w)`` for every large projection.  When a weight leaf
has been replaced by a quantized record (see ``quantize_weight``), the
matmul runs int8 x int8 with the *PDQ-predicted* output requantization
scale - computed from the input moments BEFORE the matmul (paper Sec. 4),
so the fp accumulator never needs to be materialized to find its range.

The int8 output is immediately dequantized to the compute dtype for
composability with the surrounding (residual / norm) ops; on TPU the wins
are int8 weight streaming (2x HBM) and the int8 epilogue (no fp32 output
round-trip).  See DESIGN.md Sec. 2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops


def quantize_weight(w: jax.Array, alpha: float = 6.0, beta: float = 6.0) -> dict:
    """Deploy-time: per-output-channel symmetric int8 weight record with the
    Gaussian weight stats the PDQ surrogate needs (Eqs. 8-9)."""
    w32 = w.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(w32), axis=0), 1e-8)      # (h,)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(w32 / scale[None, :]), -127, 127).astype(jnp.int8)
    return {
        "q": q,
        "scale": scale,
        "colsum": jnp.sum(q.astype(jnp.int32), axis=0, keepdims=True),
        "mu_w": jnp.mean(w32),
        "var_w": jnp.var(w32),
        "alpha": jnp.float32(alpha),
        "beta": jnp.float32(beta),
    }


def is_quantized(w) -> bool:
    return isinstance(w, dict) and "q" in w


def lin(x: jax.Array, w) -> jax.Array:
    """y = x @ w, fp or PDQ-int8 depending on the weight leaf.

    The quantized path is the fused serving pipeline (DESIGN.md Sec. 2):
    ONE prologue kernel reads x and emits (x_q, s_x, s1, s2), the surrogate
    prices the output interval from (s1, s2) in O(rows), and ONE W8A8
    matmul applies that interval in its fp-out epilogue - no separate
    amax / quantize / act_stats passes and no int8 requant -> dequant
    round-trip on the output.
    """
    if not is_quantized(w):
        return x @ w
    return ops.pdq_dense(x, w, out="fp", out_dtype=x.dtype)


def quantize_param_tree(params, path_pred=None, alpha: float = 6.0, beta: float = 6.0):
    """Replace selected 2-D weight leaves with quantized records.

    path_pred(path_str, leaf) -> bool selects leaves; default: every 2-D
    float leaf whose name starts with 'w' or ends with '_proj'.
    """
    from jax.tree_util import tree_flatten_with_path, tree_unflatten, DictKey

    def default_pred(path, leaf):
        name = path.split("/")[-1]
        return (leaf.ndim == 2 and jnp.issubdtype(leaf.dtype, jnp.floating)
                and (name.startswith("w") or name.endswith("_proj")
                     or name in ("in_proj", "out_proj")))

    pred = path_pred or default_pred
    leaves, treedef = tree_flatten_with_path(params)
    out = []
    for path, leaf in leaves:
        pstr = "/".join(str(getattr(k, "key", k)) for k in path)
        if pred(pstr, leaf):
            out.append(quantize_weight(leaf, alpha, beta))
        else:
            out.append(leaf)
    return tree_unflatten(treedef, out)
