"""Switchable linear ops: fp matmul or PDQ-int8 (W8A8) execution.

Models call ``lin(x, w)`` for every large projection.  When a weight leaf
has been replaced by a quantized record (see ``quantize_weight``), the
matmul runs int8 x int8 with the *PDQ-predicted* output requantization
scale - computed from the input moments BEFORE the matmul (paper Sec. 4),
so the fp accumulator never needs to be materialized to find its range.

Projections that consume the SAME input (Q/K/V off the attention norm,
gate/up off the ffn norm, MLA's wq_a/wkv_a) additionally share the
prologue: ``lin_grouped(x, (w1, w2, ...))`` runs ONE ``pdq_prologue`` and
ONE wide W8A8 matmul over the N-concatenated group record and splits the
output back into per-projection segments.  The sharing is exact, not
approximate: the surrogate interval of every segment is priced from the
same per-row moments ``(s1, s2)``, which depend only on the input
(DESIGN.md "Grouped execution").  ``lin_grouped`` transparently falls back
to per-projection ``lin`` calls when any member is unquantized or the
members are not views of one group record.  ``quantize_param_tree`` emits
grouped records for the known sibling sets automatically; each sibling key
keeps its place in the param tree as a lightweight *segment view*
(``{"group": <shared record>, "seg": SegRef(i)}`` - the shared arrays alias
one device buffer, so weight memory is not duplicated).

The int8 output is immediately dequantized to the compute dtype for
composability with the surrounding (residual / norm) ops; on TPU the wins
are int8 weight streaming (2x HBM) and the int8 epilogue (no fp32 output
round-trip).  See DESIGN.md Sec. 2.
"""
from __future__ import annotations

import dataclasses
import itertools

import jax
import jax.numpy as jnp

from repro.kernels import ops

# TPU lane width: grouped segments pad their N extent to this boundary so
# every (row, N-block) epilogue cell of the wide matmul belongs to exactly
# one segment.
LANE = 128

_GROUP_IDS = itertools.count()


def quantize_weight(w: jax.Array, alpha: float = 6.0, beta: float = 6.0) -> dict:
    """Deploy-time: per-output-channel symmetric int8 weight record with the
    Gaussian weight stats the PDQ surrogate needs (Eqs. 8-9)."""
    w32 = w.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(w32), axis=0), 1e-8)      # (h,)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(w32 / scale[None, :]), -127, 127).astype(jnp.int8)
    return {
        "q": q,
        "scale": scale,
        "colsum": jnp.sum(q.astype(jnp.int32), axis=0, keepdims=True),
        "mu_w": jnp.mean(w32),
        "var_w": jnp.var(w32),
        "alpha": jnp.float32(alpha),
        "beta": jnp.float32(beta),
    }


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class GroupSegs:
    """Static (trace-time) layout of a grouped weight record.

    ``sizes``   - original per-projection N extents;
    ``padded``  - the LANE-rounded extent each segment occupies in the
                  concatenated record;
    ``names``   - the sibling leaf names, for debugging;
    ``gid``     - unique id distinguishing otherwise shape-identical groups
                  (two layers' QKV triples must never be mixed in one
                  ``lin_grouped`` call).

    Registered static so it rides inside param pytrees as part of the
    treedef instead of becoming a traced leaf.
    """
    sizes: tuple[int, ...]
    padded: tuple[int, ...]
    names: tuple[str, ...] = ()
    gid: int = -1

    @property
    def offsets(self) -> tuple[int, ...]:
        out, off = [], 0
        for p in self.padded:
            out.append(off)
            off += p
        return tuple(out)

    @property
    def total(self) -> int:
        return sum(self.padded)


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class SegRef:
    """Static segment index carried by a grouped-record view."""
    index: int


def group_quantize_weights(ws, alpha: float = 6.0, beta: float = 6.0,
                           names: tuple[str, ...] = ()) -> dict:
    """Deploy-time: concatenate sibling weights (same K) along N into ONE
    quantized record with per-segment surrogate stats.

    Each segment is padded to the LANE (128) boundary before concatenation
    so the per-(row, N-block) interval epilogue of the wide W8A8 matmul
    never straddles two segments.  Per-channel ``scale``/``colsum`` keep
    their exact per-projection values (padding channels get scale 1 /
    colsum 0 and are sliced away after the matmul); ``mu_w``/``var_w``/
    ``alpha``/``beta`` become (n_seg,) vectors so ``ops.pdq_interval``
    broadcasts to a per-(row, segment) interval.
    """
    ws = [jnp.asarray(w) for w in ws]
    assert len(ws) >= 2, "a group needs at least two projections"
    K = ws[0].shape[0]
    assert all(w.ndim == 2 and w.shape[0] == K for w in ws), (
        f"grouped projections must share the input dim: "
        f"{[tuple(w.shape) for w in ws]}")
    qs, scales, colsums, mus, vrs, als, bes = [], [], [], [], [], [], []
    sizes, padded = [], []
    for w in ws:
        rec = quantize_weight(w, alpha, beta)
        n = w.shape[1]
        p = n + (-n) % LANE
        qs.append(jnp.pad(rec["q"], ((0, 0), (0, p - n))))
        scales.append(jnp.pad(rec["scale"], (0, p - n), constant_values=1.0))
        colsums.append(jnp.pad(rec["colsum"], ((0, 0), (0, p - n))))
        mus.append(rec["mu_w"])
        vrs.append(rec["var_w"])
        als.append(rec["alpha"])
        bes.append(rec["beta"])
        sizes.append(n)
        padded.append(p)
    return {
        "q": jnp.concatenate(qs, axis=1),
        "scale": jnp.concatenate(scales),
        "colsum": jnp.concatenate(colsums, axis=1),
        "mu_w": jnp.stack(mus),
        "var_w": jnp.stack(vrs),
        "alpha": jnp.stack(als),
        "beta": jnp.stack(bes),
        "segs": GroupSegs(sizes=tuple(sizes), padded=tuple(padded),
                          names=tuple(names), gid=next(_GROUP_IDS)),
    }


def group_segment_view(grec: dict, index: int) -> dict:
    """A param-tree leaf standing in for segment ``index`` of ``grec``.

    The view aliases the shared record (same device buffers), so sibling
    keys keep their place in the tree without duplicating weight memory.
    Caveat: the aliasing holds only while the leaves stay the same
    ``jax.Array`` objects - a transform that materializes per leaf
    (checkpoint serialization, per-leaf device_put resharding) replicates
    the shared arrays once per sibling.  Quantized trees are serving-time
    artifacts rebuilt from fp checkpoints, so this stays off the hot path.
    """
    assert 0 <= index < len(grec["segs"].sizes)
    return {"group": grec, "seg": SegRef(index)}


def segment_record(view: dict) -> dict:
    """Materialize a per-projection record from a segment view (slices the
    concatenated arrays; used only by the per-projection fallback path)."""
    g = view["group"]
    i = view["seg"].index
    segs = g["segs"]
    off, n = segs.offsets[i], segs.sizes[i]
    return {
        "q": g["q"][:, off:off + n],
        "scale": g["scale"][off:off + n],
        "colsum": g["colsum"][:, off:off + n],
        "mu_w": g["mu_w"][i],
        "var_w": g["var_w"][i],
        "alpha": g["alpha"][i],
        "beta": g["beta"][i],
    }


def is_quantized(w) -> bool:
    return isinstance(w, dict) and ("q" in w or "group" in w)


def is_grouped(w) -> bool:
    return isinstance(w, dict) and "q" in w and "segs" in w


def is_segment_view(w) -> bool:
    return isinstance(w, dict) and "group" in w


def lin(x: jax.Array, w) -> jax.Array:
    """y = x @ w, fp or PDQ-int8 depending on the weight leaf.

    The quantized path is the fused serving pipeline (DESIGN.md Sec. 2):
    ONE prologue kernel reads x and emits (x_q, s_x, s1, s2), the surrogate
    prices the output interval from (s1, s2) in O(rows), and ONE W8A8
    matmul applies that interval in its fp-out epilogue - no separate
    amax / quantize / act_stats passes and no int8 requant -> dequant
    round-trip on the output.  Segment views are sliced back to a
    per-projection record first (compatibility path; grouped call sites
    should use ``lin_grouped``).
    """
    if not is_quantized(w):
        tp = ops.tp_ctx()
        if (tp is not None and getattr(w, "ndim", 0) == 2
                and w.shape[1] % tp[1] == 0):
            # serving TP (inside a shard_map body): this shard's N-columns
            # only, then a tiled all-gather.  Each column sums the same
            # full-K products; XLA may tile the narrower fp matmul
            # differently (reduction-order ulps - the int8 path in
            # kernels/ops is the bit-exact one), which greedy parity
            # absorbs (see kernels/ops.tp_shard).
            ax, size = tp
            n_loc = w.shape[1] // size
            w_loc = jax.lax.dynamic_slice_in_dim(
                w, jax.lax.axis_index(ax) * n_loc, n_loc, 1)
            y = x @ w_loc
            return jax.lax.all_gather(y, ax, axis=y.ndim - 1, tiled=True)
        return x @ w
    if is_segment_view(w):
        w = segment_record(w)
    return ops.pdq_dense(x, w, out="fp", out_dtype=x.dtype)


def _common_group(ws) -> dict | None:
    """The shared group record iff ``ws`` are views of ONE group, in
    segment order, covering every segment; else None."""
    if not ws or not all(is_segment_view(w) for w in ws):
        return None
    segs = ws[0]["group"]["segs"]
    if len(segs.sizes) != len(ws):
        return None
    for i, w in enumerate(ws):
        if w["group"]["segs"] != segs or w["seg"].index != i:
            return None
    return ws[0]["group"]


def lin_grouped(x: jax.Array, ws) -> tuple:
    """(x @ w1, x @ w2, ...) for projections sharing the input x.

    When every member is a segment view of one grouped record (the layout
    ``quantize_param_tree`` emits for known sibling sets), this runs the
    grouped serving pipeline: ONE prologue + ONE wide W8A8 matmul whose
    per-(row, segment) interval epilogue prices each segment's surrogate
    grid from the shared (s1, s2) moments - the activation is read from HBM
    once instead of once per projection, and the decode-shaped skinny
    matmuls fuse into a single MXU-friendly wide call.  Otherwise it falls
    back to per-projection ``lin`` (fp weights, mixed quantization, or
    records that were never grouped), which is numerically identical.
    """
    ws = tuple(ws)
    grec = _common_group(ws)
    if grec is not None:
        return ops.pdq_dense_grouped(x, grec, out="fp", out_dtype=x.dtype)
    return tuple(lin(x, w) for w in ws)


# Sibling sets that consume the same input and therefore share one
# prologue: Q/K/V off the attention norm, gate/up off the ffn norm, MLA's
# two input-side projections.  Cross-attention is special-cased: its wk/wv
# read the encoder memory while wq reads the decoder stream, so only the
# (wk, wv) pair shares an input.  The dispatch keys on the parent dict key
# being exactly 'cross' - param leaf/key names are a repo-wide contract
# (see models/layers.py header and distributed/sharding._RULES), so a
# renamed cross block must update all three places together.
GROUP_SIBLING_SETS = (("wq", "wk", "wv"), ("w_gate", "w_up"),
                      ("wq_a", "wkv_a"))
CROSS_SIBLING_SETS = (("wk", "wv"),)


def quantize_param_tree(params, path_pred=None, alpha: float = 6.0,
                        beta: float = 6.0, group_siblings: bool = True):
    """Replace selected 2-D weight leaves with quantized records.

    path_pred(path_str, leaf) -> bool selects leaves; default: every 2-D
    float leaf whose name starts with 'w' or ends with '_proj'.

    With ``group_siblings`` (default), known same-input sibling sets whose
    members all pass the predicate are emitted as ONE grouped record
    (``group_quantize_weights``) with each sibling key holding a segment
    view, so ``lin_grouped`` call sites hit the one-prologue + one-matmul
    path without any per-call concatenation.
    """
    def default_pred(path, leaf):
        name = path.split("/")[-1]
        return (leaf.ndim == 2 and jnp.issubdtype(leaf.dtype, jnp.floating)
                and (name.startswith("w") or name.endswith("_proj")
                     or name in ("in_proj", "out_proj")))

    pred = path_pred or default_pred

    def q_ok(path, leaf):
        return hasattr(leaf, "ndim") and pred(path, leaf)

    def join(path, k):
        return f"{path}/{k}" if path else str(k)

    def rec(node, path, key):
        if isinstance(node, dict):
            out = {}
            done = set()
            if group_siblings:
                sets = CROSS_SIBLING_SETS if key == "cross" else GROUP_SIBLING_SETS
                for names in sets:
                    if not all(n in node for n in names):
                        continue
                    leaves = [node[n] for n in names]
                    if not all(hasattr(l, "ndim") and l.ndim == 2 for l in leaves):
                        continue
                    if len({l.shape[0] for l in leaves}) != 1:
                        continue
                    if not all(q_ok(join(path, n), l)
                               for n, l in zip(names, leaves)):
                        continue
                    grec = group_quantize_weights(leaves, alpha, beta,
                                                  names=names)
                    for i, n in enumerate(names):
                        out[n] = group_segment_view(grec, i)
                    done.update(names)
            for k, v in node.items():
                if k in done:
                    continue
                out[k] = rec(v, join(path, k), k)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v, join(path, str(i)), key)
                              for i, v in enumerate(node))
        if q_ok(path, node):
            return quantize_weight(node, alpha, beta)
        return node

    return rec(params, "", None)
