"""Mixture-of-Experts FFN with expert parallelism.

Dispatch design (production path, used under shard_map over ('data','model')):

  1. tokens are sharded over *both* mesh axes; each device routes its local
     tokens and packs them into a per-global-expert capacity buffer
     (E, C_e, d) - slot overflow drops (capacity factor 1.25, standard).
  2. one all_to_all over the 'model' (expert) axis with split_axis=0 /
     concat_axis=1 lands the buffer already bucketed per *local* expert:
     (E_local, nshards * C_e, d).
  3. batched SwiGLU einsum over the local expert stack.
  4. reverse all_to_all; the source combines expert outputs with its gates.

Zero-padded slots are free: SwiGLU(0) = 0 and the combine gathers only real
slots.  A dense-masked path (each device computes all its local experts over
all tokens, psum over 'model') serves tiny-token decode steps where the
dispatch machinery would be all overhead.

Extras: shared experts (DeepSeek) and a dense FFN residual (Arctic), both
plain TP-sharded MLPs applied to every token; switch-style load-balance aux
loss.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import dense_init, mlp_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0               # shared experts applied to all tokens
    dense_residual: bool = False    # Arctic: dense FFN in parallel with MoE
    d_ff_dense: int = 0             # width of shared/dense-residual FFN
    capacity_factor: float = 1.25
    router_scale: float = 1.0       # gate multiplier (deepseek routed_scaling)


def moe_init(key, d_model: int, cfg: MoEConfig, dtype):
    ks = jax.random.split(key, 6)
    E, ff = cfg.n_experts, cfg.d_ff_expert
    p = {
        "router": dense_init(ks[0], d_model, E, jnp.float32),
        "we_gate": jax.vmap(lambda k: dense_init(k, d_model, ff, dtype))(
            jax.random.split(ks[1], E)),
        "we_up": jax.vmap(lambda k: dense_init(k, d_model, ff, dtype))(
            jax.random.split(ks[2], E)),
        "we_down": jax.vmap(lambda k: dense_init(k, ff, d_model, dtype))(
            jax.random.split(ks[3], E)),
    }
    if cfg.n_shared:
        p["shared"] = mlp_init(ks[4], d_model, cfg.d_ff_dense or ff * cfg.n_shared, dtype)
    if cfg.dense_residual:
        p["dense"] = mlp_init(ks[5], d_model, cfg.d_ff_dense or ff, dtype)
    return p


def route(x: jax.Array, wr: jax.Array, cfg: MoEConfig,
          token_mask: jax.Array | None = None):
    """x: (T, d) -> gates (T, k), ids (T, k), aux load-balance loss.

    ``token_mask`` (T,) bool marks REAL tokens; masked (pad) tokens are
    routed nowhere: their gates are zeroed and their expert ids set to the
    out-of-range sentinel E, so they neither claim a capacity slot in
    ``_bucket`` (E is dropped as out-of-bounds) nor match any local expert
    in the dense-masked decode path.  This is the DESIGN.md Sec. 4 fix:
    bucketed-prefill pad tokens must not consume router capacity.
    """
    E = wr.shape[1]
    logits = (x.astype(jnp.float32) @ wr)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    gates = gates * cfg.router_scale
    if token_mask is not None:
        gates = jnp.where(token_mask[:, None], gates, 0.0)
        ids = jnp.where(token_mask[:, None], ids, E)
    # switch-style aux: E * sum_e f_e * p_e
    density = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * mean_prob)
    return gates, ids, aux


def _bucket(x: jax.Array, flat_ids: jax.Array, E: int, C: int):
    """Scatter tokens into (E, C, d) capacity buckets; overflow drops.

    Returns the buffer plus (bucket, slot, valid) per flattened assignment.
    """
    N = flat_ids.shape[0]
    oh = (flat_ids[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - oh                        # earlier same-id count
    slot = jnp.take_along_axis(pos, flat_ids[:, None], axis=1)[:, 0]
    valid = slot < C
    buf = jnp.zeros((E, C, x.shape[-1]), x.dtype)
    buf = buf.at[flat_ids, slot].set(x, mode="drop")         # OOB slots dropped
    return buf, slot, valid


def _expert_ffn(p, h: jax.Array) -> jax.Array:
    """h: (E_local, C, d) through the stacked SwiGLU experts."""
    g = jnp.einsum("ecd,edf->ecf", h, p["we_gate"])
    u = jnp.einsum("ecd,edf->ecf", h, p["we_up"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["we_down"])


def moe_ffn_tokens(
    p,
    x: jax.Array,              # (T_local, d) tokens on this shard
    cfg: MoEConfig,
    *,
    axis_name: str | None = None,   # expert-parallel mesh axis ('model')
    token_mask: jax.Array | None = None,   # (T_local,) True = real token
) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE over already-flattened local tokens."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    nshards = 1 if axis_name is None else jax.lax.psum(1, axis_name)  # static int
    E_loc = E // nshards

    gates, ids, aux = route(x, p["router"], cfg, token_mask)
    flat_ids = ids.reshape(-1)                              # (T*k,)
    xk = jnp.repeat(x, k, axis=0)                           # (T*k, d)
    C = max(1, int(T * k * cfg.capacity_factor / E + 0.999))
    buf, slot, valid = _bucket(xk, flat_ids, E, C)          # (E, C, d)

    if axis_name is not None and nshards > 1:
        recv = jax.lax.all_to_all(buf, axis_name, split_axis=0, concat_axis=1,
                                  tiled=True)               # (E_loc, nshards*C, d)
        out = _expert_ffn(p, recv)
        buf_out = jax.lax.all_to_all(out, axis_name, split_axis=1, concat_axis=0,
                                     tiled=True)            # (E, C, d)
    else:
        buf_out = _expert_ffn(p, buf)

    # combine: gather each assignment's expert output, weight by its gate
    y_k = buf_out[flat_ids, jnp.minimum(slot, C - 1)]       # (T*k, d)
    y_k = jnp.where(valid[:, None], y_k, 0.0)
    y = jnp.sum((y_k * gates.reshape(-1, 1).astype(y_k.dtype)).reshape(T, k, d), axis=1)
    return y, aux


def moe_ffn_dense_masked(
    p,
    x: jax.Array,              # (T, d) tokens (replicated over 'model')
    cfg: MoEConfig,
    *,
    axis_name: str | None = None,
    token_mask: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Decode-path MoE: every shard computes its local experts over all
    tokens, masked by gates; psum over the expert axis combines."""
    E, k = cfg.n_experts, cfg.top_k
    nshards = 1 if axis_name is None else jax.lax.psum(1, axis_name)  # static int
    E_loc = E // nshards
    gates, ids, aux = route(x, p["router"], cfg, token_mask)
    shard = 0 if axis_name is None else jax.lax.axis_index(axis_name)
    e_offset = shard * E_loc

    h = jnp.broadcast_to(x[None], (E_loc, *x.shape))        # (E_loc, T, d)
    out = _expert_ffn(p, h)                                 # (E_loc, T, d)
    local_eids = e_offset + jnp.arange(E_loc)               # (E_loc,)
    sel = (ids[None, :, :] == local_eids[:, None, None])    # (E_loc, T, k)
    w = jnp.sum(sel * gates[None], axis=-1)                 # (E_loc, T)
    y = jnp.einsum("et,etd->td", w.astype(out.dtype), out)
    if axis_name is not None and nshards > 1:
        y = jax.lax.psum(y, axis_name)
    return y, aux
