"""Modality frontend STUBS (per assignment: [audio]/[vlm] entries specify the
transformer backbone only; ``input_specs()`` provides precomputed frame/patch
embeddings).  These helpers synthesize such embeddings for real (smoke/
example) runs."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stub_patch_embeddings(key, batch: int, n_tokens: int, d_model: int,
                          dtype=jnp.bfloat16) -> jax.Array:
    """Stands in for a CLIP-style vision tower output (phi-3-vision)."""
    return (0.02 * jax.random.normal(key, (batch, n_tokens, d_model))).astype(dtype)


def stub_frame_embeddings(key, batch: int, n_frames: int, d_model: int,
                          dtype=jnp.bfloat16) -> jax.Array:
    """Stands in for a speech feature encoder output (seamless-m4t)."""
    return (0.02 * jax.random.normal(key, (batch, n_frames, d_model))).astype(dtype)
