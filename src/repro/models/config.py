"""Architecture configuration schema.

One instance per assigned architecture lives in ``repro/configs/<id>.py``.
Layer layout = head (unrolled) + n_blocks x pattern (lax.scan) + tail
(unrolled); ``n_layers`` must equal len(head) + n_blocks*len(pattern) +
len(tail).

Layer kinds:
  'global'        full-attention block (GQA or MLA) + FFN (MoE if cfg.moe)
  'global_dense'  like 'global' but always a dense FFN (DeepSeek layer 0)
  'local'         sliding-window attention block + FFN
  'mamba'         Mamba2 SSD block
  'shared'        zamba2-style shared transformer block (one param set,
                  reused at every occurrence; per-occurrence KV cache)
"""
from __future__ import annotations

import dataclasses

from .moe import MoEConfig
from .ssm import SSMConfig


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora: int
    kv_lora: int
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    family: str = "lm"                     # 'lm' | 'encdec'
    head_dim: int | None = None
    head: tuple[str, ...] = ()
    pattern: tuple[str, ...] = ("global",)
    tail: tuple[str, ...] = ()
    window: int | None = None
    attn_softcap: float | None = None
    logit_softcap: float | None = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    enc_layers: int = 0
    frontend: str | None = None            # 'audio' | 'vision' (stub embeddings)
    frontend_tokens: int = 0
    embed_scale: bool = False              # gemma: embeddings * sqrt(d_model)
    dtype: str = "bfloat16"
    remat: str = "full"                    # 'full' | 'none'
    long_context: bool = False             # may run the long_500k shape
    quant_kv: str = "none"                 # 'none' | 'dynamic' (int8 KV cache)
    loss_chunk: int = 512

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_blocks(self) -> int:
        n = self.n_layers - len(self.head) - len(self.tail)
        assert n % len(self.pattern) == 0, (
            f"{self.name}: {n} layers not divisible by pattern {self.pattern}")
        return n // len(self.pattern)

    def validate(self) -> "ArchConfig":
        _ = self.n_blocks
        if self.moe:
            assert self.moe.n_experts % 1 == 0
        return self


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Smoke-test variant: same family/topology, tiny dims."""
    base = dict(
        n_layers=len(cfg.head) + 2 * len(cfg.pattern) + len(cfg.tail),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        d_ff=128,
        vocab=512,
        head_dim=16,
        enc_layers=min(cfg.enc_layers, 2),
        frontend_tokens=min(cfg.frontend_tokens, 8),
        window=min(cfg.window, 16) if cfg.window else None,
        remat="none",
        loss_chunk=16,
        # CPU-executable smoke configs: the CPU runtime lacks the
        # bf16 x bf16 -> f32 dot thunk the TPU-target bf16 path uses.
        dtype="float32",
    )
    if cfg.moe:
        base["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=min(cfg.moe.top_k, 2), d_ff_expert=32,
            d_ff_dense=64 if (cfg.moe.n_shared or cfg.moe.dense_residual) else 0,
            capacity_factor=8.0)  # avoid capacity drops in tiny smoke tests
    if cfg.mla:
        base["mla"] = MLAConfig(q_lora=32, kv_lora=32, qk_nope=16, qk_rope=8, v_head=16)
    if cfg.ssm:
        base["ssm"] = SSMConfig(d_model=64, d_state=16, head_dim=16, expand=2,
                                d_conv=4, chunk=16)
    base.update(overrides)
    return dataclasses.replace(cfg, **base).validate()
