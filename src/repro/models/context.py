"""Distribution context for model code.

Model functions are pure; distribution is communicated via this module-level
context set by the launcher / train-step builder before tracing.  When no
context is set (unit tests, CPU smoke runs) every layer runs its local path.

Two independent contexts exist: ``DistContext`` (training/prefill MoE
dispatch over an ambient mesh, set via ``set_context``/``use_context``)
and the serving-TP context (``tp_shard``, re-exported from
``kernels/ops``: column-splits PDQ/fp projections inside a shard_map
body; see serve/sharded.py).  The sharded serve engine deliberately runs
with ``DistContext`` unset so MoE stays replica-local.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.kernels.ops import tp_ctx, tp_shard  # noqa: F401  (re-export)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` when available, else the jax<0.5 experimental one
    (whose replication check is spelled ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Any                          # jax.sharding.Mesh
    token_axes: tuple[str, ...]        # mesh axes sharding flattened tokens for MoE
    expert_axis: str                   # mesh axis experts are sharded over ('model')
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"


_CTX: DistContext | None = None


def set_context(ctx: DistContext | None) -> None:
    global _CTX
    _CTX = ctx


def get_context() -> DistContext | None:
    return _CTX


@contextlib.contextmanager
def use_context(ctx: DistContext | None):
    global _CTX
    prev = _CTX
    _CTX = ctx
    try:
        yield
    finally:
        _CTX = prev


def moe_param_specs(p) -> Any:
    """shard_map in_specs for a routed-MoE param subtree."""
    return {
        "router": P(None, None),
        "we_gate": P(_CTX.expert_axis, None, None),
        "we_up": P(_CTX.expert_axis, None, None),
        "we_down": P(_CTX.expert_axis, None, None),
    }
